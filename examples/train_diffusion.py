"""End-to-end driver: train a DiT diffusion model with the full production
stack — data pipeline, AdamW, checkpoint/restart, fault-tolerant loop —
then sample from it with SRDS.

Presets:
  --preset cpu   ~1M-param DiT, 300 steps   (default; minutes on this box)
  --preset full  the ~100M srds-dit-cifar, a few hundred steps (use on a
                 real accelerator; same code path)

  PYTHONPATH=src python examples/train_diffusion.py --preset cpu
"""
import argparse
import dataclasses as dc
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.core import (SolverConfig, SRDSConfig, make_schedule,
                        sample_sequential, srds_sample)
from repro.data import DataConfig, make_stream
from repro.models.dit import dit_forward, init_dit
from repro.optim import AdamWConfig, init_opt_state, warmup_cosine
from repro.runtime import LoopConfig, PreemptionSignal, train_loop
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=["cpu", "full"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/srds_dit_ckpt")
    args = ap.parse_args()

    base = get_arch("srds-dit-cifar")
    if args.preset == "cpu":
        cfg = dc.replace(base, num_layers=3, d_model=96, num_heads=4,
                         num_kv_heads=4, head_dim=24, d_ff=384, patch_size=4,
                         dtype="float32")
        steps = args.steps or 300
        batch = 16
    else:
        cfg = base   # 12L/768d ~100M params, the paper-scale benchmark model
        steps = args.steps or 300
        batch = 64

    key = jax.random.PRNGKey(0)
    params = init_dit(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"DiT {cfg.name} [{args.preset}]: {n_params:,} params, "
          f"{steps} steps, batch {batch}")
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, schedule=warmup_cosine(1e-3, 30, steps))
    step = jax.jit(make_train_step(cfg, opt_cfg, loss_kind="diffusion",
                                   use_kernel=False),
                   donate_argnums=(0, 1))
    stream = make_stream(cfg, DataConfig(global_batch=batch, seq_len=0))
    ck = Checkpointer(args.ckpt)
    hist = []

    def log(s, m):
        hist.append(m["loss"])
        print(f"  step {s}: mse={m['loss']:.4f} lr={m['lr']:.2e} "
              f"({m['step_time_s']:.2f}s/step)")

    params, opt, _ = train_loop(step, params, opt, stream, key, ck,
                                LoopConfig(total_steps=steps, ckpt_every=100,
                                           log_every=25),
                                preemption=PreemptionSignal(install_sigterm=True),
                                metrics_cb=log)
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f}")

    # SRDS sampling from the trained model
    def model_fn(x, t):
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return dit_forward(cfg, params, x, tb, use_kernel=False)

    size = 32 if args.preset == "full" else 32
    sched = make_schedule("ddpm_linear", 100)
    x0 = jax.random.normal(jax.random.PRNGKey(9), (2, size, size, 3))
    ref = sample_sequential(model_fn, sched, SolverConfig("ddim"), x0)
    res = srds_sample(model_fn, sched, SolverConfig("ddim"), x0,
                      SRDSConfig(tol=1e-3))
    print(f"SRDS on the trained model: {int(res.iterations)} refinements, "
          f"err vs sequential {float(jnp.mean(jnp.abs(res.sample-ref))):.2e}")
    print("sample stats:",
          f"min={float(res.sample.min()):.2f} max={float(res.sample.max()):.2f}")


if __name__ == "__main__":
    main()
