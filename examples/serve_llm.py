"""Batched serving example: prefill + lockstep greedy decode with KV caches
through the ServingEngine (reduced config on CPU; the same engine lowers on
the production mesh via repro.launch.dryrun decode cells).

  PYTHONPATH=src python examples/serve_llm.py --arch qwen3-8b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

kops.FORCE_REF = True

from repro.configs import get_arch
from repro.models import init_params
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=args.batch, max_seq=128)
    key = jax.random.PRNGKey(1)
    reqs = [Request(prompt=jax.random.randint(
                jax.random.fold_in(key, i), (8 + 2 * i,), 0, cfg.vocab_size),
            max_new_tokens=args.new_tokens)
            for i in range(args.batch)]
    outs = engine.generate(reqs)
    for i, o in enumerate(outs):
        print(f"request {i} ({reqs[i].prompt.shape[0]} prompt toks) -> {o}")
    print(f"served {args.batch} requests x {args.new_tokens} tokens "
          f"(batched lockstep decode, {cfg.name})")


if __name__ == "__main__":
    main()
