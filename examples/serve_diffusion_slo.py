"""SLO-aware diffusion sampling service, end to end on this CPU box:

  1. build a :class:`DiffusionSamplingEngine` (micro-batched SRDS with
     per-slot convergence gating + slot recycling),
  2. generate a seeded Poisson arrival trace of two traffic tiers (a
     majority of loose-tolerance/tight-SLO requests, a minority of
     tight-tolerance/loose-SLO ones),
  3. replay it under FIFO, EDF and cost-model admission with
     :func:`repro.serve.scheduler.simulate` on the engine's deterministic
     virtual clock, and compare latency percentiles / SLO attainment /
     goodput,
  4. replay a thundering-herd burst where the cost model starts shedding
     provably-hopeless requests.

  PYTHONPATH=src python examples/serve_diffusion_slo.py
"""
import jax
import jax.numpy as jnp

from repro.core import SolverConfig
from repro.serve import (EDF, FIFO, CostAware, DiffusionSamplingEngine,
                         SampleRequest, Tier, bursty_trace, poisson_trace,
                         simulate)


def main():
    # a small two-layer denoiser; any (x, t) -> eps callable works
    w1 = jax.random.normal(jax.random.PRNGKey(0), (16, 16)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(1), (16, 16)) * 0.4

    def model_fn(x, t):
        h = jnp.tanh(x @ w1) * (0.4 + 3e-4 * t)
        return jnp.tanh(h @ w2 + x * 0.1)

    engine = DiffusionSamplingEngine(model_fn, (16,), SolverConfig("ddim"),
                                     num_steps=64, batch_size=2,
                                     sec_per_eval=1e-5)

    # simple FIFO drain still works request-by-request (no SLOs involved)
    rid = engine.submit(SampleRequest(seed=0, tol=1e-3))
    out = engine.drain()
    print(f"single request: {out[rid].iterations} SRDS iterations, "
          f"{out[rid].model_evals} model evals\n")

    tiers = [Tier(tol=1e-2, slo_ms=25, iters_hint=2, weight=0.96),
             Tier(tol=1e-6, slo_ms=400, iters_hint=8, weight=0.04)]

    print("=== Poisson arrivals, 380 req/s, 100 requests ===")
    trace = poisson_trace(100, rate=380.0, tiers=tiers, seed=0)
    for policy in (FIFO(), EDF(), CostAware()):
        rep = simulate(engine, trace, policy)
        print(f"  {policy.name:5s}: p50={rep.latency_p50 * 1e3:6.1f}ms "
              f"p95={rep.latency_p95 * 1e3:6.1f}ms "
              f"slo_att={rep.slo_attainment:.2f} "
              f"goodput={rep.goodput_rps:6.1f}rps "
              f"rejected={len(rep.rejected)}")

    print("\n=== Thundering herd: 2 bursts of 20 ===")
    herd = bursty_trace(2, 20, period=0.08, tiers=tiers, seed=0, jitter=0.005)
    for policy in (FIFO(), EDF(), CostAware()):
        rep = simulate(engine, herd, policy)
        print(f"  {policy.name:5s}: p95={rep.latency_p95 * 1e3:6.1f}ms "
              f"slo_att={rep.slo_attainment:.2f} "
              f"goodput={rep.goodput_rps:6.1f}rps "
              f"rejected={len(rep.rejected)}")

    print("\nengine stats():")
    for k, v in engine.stats().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
