"""Sampling comparison driver: sequential vs vanilla SRDS vs distributed
(block-parallel + wavefront-pipelined) SRDS, plus the SRDS-native straggler
mitigation — on fake devices so the whole flow runs on this CPU box.

  PYTHONPATH=src python examples/srds_sampling.py  (re-execs with 8 devices)
"""
import json
import os
import subprocess
import sys

CODE = r"""
import jax
import jax.numpy as jnp
from repro.core import (DiffusionSchedule, SolverConfig, SRDSConfig,
                        make_schedule, sample_sequential, srds_sample)
from repro.core.pipelined import make_pipelined_sampler, make_sharded_sampler

jax.config.update("jax_enable_x64", True)
assert len(jax.devices()) == 8
w = jax.random.normal(jax.random.PRNGKey(0), (24, 24), dtype=jnp.float64) * 0.35
model_fn = lambda x, t: jnp.tanh(x @ w) * (0.4 + 3e-4 * t)
N = 64
sched = make_schedule("ddpm_linear", N)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64))
solver = SolverConfig("ddim")
x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 24), dtype=jnp.float64)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("time",))

ref = sample_sequential(model_fn, sched, solver, x0)
print(f"sequential: {N} serial evals")

res = srds_sample(model_fn, sched, solver, x0, SRDSConfig(tol=1e-5))
print(f"vanilla SRDS:     iters={int(res.iterations)} "
      f"err={float(jnp.mean(jnp.abs(res.sample-ref))):.2e}")

from repro.core import iteration_cost, predicted_evals, truncated_evals
res_t = srds_sample(model_fn, sched, solver, x0,
                    SRDSConfig(tol=1e-5, truncate=True))
cost = iteration_cost(N, None, 1)
k = int(res_t.iterations)
print(f"truncated SRDS:   iters={k} bit-identical="
      f"{bool(jnp.all(res_t.sample == res.sample))} "
      f"evals={truncated_evals(cost, k)} vs {predicted_evals(cost, k)} "
      f"untruncated (converged-prefix truncation)")

samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=1e-5, num_blocks=8))
res = samp(x0)
print(f"block-parallel:   iters={int(res.iterations)} "
      f"err={float(jnp.mean(jnp.abs(res.sample-ref))):.2e}  (8 devices)")

samp, = [make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                                SRDSConfig(tol=1e-5))]
res, steps, evals = samp(x0)
print(f"wavefront:        iters={int(res.iterations)} supersteps={int(steps)} "
      f"physical_evals={int(evals)} "
      f"err={float(jnp.mean(jnp.abs(res.sample-ref))):.2e}  "
      f"(vs {N} sequential evals; retired devices skip theirs)")

def strag(p):
    m = jnp.zeros((8,), bool).at[3].set(True)
    return jnp.where(p % 2 == 1, m, jnp.zeros((8,), bool))
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=1e-5, num_blocks=8, max_iters=20),
                            straggler_fn=strag)
res = samp(x0)
print(f"with stragglers:  iters={int(res.iterations)} "
      f"err={float(jnp.mean(jnp.abs(res.sample-ref))):.2e}  "
      f"(block 3 stale every other refinement — still exact)")

# --- batched: per-sample convergence gating (mixed-tolerance batch) ---
xb = jax.random.normal(jax.random.PRNGKey(2), (4, 24), dtype=jnp.float64)
tols = jnp.array([1e-2, 1e-3, 1e-4, 1e-5], jnp.float32)
res = srds_sample(model_fn, sched, solver, xb, SRDSConfig(per_sample=True),
                  tol=tols)
print(f"per-sample SRDS:  iters={res.iterations.tolist()} "
      f"for tol={tols.tolist()} (each sample stops at its own tolerance)")
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(per_sample=True, num_blocks=8))
res_d = samp(xb, tols)
print(f"sharded batched:  iters={res_d.iterations.tolist()} "
      f"(bit-identical to the single-program batched run: "
      f"{bool(jnp.all(res_d.sample == res.sample))})")

# --- the serving layer: micro-batching + slot recycling over a queue ---
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest
eng = DiffusionSamplingEngine(model_fn, (24,), solver, num_steps=N,
                              batch_size=4, dtype=jnp.float64)
reqs = [SampleRequest(seed=i, tol=[1e-2, 1e-3, 1e-4, 1e-5][i % 4])
        for i in range(12)]
rids = [eng.submit(r) for r in reqs]
out = eng.drain()
st = eng.stats()
iters = [out[r].iterations for r in rids]
lock = sum(len(g) * (8 + max(g) * 72) for g in
           (iters[i:i+4] for i in range(0, len(iters), 4)))
print(f"serving engine:   {len(reqs)} mixed-tol requests, batch 4 -> "
      f"{st['effective_evals_per_sample']:.0f} evals/sample "
      f"(lockstep gating would pay {lock / len(reqs):.0f})")
"""


def main():
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", CODE], env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
