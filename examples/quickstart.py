"""Quickstart: train a tiny DiT on synthetic images, then sample with
sequential DDIM vs SRDS and verify the approximation-free property.

  PYTHONPATH=src python examples/quickstart.py [--steps 150]
"""
import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

import dataclasses as dc

from repro.configs import get_arch
from repro.core import (SolverConfig, SRDSConfig, make_schedule,
                        sample_sequential, srds_sample, srds_stats)
from repro.data import DataConfig, make_stream
from repro.models.dit import dit_forward, init_dit
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--n", type=int, default=100, help="denoising steps")
    args = ap.parse_args()

    # tiny DiT on 16x16 synthetic images
    cfg = dc.replace(get_arch("srds-dit-cifar"), num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=4, head_dim=16, d_ff=256,
                     patch_size=4, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_dit(cfg, key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=2e-3),
                                   loss_kind="diffusion", use_kernel=False))
    stream = make_stream(cfg, DataConfig(global_batch=16, seq_len=0))
    stream.size = 16
    print(f"training tiny DiT ({sum(x.size for x in jax.tree.leaves(params)):,} params)")
    first = last = None
    for s in range(args.steps):
        params, opt, m = step(params, opt, stream.batch(s),
                              jax.random.fold_in(key, s))
        if s == 0:
            first = float(m["loss"])
        if s % 30 == 0:
            print(f"  step {s}: mse={float(m['loss']):.4f}")
    last = float(m["loss"])
    assert last < first, "training should reduce the loss"

    # sample: sequential vs SRDS
    def model_fn(x, t):
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return dit_forward(cfg, params, x, tb, use_kernel=False)

    sched = make_schedule("ddpm_linear", args.n)
    solver = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(42), (4, 16, 16, 3))
    ref = sample_sequential(model_fn, sched, solver, x0)
    scfg = SRDSConfig(tol=2e-3)
    res = srds_sample(model_fn, sched, solver, x0, scfg)
    scale = float(jnp.mean(jnp.abs(ref)))
    err = float(jnp.mean(jnp.abs(res.sample - ref))) / max(scale, 1e-9)
    st = srds_stats(sched, solver, scfg, int(res.iterations))
    stp = srds_stats(sched, solver, scfg, int(res.iterations), pipelined=True)
    print(f"\nsequential evals: {args.n}")
    print(f"SRDS: {int(res.iterations)} refinements, "
          f"eff-serial {st.serial_evals} (pipelined {stp.serial_evals}), "
          f"total {st.total_evals}")
    print(f"relative |SRDS - sequential| = {err:.2e}  "
          f"(== sequential up to the tolerance: approximation-free)")
    print(f"projected latency gain (pipelined): {args.n / stp.serial_evals:.2f}x")


if __name__ == "__main__":
    main()
