#!/usr/bin/env python
"""Docs-vs-code drift gate (stdlib-only, like reprolint — the CI lint
leg runs it with no JAX installed).

Documentation rots in ways tests never notice: a rule table that stopped
matching the linter's registry, a "rules RL001-RL007" range written when
RL007 was the last rule, a quoted command whose module was renamed, a
pointer to a file that moved.  This gate re-derives each of those claims
from the code and fails loudly on drift:

1. **Rule table**: the ``| RLxxx | `name` | ...`` table in README.md
   must carry exactly ``repro.analysis``'s registered rules — same
   codes, same names (the same data ``python -m repro.analysis
   --list-rules`` prints).
2. **Rule references**: every ``RLxxx`` code mentioned anywhere in the
   checked docs must exist in the registry, and every ``RL001-RLxxx``
   range must end at the registry's last rule (stale ranges are how
   "RL001-RL007" survives the introduction of RL008).
3. **Quoted commands**: every ``python -m <module>`` in the docs must
   resolve to a real module file (under ``src/`` or the repo root).
4. **Quoted paths**: every backticked repo path and relative markdown
   link must exist.

Checked docs: README.md, ROADMAP.md, docs/*.md.

Usage:
    python scripts/check_docs.py [--root REPO_ROOT]

Exit 0 clean, 1 drift found, 2 could not run.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

_RULE_ROW = re.compile(r"^\|\s*(RL\d{3})\s*\|\s*`([^`]+)`\s*\|")
_RULE_REF = re.compile(r"\bRL\d{3}\b")
_RULE_RANGE = re.compile(r"\b(RL\d{3})\s*[-–]\s*(RL\d{3})\b")
_PY_DASH_M = re.compile(r"python(?:3)?\s+-m\s+([A-Za-z0-9_.]+)")
_BACKTICK = re.compile(r"`([^`\n]+)`")
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backticked tokens that look like repo paths: contain a slash, no
# placeholders/globs, end in a source-ish extension or a trailing slash
_PATHLIKE = re.compile(r"^[A-Za-z0-9_.][A-Za-z0-9_./-]*"
                       r"(?:\.(?:py|sh|md|json|yml|yaml|toml|txt)|/)$")


def doc_files(root: Path):
    docs = [root / "README.md", root / "ROADMAP.md"]
    docs += sorted((root / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def module_exists(root: Path, module: str) -> bool:
    rel = Path(*module.split("."))
    for base in (root / "src", root):
        if (base / rel).with_suffix(".py").exists() \
                or (base / rel / "__init__.py").exists():
            return True
    return False


def check_rule_table(root: Path, registry: dict) -> list:
    """README's rule table == the registry (codes and names)."""
    failures = []
    readme = root / "README.md"
    table = {}
    for line in readme.read_text(encoding="utf-8").splitlines():
        m = _RULE_ROW.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    if not table:
        return [f"{readme.name}: rule table (| RLxxx | `name` | ...) "
                f"not found — the registry has {len(registry)} rules to "
                f"document"]
    for code, name in sorted(registry.items()):
        if code not in table:
            failures.append(f"{readme.name}: rule table is missing {code} "
                            f"(`{name}`) — run `python -m repro.analysis "
                            f"--list-rules` and update it")
        elif table[code] != name:
            failures.append(f"{readme.name}: rule table names {code} "
                            f"`{table[code]}` but the registry says "
                            f"`{name}`")
    for code in sorted(set(table) - set(registry)):
        failures.append(f"{readme.name}: rule table documents {code}, "
                        f"which is not in the registry")
    return failures


def check_rule_refs(doc: Path, text: str, registry: dict) -> list:
    failures = []
    last = max(registry) if registry else None
    for code in sorted(set(_RULE_REF.findall(text))):
        if code not in registry:
            failures.append(f"{doc.name}: references {code}, which is not "
                            f"a registered reprolint rule")
    for lo, hi in set(_RULE_RANGE.findall(text)):
        if hi in registry and hi != last:
            failures.append(f"{doc.name}: stale rule range {lo}-{hi} — the "
                            f"registry now ends at {last}")
    return failures


def check_commands(root: Path, doc: Path, text: str) -> list:
    failures = []
    for module in sorted(set(_PY_DASH_M.findall(text))):
        top = module.split(".")[0]
        if not ((root / "src" / top).is_dir() or (root / top).is_dir()):
            continue          # third-party module (python -m pytest, ...)
        if not module_exists(root, module):
            failures.append(f"{doc.name}: quotes `python -m {module}` but "
                            f"no such module exists under src/ or the "
                            f"repo root")
    return failures


def check_paths(root: Path, doc: Path, text: str) -> list:
    failures = []
    candidates = set()
    for tok in _BACKTICK.findall(text):
        tok = tok.strip().split()[0] if tok.strip() else ""
        if "/" in tok and ".." not in tok and _PATHLIKE.match(tok):
            candidates.add(tok)
    for target in _MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")) \
                or ".." in target:
            continue
        candidates.add(target.split("#")[0])
    for rel in sorted(c for c in candidates if c):
        # resolve relative to the doc, the repo root, and the package
        # root (docs shorthand like `serve/diffusion.py`)
        if not any(base / rel for base in
                   (doc.parent, root, root / "src" / "repro")
                   if (base / rel).exists()):
            failures.append(f"{doc.name}: points at `{rel}`, which does "
                            f"not exist")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: this script's parent's parent)")
    args = ap.parse_args(argv)
    root = Path(args.root) if args.root else Path(__file__).resolve().parents[1]

    sys.path.insert(0, str(root / "src"))
    try:
        from repro.analysis.core import rule_table
    except Exception as exc:     # pragma: no cover - broken tree
        print(f"check_docs: cannot import repro.analysis ({exc})",
              file=sys.stderr)
        return 2
    registry = {code: name for code, name, _ in rule_table()}

    failures = check_rule_table(root, registry)
    for doc in doc_files(root):
        text = doc.read_text(encoding="utf-8")
        failures += check_rule_refs(doc, text, registry)
        failures += check_commands(root, doc, text)
        failures += check_paths(root, doc, text)

    if failures:
        print("docs-vs-code drift gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    ndocs = len(doc_files(root))
    print(f"check_docs OK ({ndocs} docs against {len(registry)} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
