#!/usr/bin/env bash
# Tier-1 verify — the one command CI and humans both run (see ROADMAP.md).
# Usage: scripts/check.sh [--fast] [--lint-only] [extra pytest args]
#   --fast:      skip tests marked slow/distributed (the CI matrix legs run
#                this; a separate full leg runs everything).
#   --lint-only: run only the reprolint static-analysis gate, no pytest
#                (the dependency-free CI lint leg runs this).
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
LINT_ONLY=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --fast) FAST=1 ;;
    --lint-only) LINT_ONLY=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

# Static-analysis gate: reprolint (python -m repro.analysis) enforces the
# standing policies as AST rules RL001-RL010 — compat drift, engine-seam
# ownership, host-sync discipline, donation safety, fused-path gating,
# test-tier markers, tracked artifacts, model-eval seam, accel-seam
# ownership, kernel-tile literals.  It replaced the
# old grep lints (which missed aliased imports like `from jax import
# tree_map`).  A missing or crashing linter is a loud failure, never a
# silent pass: the module is stdlib-only, so it must import even without
# JAX.
if ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
     python -m repro.analysis src tests benchmarks examples scripts; then
  echo "reprolint FAILED (or could not run) — see findings above." >&2
  echo "Run 'python -m repro.analysis --list-rules' for the rule table;" >&2
  echo "suppress a deliberate exception with '# reprolint: disable=CODE'." >&2
  exit 1
fi

# Docs-vs-code drift gate: the README/docs rule table must match the
# linter's own registry, quoted commands/modules must exist, and doc
# pointers must resolve.  Stdlib-only too, so the dependency-free CI
# lint leg can run it.
if ! python scripts/check_docs.py; then
  echo "check_docs FAILED — docs drifted from the code; see above." >&2
  exit 1
fi

if [[ "${LINT_ONLY}" == "1" ]]; then
  exit 0
fi

if [[ "${FAST}" == "1" ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -x -q -m "not slow and not distributed" "${ARGS[@]+"${ARGS[@]}"}"
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "${ARGS[@]+"${ARGS[@]}"}"
