#!/usr/bin/env bash
# Tier-1 verify — the one command CI and humans both run (see ROADMAP.md).
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# Compat-policy lint (ROADMAP "Runtime-compat policy"): APIs that drifted
# across the JAX 0.4 -> 0.5 boundary may only be touched through
# repro.compat — direct call sites anywhere else fail the build.
if violations=$(grep -rnE 'jax\.shard_map\(|jax\.experimental\.shard_map|jax\.make_mesh\(' \
      --include='*.py' src tests benchmarks examples \
      | grep -v '^src/repro/compat\.py:'); then
  echo "compat-policy lint FAILED: drifted JAX APIs called outside repro.compat" >&2
  echo "${violations}" >&2
  echo "Use repro.compat.shard_map / repro.compat.make_mesh instead (ROADMAP.md)." >&2
  exit 1
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
