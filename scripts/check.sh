#!/usr/bin/env bash
# Tier-1 verify — the one command CI and humans both run (see ROADMAP.md).
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
