#!/usr/bin/env bash
# Tier-1 verify — the one command CI and humans both run (see ROADMAP.md).
# Usage: scripts/check.sh [--fast] [extra pytest args]
#   --fast: skip tests marked slow/distributed (the CI matrix legs run this;
#           a separate full leg runs everything).
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --fast) FAST=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

# Compat-policy lint (ROADMAP "Runtime-compat policy"): APIs that drifted
# across the JAX 0.4 -> 0.5 boundary may only be touched through
# repro.compat — direct call sites anywhere else fail the build.  This
# includes jax.tree_map / jax.tree_util.tree_map (jax.tree_map was removed
# in 0.5; compat.tree is the blessed spelling).
if violations=$(grep -rnE 'jax\.shard_map\(|jax\.experimental\.shard_map|jax\.make_mesh\(|jax\.tree_util\.tree_map\(|jax\.tree_map\(' \
      --include='*.py' src tests benchmarks examples \
      | grep -v '^src/repro/compat\.py:'); then
  echo "compat-policy lint FAILED: drifted JAX APIs called outside repro.compat" >&2
  echo "${violations}" >&2
  echo "Use repro.compat.shard_map / make_mesh / tree instead (ROADMAP.md)." >&2
  exit 1
fi

# Artifact lint (the PR 1 -> 2 regression class): build caches (incl.
# pytest's .pytest_cache droppings) and dry-run experiment outputs must
# never be tracked.
if tracked=$(git ls-files | grep -E '(^|/)__pycache__(/|$)|(^|/)\.pytest_cache(/|$)|\.pyc$|^experiments/dryrun'); then
  echo "artifact lint FAILED: build/experiment artifacts are tracked in git" >&2
  echo "${tracked}" >&2
  echo "git rm --cached them and keep .gitignore covering the pattern." >&2
  exit 1
fi

if [[ "${FAST}" == "1" ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -x -q -m "not slow and not distributed" "${ARGS[@]+"${ARGS[@]}"}"
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "${ARGS[@]+"${ARGS[@]}"}"
