"""Fault-tolerant training loop: periodic+preemption checkpointing,
restart-resume, transient-failure retry, straggler policy hooks.

Designed for 1000+-node operation:
  * the loop's *only* durable state is (params, opt_state, step) + the
    stateless data pipeline (batch = f(step)), so restart-resume is
    bitwise-exact (asserted in tests);
  * preemption is an injectable signal (SIGTERM handler in production; an
    event/callback in tests) — the loop finishes the in-flight step, saves,
    and exits with PREEMPTED_EXIT_CODE for the launcher to reschedule;
  * transient step failures (device OOM blips, flaky interconnect) retry
    with the same batch up to ``max_retries`` — determinism makes the retry
    exact rather than approximate;
  * SRDS-side straggler mitigation lives in the sampler itself
    (core/pipelined.py: stale-fine-result substitution); training-side
    stragglers are an infrastructure concern surfaced via ``step_timeout``
    telemetry in the metrics dict.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer

PREEMPTED_EXIT_CODE = 17


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    max_retries: int = 2
    step_timeout_s: Optional[float] = None   # telemetry threshold


class PreemptionSignal:
    """Shared flag; production wiring hooks SIGTERM, tests set it directly."""

    def __init__(self, install_sigterm: bool = False):
        self._ev = threading.Event()
        if install_sigterm:
            signal.signal(signal.SIGTERM, lambda *_: self._ev.set())

    def set(self):
        self._ev.set()

    def is_set(self) -> bool:
        return self._ev.is_set()


class Preempted(RuntimeError):
    pass


def train_loop(step_fn: Callable, params, opt_state, stream, key,
               ckpt: Checkpointer, cfg: LoopConfig,
               preemption: Optional[PreemptionSignal] = None,
               metrics_cb: Optional[Callable[[int, Dict], None]] = None,
               fault_injector: Optional[Callable[[int], None]] = None):
    """Run (or resume) training.  Returns (params, opt_state, step).

    Resume: if the checkpointer has a checkpoint, state is restored from it
    and the loop continues from the saved step — callers pass freshly-inited
    (params, opt_state) as restore templates.
    """
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), start_step, _ = ckpt.restore(
            (params, opt_state), latest)

    step = start_step
    while step < cfg.total_steps:
        if preemption is not None and preemption.is_set():
            ckpt.save(step, (params, opt_state), {"preempted": True})
            raise Preempted(f"preempted at step {step}")
        batch = stream.batch(step)
        step_key = jax.random.fold_in(key, step)
        t0 = time.monotonic()
        for attempt in range(cfg.max_retries + 1):
            try:
                if fault_injector is not None:
                    fault_injector(step)   # may raise (simulated fault)
                new_params, new_opt, metrics = step_fn(params, opt_state,
                                                       batch, step_key)
                params, opt_state = new_params, new_opt
                break
            except Preempted:
                raise
            except Exception:
                if attempt >= cfg.max_retries:
                    # persist state before giving up so restart can resume
                    ckpt.save(step, (params, opt_state), {"failed_step": step})
                    raise
        dt = time.monotonic() - t0
        step += 1
        if metrics_cb is not None and (step % cfg.log_every == 0
                                       or step == cfg.total_steps):
            m = {k: float(v) for k, v in metrics.items()}
            m["step_time_s"] = dt
            if cfg.step_timeout_s and dt > cfg.step_timeout_s:
                m["straggler"] = 1.0
            metrics_cb(step, m)
        if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
            ckpt.save_async(step, (params, opt_state))
    ckpt.wait()
    return params, opt_state, step
