from .fault_tolerance import (PREEMPTED_EXIT_CODE, LoopConfig, Preempted,
                              PreemptionSignal, train_loop)
