from .adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                    global_norm, init_opt_state, warmup_cosine)
