"""AdamW with fp32 moments, global-norm clipping, decoupled weight decay.

Pure-functional (no optax dependency): state is a plain pytree so the
checkpointer and ZeRO-1 sharding rules treat it like any other tree.
Mixed precision: bf16 params are updated in fp32 and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    bf16_grad_sync: bool = False


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm, keep_dtype: bool = False):
    """keep_dtype=True scales grads in their own dtype (bf16) so the DP
    all-reduce XLA fuses around the scaling stays bf16 — 2x less wire
    traffic; the f32 upcast then happens after the sync, inside the
    per-shard moment update (see EXPERIMENTS.md §Perf)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    if keep_dtype:
        return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip,
                                       keep_dtype=cfg.bf16_grad_sync)
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched
