"""Train-step factories: loss -> grad -> clipped AdamW, donated buffers.

``make_train_step``         — standard pjit path (TP/EP/SP via ParallelCtx &
                              in/out shardings supplied by the launcher).
``make_dp_train_step_compressed`` — pure-DP variant whose gradient
                              all-reduce goes through the int8+error-feedback
                              compressed collective (shard_map ring).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models.transformer import LOCAL, ParallelCtx
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from .losses import diffusion_loss, lm_loss


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    parallel: ParallelCtx = LOCAL, remat: bool = False,
                    loss_kind: str = "lm",
                    use_kernel: Optional[bool] = None):
    """Returns step(params, opt_state, batch, key) -> (params, opt_state,
    metrics).  jit with donation is applied by the caller (the launcher owns
    shardings)."""

    def loss_fn(params, batch, key):
        if loss_kind == "lm":
            return lm_loss(cfg, params, batch, parallel=parallel, remat=remat,
                           use_kernel=use_kernel)
        return diffusion_loss(cfg, params, batch, key, use_kernel=use_kernel)

    def step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, key)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return step


def jit_train_step(step_fn, in_shardings=None, out_shardings=None):
    return jax.jit(step_fn, donate_argnums=(0, 1),
                   in_shardings=in_shardings, out_shardings=out_shardings)


# --------------------------------------------------------------------------
# compressed-gradient pure-DP variant
# --------------------------------------------------------------------------

def make_dp_train_step_compressed(cfg: ArchConfig, opt_cfg: AdamWConfig, mesh,
                                  axis: str = "data", *, remat: bool = False,
                                  loss_kind: str = "lm",
                                  use_kernel: Optional[bool] = None):
    """Data-parallel train step with int8 error-feedback gradient sync.

    Params/opt-state replicated; batch sharded over ``axis``; the gradient
    mean runs through :func:`repro.parallel.collectives.compressed_psum_mean`
    with a persistent error-feedback buffer carried in the opt state.
    """
    from repro.parallel.collectives import compressed_psum_mean

    def loss_fn(params, batch, key):
        if loss_kind == "lm":
            return lm_loss(cfg, params, batch, remat=remat,
                           use_kernel=use_kernel)
        return diffusion_loss(cfg, params, batch, key, use_kernel=use_kernel)

    def local_step(params, opt_state, ef, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, key)
        grads, ef = compressed_psum_mean(grads, axis, ef)
        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        loss = jax.lax.pmean(loss, axis)
        metrics = {k: jax.lax.pmean(v, axis) for k, v in metrics.items()}
        return params, opt_state, ef, dict(metrics, loss=loss, **opt_metrics)

    fn = compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis), P()),   # batch leaves shard dim 0
        out_specs=(P(), P(), P(), P()),
        check_vma=False)
    return jax.jit(fn, donate_argnums=(0, 1, 2))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
