from .losses import diffusion_loss, lm_loss
from .steps import (init_error_feedback, jit_train_step,
                    make_dp_train_step_compressed, make_train_step)
