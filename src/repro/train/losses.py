"""Loss functions: chunked-CE language modelling (causal / masked / VLM)
and the diffusion epsilon-prediction objective."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import dit
from repro.models.transformer import LOCAL, ParallelCtx, forward_hidden

AUX_COEF = 0.01
CE_CHUNK = 512


def _chunked_ce(x, labels, valid, unembed_w, vocab_real, chunk=CE_CHUNK,
                unroll: bool = False, masksum: bool = False):
    """Cross-entropy without materializing (B, S, V) fp32 logits.

    x: (B, S, d); labels: (B, S) int32; valid: (B, S) bool.
    Scans over sequence chunks; padded-vocab columns are masked out of the
    logsumexp.  Returns (sum_loss, sum_valid).
    """
    b, s, d = x.shape
    vpad = unembed_w.shape[1]
    n_chunks = max(1, s // chunk)
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    xs = x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)
    vs = valid.reshape(b, n_chunks, cs).transpose(1, 0, 2)
    col_ok = (jnp.arange(vpad) < vocab_real)

    def body(carry, inp):
        x_c, l_c, v_c = inp
        logits = (x_c @ unembed_w).astype(jnp.float32)
        logits = jnp.where(col_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        if masksum:
            # mask-sum stays local under vocab-TP: the (B,C,V) gather that
            # take_along_axis forces XLA to all-gather disappears
            gold = jnp.sum(jnp.where(l_c[..., None] == jnp.arange(logits.shape[-1]),
                                     logits, 0.0), axis=-1)
        else:
            gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = jnp.where(v_c, lse - gold, 0.0)
        loss_sum, n_sum = carry
        return (loss_sum + jnp.sum(nll), n_sum + jnp.sum(v_c)), None

    (loss_sum, n_sum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls, vs),
        unroll=unroll)
    return loss_sum, n_sum


def lm_loss(cfg: ArchConfig, params, batch, *, parallel: ParallelCtx = LOCAL,
            remat: bool = False, use_kernel: Optional[bool] = None):
    """Next-token CE for causal archs; masked-unit CE for encoder (audio);
    prefix positions excluded for VLM.  Returns (loss, metrics)."""
    x, aux, _ = forward_hidden(cfg, params, batch, parallel=parallel,
                               remat=remat, use_kernel=use_kernel)
    labels = batch["labels"]
    b, s = labels.shape
    if cfg.causal:
        x_in = x[:, :-1]
        tgt = labels[:, 1:]
        valid = jnp.ones((b, s - 1), bool)
        if cfg.frontend == "vision":
            pos = jnp.arange(s - 1)
            valid = jnp.broadcast_to(pos >= cfg.num_prefix_embeds, (b, s - 1))
    else:
        x_in = x
        tgt = labels
        valid = batch.get("mask", jnp.ones((b, s), bool))
    loss_sum, n = _chunked_ce(x_in, tgt, valid, params["unembed"]["w"],
                              cfg.vocab_size, unroll=parallel.scan_unroll,
                              masksum=parallel.ce_masksum)
    ce = loss_sum / jnp.maximum(n, 1.0)
    loss = ce + AUX_COEF * aux
    return loss, {"ce": ce, "aux": aux, "tokens": n}


def diffusion_loss(cfg: ArchConfig, params, batch, key, *,
                   schedule_ab=None, use_kernel: Optional[bool] = None):
    """Epsilon-prediction MSE on the DiT (or any denoiser).

    batch['images']: (B, H, W, C) in [-1, 1]; t sampled uniformly over the
    training grid; ab(t) from a linear-beta alpha-bar curve by default.
    """
    imgs = batch["images"]
    b = imgs.shape[0]
    k_t, k_eps = jax.random.split(key)
    t = jax.random.uniform(k_t, (b,), minval=0.0, maxval=999.0)
    if schedule_ab is None:
        betas = jnp.linspace(1e-4, 0.02, 1000)
        ab_full = jnp.cumprod(1.0 - betas)
        ab = ab_full[t.astype(jnp.int32)]
    else:
        ab = schedule_ab(t)
    eps = jax.random.normal(k_eps, imgs.shape, imgs.dtype)
    x_t = (jnp.sqrt(ab)[:, None, None, None] * imgs
           + jnp.sqrt(1 - ab)[:, None, None, None] * eps)
    pred = dit.dit_forward(cfg, params, x_t, t, use_kernel=use_kernel)
    loss = jnp.mean(jnp.square(pred.astype(jnp.float32) - eps))
    return loss, {"mse": loss}
