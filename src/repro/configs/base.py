"""Architecture + shape configuration schema and registry."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (or the paper's own DiT).

    Exact published dimensions go in the fields; TP-padding (heads/vocab to
    multiples of the model-axis size) is *derived*, never baked in, so the
    logical arch stays faithful to the source.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | dit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True              # False => encoder-only
    window: Optional[int] = None     # sliding-window attention size
    rope_theta: float = 10_000.0

    # block wiring
    block: str = "attn_mlp"          # attn_mlp | rwkv6 | hymba
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    moe_capacity_factor: float = 1.25

    # SSM / RWKV
    ssm_state: int = 0               # hymba per-head SSM state size
    ssm_d_inner: int = 0             # hymba SSM inner width (0 -> d_model)
    rwkv_head_dim: int = 64

    # modality frontends (STUBS: input_specs feeds precomputed embeddings)
    frontend: Optional[str] = None   # vision | audio
    num_prefix_embeds: int = 0       # image patches spliced as a prefix

    # DiT specifics
    patch_size: int = 0
    in_channels: int = 0

    dtype: str = "bfloat16"
    source: str = ""                 # provenance tag from the assignment

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def padded_heads(self, model_parallel: int) -> Tuple[int, int]:
        """(q_heads, kv_heads) padded so TP over ``model_parallel`` divides.

        Order matters: q heads are padded to a multiple of the TP degree
        first, then kv heads to the smallest divisor of the padded q count
        that is >= the original (keeps the GQA group ratio integral).
        Examples at TP16: hymba 25/5 -> 32/8; qwen1.5 40/40 -> 48/48;
        arctic 56/8 -> 64/8; qwen3 32/8 unchanged (kv replicates).
        """
        hq, hkv = self.num_heads, self.num_kv_heads
        if hq % model_parallel:
            hq = _round_up(hq, model_parallel)
        if hq % hkv:
            hkv = min(d for d in range(hkv, hq + 1) if hq % d == 0)
        return hq, hkv

    def padded_vocab(self, model_parallel: int) -> int:
        return _round_up(self.vocab_size, max(128, model_parallel))

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM / SWA hybrid / linear attn)."""
        return self.block in ("rwkv6", "hymba") or self.window is not None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS=6ND accounting."""
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.block == "rwkv6":
            per_layer = 4 * d * d + 2 * d * self.d_ff + d * d  # tmix + cmix
        elif self.block == "hymba":
            din = self.ssm_d_inner or d
            ssm = d * 2 * din + din * (2 * self.ssm_state + 2) + din * d
            mlp = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
            per_layer = attn + ssm + mlp
        else:
            mlp_mult = 3 if self.act == "swiglu" else 2
            per_layer = attn + mlp_mult * d * self.d_ff
            if self.moe_experts:
                per_layer += self.moe_experts * mlp_mult * d * self.moe_d_ff + d * self.moe_experts
                if not self.moe_dense_residual:
                    per_layer -= mlp_mult * d * self.d_ff  # MoE replaces dense
        embed = self.vocab_size * d * (1 if self.is_encoder_only else 2)
        return self.num_layers * per_layer + embed

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of experts)."""
        if not self.moe_experts:
            return self.param_count()
        mlp_mult = 3 if self.act == "swiglu" else 2
        inactive = (self.moe_experts - self.moe_top_k) * mlp_mult * self.d_model * self.moe_d_ff
        return self.param_count() - self.num_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=64,
            num_heads=max(2, min(4, self.num_heads)),
            num_kv_heads=max(1, min(2, self.num_kv_heads)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            moe_experts=min(self.moe_experts, 4),
            moe_d_ff=64 if self.moe_experts else 0,
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 8),
            ssm_d_inner=64 if self.block == "hymba" else 0,
            rwkv_head_dim=16,
            window=min(self.window, 32) if self.window else None,
            num_prefix_embeds=min(self.num_prefix_embeds, 4),
            patch_size=min(self.patch_size, 2) if self.patch_size else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_ARCHS = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCHS:
        # late import so `configs.<arch>` modules self-register
        from repro import configs as _c  # noqa: F401
        _c.load_all()
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCHS)}")
    return _ARCHS[name]


def arch_names():
    from repro import configs as _c
    _c.load_all()
    return sorted(_ARCHS)


def shape_cells(arch: ArchConfig):
    """The runnable (arch x shape) cells per the assignment's skip rules."""
    cells = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not arch.supports_long_context:
            continue  # full-attention archs skip 500k decode (see DESIGN.md)
        if s.is_decode and arch.is_encoder_only:
            continue  # encoder-only: no decode step
        cells.append(s)
    return cells
