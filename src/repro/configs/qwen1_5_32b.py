"""qwen1.5-32b [dense] — QKV bias — [hf:Qwen/Qwen1.5-0.5B; hf]."""
from .base import ArchConfig, register_arch

QWEN15_32B = register_arch(ArchConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True, act="swiglu", norm="rmsnorm",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
))
