"""hymba-1.5b [hybrid] — parallel attn+mamba heads, SWA —
[arXiv:2411.13676; hf].  All layers SWA (SSM path carries global context);
heads padded 25->32 / kv 5->8 only when TP requires (derived, see base)."""
from .base import ArchConfig, register_arch

HYMBA_1_5B = register_arch(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    block="hymba", ssm_state=16, ssm_d_inner=1600,
    window=1024, act="swiglu", norm="rmsnorm",
    source="arXiv:2411.13676; hf",
))
