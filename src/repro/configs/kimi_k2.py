"""kimi-k2-1t-a32b [moe] — 384 experts top-8, trillion-param —
[arXiv:2501.kimi2; unverified, paper-table]."""
from .base import ArchConfig, register_arch

KIMI_K2 = register_arch(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    moe_experts=384, moe_top_k=8, moe_d_ff=2048, moe_dense_residual=True,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2501.kimi2; unverified",
))
