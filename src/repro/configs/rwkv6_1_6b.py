"""rwkv6-1.6b 'Finch' [ssm] — attention-free, data-dependent decay —
[arXiv:2404.05892; unverified]."""
from .base import ArchConfig, register_arch

RWKV6_1_6B = register_arch(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    block="rwkv6", rwkv_head_dim=64, norm="layernorm", act="swiglu",
    source="arXiv:2404.05892; unverified",
))
