"""The paper's own denoiser configs: DiT backbones at the paper's benchmark
scales (CIFAR 32x32, LSUN 128x128 pixel; SD-v2-like 64x64x4 latent).

:func:`dit_denoiser` is the one-stop constructor wiring these configs into
the sharding-aware :class:`repro.core.denoiser.Denoiser` seam — the same
object drives ``srds_sample``, the sharded/pipelined drivers and the
serving engine, model-parallel or not."""
from .base import ArchConfig, get_arch, register_arch

# ~100M DiT for the end-to-end training example (CIFAR-scale)
SRDS_DIT_S = register_arch(ArchConfig(
    name="srds-dit-cifar", family="dit",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=0, causal=False, act="gelu", norm="layernorm",
    patch_size=4, in_channels=3,
    source="paper benchmark: 32x32 CIFAR pixel diffusion",
))

# LSUN-church/bedroom-scale pixel model (paper Table 1)
SRDS_DIT_L = register_arch(ArchConfig(
    name="srds-dit-lsun", family="dit",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=0, causal=False, act="gelu", norm="layernorm",
    patch_size=8, in_channels=3,
    source="paper benchmark: 128x128 LSUN pixel diffusion",
))

# StableDiffusion-v2-like latent denoiser (paper Tables 2-4), DiT-XL-ish
SRDS_DIT_SD = register_arch(ArchConfig(
    name="srds-dit-sd2", family="dit",
    num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
    d_ff=4608, vocab_size=0, causal=False, act="gelu", norm="layernorm",
    patch_size=2, in_channels=4,
    source="paper benchmark: SD-v2 latent diffusion (64x64x4 latents)",
))


def dit_denoiser(arch, params, *, use_kernel=None, shard_axis=None,
                 mesh=None):
    """DiT denoiser for a paper config, through the seam.

    ``arch`` is a registered config name (``srds-dit-cifar`` /
    ``srds-dit-lsun`` / ``srds-dit-sd2``) or an :class:`ArchConfig`.
    Without ``shard_axis`` this is the plain ``model_fn(x, t)`` every
    sampler already consumes (adapted on entry via
    :func:`repro.core.denoiser.as_denoiser`); with it, the returned
    :class:`repro.core.denoiser.Denoiser` patch-shards the backbone over
    that mesh axis — typically ``"model"`` on the (time, data, model) mesh
    from :func:`repro.launch.mesh.make_srds_mesh` — and every driver runs
    a genuinely model-parallel fine solve with no driver-side changes.
    """
    from repro.models.dit import make_denoiser
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    return make_denoiser(cfg, params, use_kernel=use_kernel,
                         shard_axis=shard_axis, mesh=mesh)
