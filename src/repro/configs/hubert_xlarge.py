"""hubert-xlarge [audio] — encoder-only, conv frontend STUBBED
(input_specs provides precomputed frame embeddings) —
[arXiv:2106.07447; unverified]."""
from .base import ArchConfig, register_arch

HUBERT_XLARGE = register_arch(ArchConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, act="gelu", norm="layernorm",
    frontend="audio",
    source="arXiv:2106.07447; unverified",
))
