"""Architecture configs (one module per assigned arch + the paper's own)."""
import importlib

from .base import (SHAPES, ArchConfig, ShapeConfig, arch_names, get_arch,
                   register_arch, shape_cells)

_MODULES = [
    "stablelm_3b", "qwen1_5_32b", "qwen3_8b", "qwen3_14b", "phi3_vision",
    "rwkv6_1_6b", "hymba_1_5b", "arctic_480b", "kimi_k2", "hubert_xlarge",
    "srds_dit",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
