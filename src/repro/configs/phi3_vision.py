"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP (frontend STUBBED:
input_specs provides precomputed patch embeddings spliced as a prefix) —
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from .base import ArchConfig, register_arch

PHI3_VISION = register_arch(ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    act="swiglu", norm="rmsnorm",
    frontend="vision", num_prefix_embeds=576,   # 24x24 CLIP patch grid
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
))
