"""qwen3-8b [dense] — qk_norm, GQA — [hf:Qwen/Qwen3-8B; hf]."""
from .base import ArchConfig, register_arch

QWEN3_8B = register_arch(ArchConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128,
    qk_norm=True, act="swiglu", norm="rmsnorm", rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
))
