"""arctic-480b [moe] — 128 experts top-2 + dense residual —
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import ArchConfig, register_arch

ARCTIC_480B = register_arch(ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    moe_experts=128, moe_top_k=2, moe_d_ff=4864, moe_dense_residual=True,
    act="swiglu", norm="rmsnorm",
    source="hf:Snowflake/snowflake-arctic-base; hf",
))
