"""Fused elementwise Pallas kernels for the sampler's inner loop.

Between denoiser calls the sampler is elementwise-bound; fusing the DDIM
update (5 reads/1 write naive -> 2 reads/1 write fused) and the Parareal
predictor-corrector (+ residual reduction, saving a separate full pass for
the convergence norm) removes HBM round-trips on the latency-critical path.

Layout: the ops wrapper flattens/pads operands to (rows, 128) — the TPU
native lane width, and a warp-friendly lane count on the Triton lowering
— and tiles rows.  These kernels are lowering-portable as written: no
scratch is carried across grid steps (each row tile is independent, and
the reduction outputs are per-tile partials summed by the wrapper), so
the same body compiles on both the Mosaic (TPU) and Triton (GPU)
pipelines.  Tile sizes are resolved per backend by
:mod:`repro.kernels.tuning`; the constants here are the interpret-mode
anchors that seam's heuristics reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
# Heuristic default row-tile size (the tuning seam's cpu/tpu anchor; GPU
# resolves a smaller tile).  The ops wrappers pad row counts to a multiple
# of the *resolved* tile size whenever per-tile reduction partials are
# consumed (a partial tile mapped past the array is an unspecified read on
# compiled backends) — resolve once, then pad and launch with the same
# value.
TILE_ROWS = 256


def _ddim_kernel(x_ref, e_ref, ab_ref, o_ref):
    a = ab_ref[0, 0]
    b = ab_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    x0 = (x - jnp.sqrt(1.0 - a) * e) * jax.lax.rsqrt(a)
    o_ref[...] = (jnp.sqrt(b) * x0 + jnp.sqrt(1.0 - b) * e).astype(o_ref.dtype)


def ddim_fused_pallas(x2d, eps2d, ab, *, block_rows=TILE_ROWS,
                      interpret=False):
    """x2d/eps2d: (R, 128); ab: (1, 2) [alpha_bar_from, alpha_bar_to]."""
    r = x2d.shape[0]
    br = min(block_rows, r)
    return pl.pallas_call(
        _ddim_kernel,
        grid=(pl.cdiv(r, br),),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
        name="srds_ddim_fused",
    )(x2d, eps2d, ab)


def _parareal_resid_kernel(y_ref, c_ref, p_ref, x_ref, o_ref, r_ref):
    y = y_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    xo = x_ref[...].astype(jnp.float32)
    out = y + c - p
    o_ref[...] = out.astype(o_ref.dtype)
    r_ref[0, 0] = jnp.sum(jnp.abs(out - xo))


def parareal_update_residual_pallas(y2d, c2d, p2d, x2d, *,
                                    block_rows=TILE_ROWS, interpret=False):
    """Fused ``out = y + cur - prev`` with per-tile L1(out - x_old) partials.

    This is the convergence-norm feed: ``x2d`` holds the block's previous
    trajectory value, so summing the partials gives exactly the raw L1 sum
    behind the engine's ``l1_mean`` residual — the separate full-tensor
    reduction pass disappears.  Returns ``(out (R, 128),
    partials (tiles, 1) f32)``; the caller sums (or per-sample reshapes)
    the partials.  ``block_rows`` must tile the row count so partials can
    be regrouped per sample by the ops wrapper.
    """
    r = y2d.shape[0]
    br = min(block_rows, r)
    tiles = pl.cdiv(r, br)
    return pl.pallas_call(
        _parareal_resid_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(y2d.shape, y2d.dtype),
            jax.ShapeDtypeStruct((tiles, 1), jnp.float32),
        ],
        interpret=interpret,
        name="srds_parareal_update_residual",
    )(y2d, c2d, p2d, x2d)


def _parareal_kernel(y_ref, c_ref, p_ref, o_ref, r_ref):
    y = y_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    o_ref[...] = (y + c - p).astype(o_ref.dtype)
    r_ref[0, 0] = jnp.sum(jnp.abs(c - p))


def parareal_update_pallas(y2d, c2d, p2d, *, block_rows=TILE_ROWS,
                           interpret=False):
    """Fused out = y + cur - prev with per-tile L1(cur - prev) partials.

    Returns (out (R, 128), partials (tiles, 1) f32) — caller sums partials.
    """
    r = y2d.shape[0]
    br = min(block_rows, r)
    tiles = pl.cdiv(r, br)
    return pl.pallas_call(
        _parareal_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(y2d.shape, y2d.dtype),
            jax.ShapeDtypeStruct((tiles, 1), jnp.float32),
        ],
        interpret=interpret,
        name="srds_parareal_update",
    )(y2d, c2d, p2d)
