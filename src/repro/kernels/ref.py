"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here defines the exact semantics its kernel must reproduce;
tests sweep shapes/dtypes and assert allclose(kernel, ref).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Flash attention oracle
# --------------------------------------------------------------------------

def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None) -> jnp.ndarray:
    """Multi-head attention with optional causal / sliding-window masking.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    window: attend to keys j with q_pos - window < j <= q_pos (causal SWA).
    Returns (B, Hq, Sq, D) in q.dtype (f32 accumulation).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned (decode)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: Optional[int] = None,
                      scale: Optional[float] = None, chunk: int = 512,
                      unroll: bool = False) -> jnp.ndarray:
    """Flash-style attention in *pure JAX*: online softmax over KV tiles via
    lax.scan, so peak memory is O(S*chunk) instead of O(S^2).

    This is the memory profile the Pallas TPU kernel has, expressed in plain
    HLO — used by the dry-run so compiled memory_analysis reflects the
    deployment kernel rather than a materialized S^2 logits tensor.
    Semantics identical to :func:`attention` (same oracle tests cover it).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else float(d) ** -0.5
    c = min(chunk, sk)
    n_chunks = -(-sk // c)
    pad = n_chunks * c - sk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if group > 1:
        kp = jnp.repeat(kp, group, axis=1)
        vp = jnp.repeat(vp, group, axis=1)
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(sq) + (sk - sq)

    ks = jnp.moveaxis(kp.reshape(b, hq, n_chunks, c, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hq, n_chunks, c, d), 2, 0)

    def body(carry, inp):
        acc, m, l, idx = carry
        k_c, v_c = inp
        s_ = jnp.einsum("bhqd,bhkd->bhqk", qf, k_c.astype(jnp.float32)) * scale
        kpos = idx * c + jnp.arange(c)
        keep = (kpos < sk)[None, :]
        if causal:
            keep = jnp.logical_and(keep, kpos[None, :] <= qpos[:, None])
        if window is not None:
            keep = jnp.logical_and(keep, kpos[None, :] > qpos[:, None] - window)
        s_ = jnp.where(keep[None, None], s_, -1e30)
        m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
        p = jnp.where(keep[None, None], jnp.exp(s_ - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, v_c.astype(jnp.float32))
        return (acc, m_new, l, idx + 1), None

    init = (jnp.zeros((b, hq, sq, d), jnp.float32),
            jnp.full((b, hq, sq), -1e30, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32), jnp.int32(0))
    (acc, m, l, _), _ = jax.lax.scan(body, init, (ks, vs), unroll=unroll)
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


# --------------------------------------------------------------------------
# RWKV6 (Finch) WKV oracle — sequential scan, the exact recurrence
# --------------------------------------------------------------------------

def rwkv6_wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              w: jnp.ndarray, u: jnp.ndarray,
              state: Optional[jnp.ndarray] = None):
    """Data-dependent-decay linear attention (RWKV6 'WKV').

    r, k, w: (B, H, T, Dk); v: (B, H, T, Dv); u: (H, Dk) bonus.
    decay_t = exp(-exp(w_t)) per channel (w are decay *logits*).

        out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t   = diag(decay_t) S_{t-1} + k_t v_t^T

    Returns out (B, H, T, Dv) and final state (B, H, Dk, Dv).
    """
    bsz, h, t, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((bsz, h, dk, dv), jnp.float32)

    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                      # (B,H,Dk)/(B,H,Dv)
        a = k_t[..., :, None] * v_t[..., None, :]     # (B,H,Dk,Dv) outer
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + uf[None, :, :, None] * a)
        dec = jnp.exp(-jnp.exp(w_t))
        s = dec[..., None] * s + a
        return s, out

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (rf, kf, vf, wf))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 2).astype(v.dtype), state


# --------------------------------------------------------------------------
# Fused DDIM update oracle
# --------------------------------------------------------------------------

def ddim_fused(x: jnp.ndarray, eps: jnp.ndarray, a, b) -> jnp.ndarray:
    """x' = sqrt(b) * (x - sqrt(1-a) eps)/sqrt(a) + sqrt(1-b) eps."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    xf = x.astype(jnp.float32)
    ef = eps.astype(jnp.float32)
    x0 = (xf - jnp.sqrt(1.0 - a) * ef) / jnp.sqrt(a)
    return (jnp.sqrt(b) * x0 + jnp.sqrt(1.0 - b) * ef).astype(x.dtype)


# --------------------------------------------------------------------------
# Fused Parareal predictor-corrector + block-local L1 residual oracle
# --------------------------------------------------------------------------

def parareal_update(y: jnp.ndarray, cur: jnp.ndarray, prev: jnp.ndarray):
    """out = y + cur - prev;  resid = sum(|out - y_prev_traj|)? No —
    residual here is sum |cur - prev| (the correction magnitude), which
    upper-bounds the trajectory change contributed by this block and is
    what the fused kernel accumulates for the cheap convergence heuristic.

    Returns (out, resid_scalar_f32).
    """
    out = y + cur - prev
    resid = jnp.sum(jnp.abs((cur - prev).astype(jnp.float32)))
    return out, resid


def parareal_update_residual(y: jnp.ndarray, cur: jnp.ndarray,
                             prev: jnp.ndarray, old: jnp.ndarray, *,
                             batched: bool = False,
                             batch_dims: Optional[int] = None):
    """out = y + cur - prev;  resid = L1 sum |out - old| — the exact raw
    sum behind the engine's ``l1_mean`` convergence residual (``old`` is
    the block's previous trajectory value), accumulated in the same pass
    as the update so the convergence norm needs no second full-tensor
    reduction.  All accumulation in f32 (matching the kernel).

    ``batch_dims`` is the number of leading axes the residual reduction
    *preserves*: 0 -> scalar sum, 1 -> per-sample ``(K,)``, 2 -> per-block
    per-sample ``(B, K)`` (the sliding-window frontier feed).  ``batched``
    is the legacy spelling of ``batch_dims=1``.

    Returns ``(out, resid)`` with resid an f32 array of shape
    ``y.shape[:batch_dims]``.
    """
    nd = (1 if batched else 0) if batch_dims is None else int(batch_dims)
    if not 0 <= nd < y.ndim + 1:
        raise ValueError(f"batch_dims={nd} out of range for ndim={y.ndim}")
    yf, cf, pf, of = (t.astype(jnp.float32) for t in (y, cur, prev, old))
    outf = yf + cf - pf
    axes = tuple(range(nd, y.ndim)) if nd else None
    resid = jnp.sum(jnp.abs(outf - of), axis=axes)
    return (y + cur - prev), resid
