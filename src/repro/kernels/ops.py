"""Public jit-ready wrappers around the Pallas kernels.

Dispatch policy: kernels run *compiled* on backends with a Pallas
lowering — TPU (Mosaic) and GPU (Triton) — and in ``interpret=True`` mode
elsewhere (this container is CPU-only — interpret mode executes the kernel
body in Python, validating semantics against :mod:`repro.kernels.ref`).
The backend also picks the kernel *family* where two exist: TPU-structured
kernels carry state across the sequential innermost grid axis, GPU ones
loop in-kernel (see the flash_attention/rwkv6_scan module docstrings).
Set ``repro.kernels.ops.FORCE_REF = True`` to bypass kernels entirely (used
by models on hot training paths where the interpreted kernel would dominate
CPU test time).

Tile/block sizes are never hardcoded here: every dispatch resolves its
launch parameters through :mod:`repro.kernels.tuning` (overrides > committed
per-backend tables > backend heuristics).  Call sites outside
``repro.kernels`` must do the same — pass ``tuner=`` or explicit
``KernelTuner`` overrides, not raw integers (reprolint RL010).
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref, tuning
from .elementwise import (LANES, ddim_fused_pallas, parareal_update_pallas,
                          parareal_update_residual_pallas)
from .flash_attention import flash_attention_bwd, flash_attention_fwd
from .rwkv6_scan import rwkv6_wkv_pallas

FORCE_REF = False

# backends with a compiled Pallas lowering: Mosaic (tpu) and Triton (gpu).
# Everything else runs the kernels interpreted (semantics-validation only).
_COMPILED_BACKENDS = ("tpu", "gpu")


def _interpret() -> bool:
    return jax.default_backend() not in _COMPILED_BACKENDS


def _plat() -> str:
    """Kernel family for the current backend ("gpu" Triton structure vs
    "tpu" grid-carried structure; the latter is also the interpret-mode
    default elsewhere)."""
    return "gpu" if jax.default_backend() == "gpu" else "tpu"


def _resolve(kernel: str, tuner: Optional[tuning.KernelTuner], *,
             dtype=None, shape=None, **explicit) -> tuning.KernelConfig:
    """Resolve a kernel config, treating non-None explicit kwargs as
    overrides (an explicitly passed size always wins and marks the config
    ``source="override"``)."""
    overrides = {k: int(v) for k, v in explicit.items() if v is not None}
    t = tuner if tuner is not None else tuning.get_tuner()
    return t.resolve(kernel, dtype=dtype, shape=shape,
                     overrides=overrides or None)


# backends where the default path needs no warning: tpu/gpu run the
# compiled kernels, cpu is the known interpret-mode test/dev tier
_QUIET_BACKENDS = ("tpu", "gpu", "cpu")
_warned_degraded = False


def fused_default() -> bool:
    """Whether the fused elementwise Pallas path is on by default.

    Capability-driven: True exactly on backends with a *compiled* Pallas
    lowering (``_COMPILED_BACKENDS`` — TPU via Mosaic, GPU via Triton).
    Elsewhere the kernels only exist in ``interpret=True`` mode
    (Python-executed, for semantics validation), which would dominate the
    sampler's runtime, so e.g. CPU defaults to the pure-jnp reference
    path.  ``FORCE_REF`` force-disables the kernels regardless of backend.

    On an accelerator backend with no Pallas lowering (e.g. a plugin
    backend), the silent fallback is a real perf surprise — the deployment
    paid for an accelerator and the fused update quietly runs unfused — so
    the first call emits one structured ``UserWarning`` naming the backend
    and the knobs (``use_fused`` / ``FORCE_REF`` / the
    ``repro.kernels.tuning`` tables that would size a future lowering);
    subsequent calls stay silent.
    """
    backend = jax.default_backend()
    global _warned_degraded
    if not FORCE_REF and backend not in _QUIET_BACKENDS \
            and not _warned_degraded:
        _warned_degraded = True
        warnings.warn(
            f"repro.kernels: fused Pallas elementwise path is OFF by "
            f"default on backend={backend!r} (compiled kernels ship for "
            f"{_COMPILED_BACKENDS}; elsewhere they exist in interpret "
            f"mode, which would dominate runtime) — the pure-jnp "
            f"reference path is used instead.  Pass use_fused=True to "
            f"force the kernels, set repro.kernels.ops.FORCE_REF=True to "
            f"silence this by pinning the reference path, or — once a "
            f"lowering exists for this backend — add it to "
            f"_COMPILED_BACKENDS and commit a "
            f"repro.kernels.tuning table for it.",
            UserWarning, stacklevel=2)
    return (not FORCE_REF) and backend in _COMPILED_BACKENDS


# --------------------------------------------------------------------------
# Flash attention (custom_vjp; Pallas fwd + Pallas bwd)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash(q, k, v, causal, window, scale, block_q, block_k, num_warps,
           num_stages, plat):
    o, _ = flash_attention_fwd(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               num_warps=num_warps, num_stages=num_stages,
                               plat=plat, interpret=_interpret())
    return o


def _flash_fwd(q, k, v, causal, window, scale, block_q, block_k, num_warps,
               num_stages, plat):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 scale=scale, block_q=block_q, block_k=block_k,
                                 num_warps=num_warps, num_stages=num_stages,
                                 plat=plat, interpret=_interpret())
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, scale, block_q, block_k, num_warps, num_stages,
               plat, res, do):
    q, k, v, o, lse = res
    dq, dk_g, dv_g = flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, num_warps=num_warps,
        num_stages=num_stages, plat=plat, interpret=_interpret())
    group = q.shape[0] // k.shape[0]
    if group > 1:  # reduce GQA groups: (BH,...) -> (BKV,...)
        dk_g = dk_g.reshape(k.shape[0], group, *k.shape[1:]).sum(axis=1)
        dv_g = dv_g.reshape(v.shape[0], group, *v.shape[1:]).sum(axis=1)
    return dq, dk_g.astype(k.dtype), dv_g.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None,
              block_q: Optional[int] = None, block_k: Optional[int] = None,
              num_warps: Optional[int] = None,
              num_stages: Optional[int] = None,
              tuner: Optional[tuning.KernelTuner] = None,
              plat: Optional[str] = None,
              use_kernel: Optional[bool] = None):
    """(B, Hq, Sq, D) x (B, Hkv, Sk, D) -> (B, Hq, Sq, D). GQA via Hq%Hkv==0.

    Block sizes resolve through the tuning seam (``tuner`` or the process
    default); explicit ``block_q``/``block_k``/``num_warps``/``num_stages``
    act as overrides.  ``plat`` pins the kernel family (tests exercise the
    Triton-structured kernels on CPU with ``plat="gpu"``); default follows
    the backend.
    """
    if use_kernel is None:
        use_kernel = not FORCE_REF
    if not use_kernel:
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = float(scale) if scale is not None else float(d) ** -0.5
    cfg = _resolve("flash", tuner, dtype=q.dtype, shape=(sq, sk, d),
                   block_q=block_q, block_k=block_k, num_warps=num_warps,
                   num_stages=num_stages)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)
    o = _flash(qf, kf, vf, causal, window, scale,
               cfg.params["block_q"], cfg.params["block_k"],
               cfg.params.get("num_warps"), cfg.params.get("num_stages"),
               plat if plat is not None else _plat())
    return o.reshape(b, hq, sq, d)


# --------------------------------------------------------------------------
# RWKV6 WKV (kernel fwd; ref-autodiff bwd)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _wkv(r, k, v, w, u, s0):
    out, _ = ref.rwkv6_wkv(r, k, v, w, u, s0)
    return out


def _wkv_fwd(r, k, v, w, u, s0):
    return _wkv(r, k, v, w, u, s0), (r, k, v, w, u, s0)


def _wkv_bwd(res, dout):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(lambda *a: ref.rwkv6_wkv(*a)[0], r, k, v, w, u, s0)
    return vjp(dout)


_wkv.defvjp(_wkv_fwd, _wkv_bwd)


def rwkv6_wkv(r, k, v, w, u, state=None, *, chunk: Optional[int] = None,
              tuner: Optional[tuning.KernelTuner] = None,
              plat: Optional[str] = None,
              use_kernel: Optional[bool] = None):
    """r,k,w: (B,H,T,Dk); v: (B,H,T,Dv); u: (H,Dk); state: (B,H,Dk,Dv).

    Returns (out (B,H,T,Dv), final_state).  Kernel forward; reference
    autodiff backward (training uses the pure-JAX chunked path in models).
    The TPU family's chunk size comes from the tuning seam
    (``chunk_target`` capped to a divisor of T); an explicit ``chunk``
    overrides.  The GPU family streams timesteps in-kernel and ignores it.
    """
    bsz, h, t, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((bsz, h, dk, dv), jnp.float32)
    if use_kernel is None:
        use_kernel = not FORCE_REF
    if not use_kernel:
        return ref.rwkv6_wkv(r, k, v, w, u, state)
    if chunk is None:
        cfg = _resolve("rwkv6", tuner, dtype=r.dtype, shape=(t, dk))
        c = tuning.pick_chunk(t, cfg.params["chunk_target"])
    else:
        c = int(chunk)
    flat = lambda x: x.reshape(bsz * h, *x.shape[2:])
    u_t = jnp.tile(u, (bsz, 1))
    out, s_fin = rwkv6_wkv_pallas(flat(r), flat(k), flat(v), flat(w), u_t,
                                  flat(state), chunk=c,
                                  plat=plat if plat is not None else _plat(),
                                  interpret=_interpret())
    return (out.reshape(bsz, h, t, dv),
            s_fin.reshape(bsz, h, dk, dv))


# --------------------------------------------------------------------------
# Fused elementwise ops
# --------------------------------------------------------------------------

def _to_2d(x, row_multiple: int = 1):
    """Flatten/pad to (rows, 128); ``row_multiple`` additionally pads the
    row count to a multiple of the kernel's tile size (zero rows) when it
    exceeds one tile, so a fixed tile size never maps a partial tile past
    the array — compiled Pallas reads of out-of-bounds block regions are
    unspecified (interpret mode zero-fills, masking the bug on CPU), which
    matters whenever per-tile *reductions* are consumed, not just the
    masked elementwise outputs.  (At ``rows <= row_multiple`` the kernels
    shrink the tile to ``rows`` exactly — a single full tile.)"""
    n = x.size
    rows = -(-n // LANES)
    if rows > row_multiple:
        rows += (-rows) % row_multiple
    pad = rows * LANES - n
    flat = x.reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANES), n


def _tile_rows(tuner, dtype, shape, block_rows) -> int:
    cfg = _resolve("elementwise", tuner, dtype=dtype, shape=shape,
                   tile_rows=block_rows)
    return cfg.params["tile_rows"]


def ddim_fused(x, eps, a, b, *, tuner: Optional[tuning.KernelTuner] = None,
               block_rows: Optional[int] = None,
               use_kernel: Optional[bool] = None):
    if use_kernel is None:
        use_kernel = not FORCE_REF
    if not use_kernel:
        return ref.ddim_fused(x, eps, a, b)
    tr = _tile_rows(tuner, x.dtype, x.shape, block_rows)
    x2, n = _to_2d(x)
    e2, _ = _to_2d(eps)
    ab = jnp.stack([jnp.asarray(a, jnp.float32),
                    jnp.asarray(b, jnp.float32)]).reshape(1, 2)
    o = ddim_fused_pallas(x2, e2, ab, block_rows=tr, interpret=_interpret())
    return o.reshape(-1)[:n].reshape(x.shape)


def parareal_update(y, cur, prev, *,
                    tuner: Optional[tuning.KernelTuner] = None,
                    block_rows: Optional[int] = None,
                    use_kernel: Optional[bool] = None):
    """Returns (y + cur - prev, sum|cur - prev|) fused in one pass."""
    if use_kernel is None:
        use_kernel = not FORCE_REF
    if not use_kernel:
        return ref.parareal_update(y, cur, prev)
    # pad rows to the resolved tile size: the L1 partials are consumed, so
    # the last tile must not read past the array (see _to_2d)
    tr = _tile_rows(tuner, y.dtype, y.shape, block_rows)
    y2, n = _to_2d(y, row_multiple=tr)
    c2, _ = _to_2d(cur, row_multiple=tr)
    p2, _ = _to_2d(prev, row_multiple=tr)
    o, partials = parareal_update_pallas(y2, c2, p2, block_rows=tr,
                                         interpret=_interpret())
    return o.reshape(-1)[:n].reshape(y.shape), jnp.sum(partials)


def _to_2d_per_sample(x):
    """(K, ...) -> (K * rows_per_sample, 128) with per-sample padding, so
    row tiles never straddle two samples and per-tile partials regroup into
    per-sample sums.  Returns (x2d, rows_per_sample, per_sample_size)."""
    k = x.shape[0]
    n = x.size // k
    rows = -(-n // LANES)
    pad = rows * LANES - n
    flat = x.reshape(k, n)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(k * rows, LANES), rows, n


def parareal_update_residual(y, cur, prev, old, *, batched: bool = False,
                             batch_dims: Optional[int] = None,
                             tuner: Optional[tuning.KernelTuner] = None,
                             block_rows: Optional[int] = None,
                             use_kernel: Optional[bool] = None):
    """Fused predictor-corrector update + convergence-residual partials.

    Returns ``(y + cur - prev, sum|out - old|)`` in one pass — ``old`` is
    the block's previous trajectory value, so the second output is exactly
    the raw L1 sum behind the engine's ``l1_mean`` convergence norm (the
    kernel's per-tile partials feed it directly; no second full-tensor
    reduction).  ``batch_dims`` picks the residual's reduction shape — the
    number of leading axes preserved: 0 -> scalar, 1 -> per-sample ``(K,)``
    (legacy spelling ``batched=True``), 2 -> per-block per-sample
    ``(B, K)``, the sliding-window frontier feed (each leading-axes slice
    gets its own tile rows, so partials never straddle two slices).
    Tile rows resolve through the tuning seam; ``block_rows`` overrides
    (per-sample paths still cap it to a divisor of the sample row count).
    """
    if use_kernel is None:
        use_kernel = not FORCE_REF
    if not use_kernel:
        return ref.parareal_update_residual(y, cur, prev, old,
                                            batched=batched,
                                            batch_dims=batch_dims)
    nd = (1 if batched else 0) if batch_dims is None else int(batch_dims)
    if not 0 <= nd < y.ndim + 1:
        raise ValueError(f"batch_dims={nd} out of range for ndim={y.ndim}")
    if nd >= 2:
        # flatten the preserved leading axes into one pseudo-sample axis,
        # run the per-sample path, and restore the leading shape on the
        # partials — each (block, sample) slice keeps its own padded rows
        lead = y.shape[:nd]
        flat = lambda t: t.reshape((-1,) + t.shape[nd:])
        out, resid = parareal_update_residual(
            flat(y), flat(cur), flat(prev), flat(old), batch_dims=1,
            tuner=tuner, block_rows=block_rows, use_kernel=True)
        return out.reshape(y.shape), resid.reshape(lead)
    tr = _tile_rows(tuner, y.dtype, y.shape, block_rows)
    if nd == 0:
        # pad rows to the tile size so the consumed partials never cover
        # an out-of-bounds block region on compiled backends (zero rows
        # contribute |0 + 0 - 0 - 0| = 0 to the L1 sums)
        y2, n = _to_2d(y, row_multiple=tr)
        c2, _ = _to_2d(cur, row_multiple=tr)
        p2, _ = _to_2d(prev, row_multiple=tr)
        x2, _ = _to_2d(old, row_multiple=tr)
        o, partials = parareal_update_residual_pallas(
            y2, c2, p2, x2, block_rows=tr, interpret=_interpret())
        return o.reshape(-1)[:n].reshape(y.shape), jnp.sum(partials)
    k = y.shape[0]
    y2, rows, n = _to_2d_per_sample(y)
    c2, _, _ = _to_2d_per_sample(cur)
    p2, _, _ = _to_2d_per_sample(prev)
    x2, _, _ = _to_2d_per_sample(old)
    br = tuning.sample_tile_rows(rows, tr)
    o, partials = parareal_update_residual_pallas(
        y2, c2, p2, x2, block_rows=br, interpret=_interpret())
    resid = partials.reshape(k, rows // br).sum(axis=1)
    out = o.reshape(k, rows * LANES)[:, :n].reshape(y.shape)
    return out, resid
