"""Kernel block/tile autotuning seam: one home for every launch-shape knob.

Before this module existed, every Pallas kernel carried hardcoded tile
sizes (``TILE_ROWS = 256``, ``block_q = block_k = 128``, ``chunk = 32``)
that were never tuned for any backend.  The seam replaces those literals
with a three-tier resolution, keyed by ``(backend, kernel, dtype,
shape-bucket)``:

1. **explicit overrides** — a call site (or test) pins parameters via
   ``KernelTuner(overrides=...)`` / ``resolve(..., overrides=...)``;
2. **committed tuning tables** — versioned JSON under
   ``tuning_tables/<backend>.json``, written/refreshed by the measured
   sweep in ``benchmarks/autotune_kernels.py``;
3. **backend-aware heuristics** — the documented defaults (yesterday's
   constants become the CPU/interpret anchors; GPU gets Triton-sized
   tiles), used for any key the table does not cover.

``kernels.ops`` dispatch consults this module instead of literal
defaults; call sites outside ``repro.kernels`` must not pass raw tile
integers (reprolint RL010 ``kernel-tile-literals``) — they pass a
``tuner=`` or let dispatch resolve.  See docs/kernels.md for the
contract and the table-refresh procedure.

Tuned parameters per kernel family:

========== =============================== ==============================
kernel     parameters                      tuning shape (bucket basis)
========== =============================== ==============================
elementwise ``tile_rows``                  operand shape -> (total size,)
flash       ``block_q``, ``block_k`` (+    ``(sq, sk, head_dim)``
            ``num_warps``, ``num_stages``
            on the Triton lowering)
rwkv6       ``chunk_target`` (TPU chunked  ``(t, dk)``
            grid; the GPU kernel streams
            timesteps and ignores it)
========== =============================== ==============================

Buckets round every dimension up to the next power of two, so a handful
of table entries covers a continuum of shapes; a miss falls back to the
heuristic tier (never an error).  A *malformed* table, by contrast,
fails loudly (:class:`TuningTableError`) — a silently ignored table is
how a tuned deployment quietly runs default sizes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Mapping, Optional, Sequence, Tuple

from .elementwise import TILE_ROWS

__all__ = [
    "KernelConfig", "KernelTuner", "TuningTableError", "TABLE_SCHEMA_VERSION",
    "TABLE_DIR", "KERNELS", "bucket_for", "next_pow2", "get_tuner",
    "set_tuner", "resolve", "pick_chunk", "sample_tile_rows",
    "validate_table",
]

TABLE_SCHEMA_VERSION = 1
TABLE_DIR = os.path.join(os.path.dirname(__file__), "tuning_tables")
KERNELS = ("elementwise", "flash", "rwkv6")
_SOURCES = ("override", "table", "heuristic")

# Backend-aware heuristic defaults — tier (3).  The ``None`` row is the
# fallback for CPU/interpret and any unknown backend: it carries the
# constants the kernels shipped with (elementwise.TILE_ROWS, the MXU-sized
# 128x128 flash tiles, the chunk=32 WKV grid), which stay the documented
# interpret-mode anchors.  The GPU row is Triton-sized: a (256, 128) f32
# elementwise tile is 128 KiB — past shared-memory budgets — so row tiles
# shrink; flash tiles drop to 64x64 with explicit warp/stage counts.
_HEURISTICS: Dict[str, Dict[Optional[str], Dict[str, int]]] = {
    "elementwise": {
        "tpu": {"tile_rows": TILE_ROWS},
        "gpu": {"tile_rows": 32},
        None: {"tile_rows": TILE_ROWS},
    },
    "flash": {
        "tpu": {"block_q": 128, "block_k": 128},
        "gpu": {"block_q": 64, "block_k": 64, "num_warps": 4,
                "num_stages": 2},
        None: {"block_q": 128, "block_k": 128},
    },
    "rwkv6": {
        "tpu": {"chunk_target": 32},
        "gpu": {"chunk_target": 32},
        None: {"chunk_target": 32},
    },
}


class TuningTableError(ValueError):
    """A tuning table failed validation — raised loudly, never skipped."""


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """A resolved kernel launch configuration.

    ``source`` records provenance for benchmarking/CI: ``"override"``
    (an explicit parameter won), ``"table"`` (a committed tuning-table
    entry matched the full key) or ``"heuristic"`` (backend-aware
    default).  ``key`` is the ``(backend, kernel, dtype, bucket)``
    lookup that produced it.
    """
    kernel: str
    params: Mapping[str, int]
    source: str
    key: Tuple[str, str, str, Tuple[int, ...]]


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (>=1)."""
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def bucket_for(kernel: str, shape: Optional[Sequence[int]]) -> Tuple[int, ...]:
    """Shape bucket for a kernel's tuning shape (see module docstring).

    ``elementwise`` buckets on total element count (the op flattens);
    the others bucket per dimension.  ``None`` -> the empty bucket
    (matches only entries with ``"bucket": []``, i.e. shape-agnostic).
    """
    if shape is None:
        return ()
    dims = [int(d) for d in shape]
    if kernel == "elementwise":
        total = 1
        for d in dims:
            total *= max(1, d)
        return (next_pow2(total),)
    return tuple(next_pow2(d) for d in dims)


def _largest_divisor(n: int, cap: int) -> int:
    for c in range(min(int(cap), int(n)), 0, -1):
        if n % c == 0:
            return c
    return 1


def pick_chunk(t: int, cap: int = 32) -> int:
    """Largest divisor of the sequence length ``t`` not exceeding ``cap``
    (the chunked WKV grid needs ``t % chunk == 0``).  ``cap`` comes from
    the resolved ``rwkv6`` config's ``chunk_target``."""
    return _largest_divisor(t, cap)


def sample_tile_rows(rows: int, cap: int) -> int:
    """Largest divisor of the per-sample row count not exceeding ``cap``
    (tile rows must divide ``rows`` so per-tile reduction partials stay
    sample-local).  ``cap`` comes from the resolved ``elementwise``
    config's ``tile_rows``."""
    return _largest_divisor(rows, cap)


def validate_table(obj, path: str = "<table>") -> dict:
    """Validate a tuning-table payload; returns it or raises loudly."""
    def bad(msg):
        raise TuningTableError(f"tuning table {path}: {msg}")

    if not isinstance(obj, dict):
        bad(f"top level must be an object, got {type(obj).__name__}")
    if obj.get("version") != TABLE_SCHEMA_VERSION:
        bad(f"version must be {TABLE_SCHEMA_VERSION}, "
            f"got {obj.get('version')!r} (refresh the table with "
            f"benchmarks.autotune_kernels)")
    if not isinstance(obj.get("backend"), str):
        bad("missing/non-string 'backend'")
    entries = obj.get("entries")
    if not isinstance(entries, list):
        bad("'entries' must be a list")
    for i, e in enumerate(entries):
        where = f"entries[{i}]"
        if not isinstance(e, dict):
            bad(f"{where} must be an object")
        if e.get("kernel") not in KERNELS:
            bad(f"{where}: unknown kernel {e.get('kernel')!r} "
                f"(known: {KERNELS})")
        if not isinstance(e.get("dtype"), str):
            bad(f"{where}: missing/non-string 'dtype'")
        bucket = e.get("bucket")
        if not isinstance(bucket, list) or not all(
                isinstance(b, int) and not isinstance(b, bool) and b > 0
                for b in bucket):
            bad(f"{where}: 'bucket' must be a list of positive ints")
        params = e.get("params")
        if not isinstance(params, dict) or not params or not all(
                isinstance(k, str) and isinstance(v, int)
                and not isinstance(v, bool) and v > 0
                for k, v in params.items()):
            bad(f"{where}: 'params' must be a non-empty "
                f"{{name: positive int}} object")
    return obj


def _dtype_name(dtype) -> str:
    if dtype is None:
        return "float32"
    if isinstance(dtype, str):
        return dtype
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


class KernelTuner:
    """Resolves kernel launch parameters from overrides > tables > heuristics.

    Args:
      table_dir: directory of per-backend ``<backend>.json`` tables
        (default: the committed ``tuning_tables/``).  A missing file is
        a valid empty table; a malformed file raises
        :class:`TuningTableError` at first resolve for that backend.
      tables: pre-built ``{backend: payload}`` tables (validated here),
        taking precedence over ``table_dir`` files — the in-memory path
        used by tests and the autotune sweep's self-check.
      overrides: ``{kernel: {param: int}}`` pinned parameters applied on
        top of whatever the table/heuristic tier resolves.
    """

    def __init__(self, table_dir: Optional[str] = None,
                 tables: Optional[Mapping[str, dict]] = None,
                 overrides: Optional[Mapping[str, Mapping[str, int]]] = None):
        self.table_dir = TABLE_DIR if table_dir is None else table_dir
        self.overrides = {k: dict(v) for k, v in (overrides or {}).items()}
        self._tables: Dict[str, Optional[dict]] = {}
        for backend, payload in (tables or {}).items():
            self._tables[backend] = validate_table(
                payload, f"<tables[{backend!r}]>")

    def _table(self, backend: str) -> Optional[dict]:
        if backend not in self._tables:
            path = os.path.join(self.table_dir, f"{backend}.json")
            if not os.path.exists(path):
                self._tables[backend] = None
            else:
                try:
                    with open(path) as f:
                        payload = json.load(f)
                except (OSError, json.JSONDecodeError) as e:
                    raise TuningTableError(
                        f"tuning table {path}: unreadable/invalid JSON "
                        f"({e})") from e
                self._tables[backend] = validate_table(payload, path)
        return self._tables[backend]

    def _lookup(self, backend: str, kernel: str, dtype: str,
                bucket: Tuple[int, ...]) -> Optional[Dict[str, int]]:
        table = self._table(backend)
        if table is None:
            return None
        for e in table["entries"]:
            if (e["kernel"] == kernel and e["dtype"] == dtype
                    and tuple(e["bucket"]) == bucket):
                return dict(e["params"])
        return None

    def resolve(self, kernel: str, *, backend: Optional[str] = None,
                dtype=None, shape: Optional[Sequence[int]] = None,
                overrides: Optional[Mapping[str, int]] = None) -> KernelConfig:
        """Resolve launch parameters for ``kernel``.

        ``backend=None`` probes ``jax.default_backend()``; ``shape`` is
        the kernel's tuning shape (see module docstring), bucketed
        before lookup.  An unknown ``(dtype, bucket)`` key falls back to
        the backend heuristics; overrides (instance-level, then
        call-level) always win and mark the config ``source="override"``.
        """
        if kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r} (known: {KERNELS})")
        if backend is None:
            import jax
            backend = jax.default_backend()
        dt = _dtype_name(dtype)
        bucket = bucket_for(kernel, shape)
        heur = _HEURISTICS[kernel]
        params = dict(heur.get(backend) or heur[None])
        source = "heuristic"
        from_table = self._lookup(backend, kernel, dt, bucket)
        if from_table is not None:
            params.update(from_table)
            source = "table"
        pinned = dict(self.overrides.get(kernel) or {})
        pinned.update(overrides or {})
        if pinned:
            params.update(pinned)
            source = "override"
        return KernelConfig(kernel=kernel, params=params, source=source,
                            key=(backend, kernel, dt, bucket))


_DEFAULT_TUNER: Optional[KernelTuner] = None


def get_tuner() -> KernelTuner:
    """The process-default tuner (committed tables + heuristics)."""
    global _DEFAULT_TUNER
    if _DEFAULT_TUNER is None:
        _DEFAULT_TUNER = KernelTuner()
    return _DEFAULT_TUNER


def set_tuner(tuner: Optional[KernelTuner]) -> None:
    """Install (or with ``None`` reset) the process-default tuner."""
    global _DEFAULT_TUNER
    _DEFAULT_TUNER = tuner


def resolve(kernel: str, **kwargs) -> KernelConfig:
    """``get_tuner().resolve(...)`` convenience."""
    return get_tuner().resolve(kernel, **kwargs)
