"""Pallas kernels for SRDS hot spots (validated in interpret mode).

flash_attention: backbone attention (fwd+bwd, causal/SWA/GQA; TPU + GPU
                 kernel families)
rwkv6_scan:      RWKV6 WKV recurrence (TPU chunked / GPU streaming)
elementwise:     fused DDIM step + fused Parareal update/residual
                 (lowering-portable)
ops:             jit-ready dispatch wrappers;  ref: pure-jnp oracles
tuning:          block/tile autotuning seam (overrides > committed
                 per-backend tables > heuristics)
"""
from . import ops, ref, tuning

__all__ = ["ops", "ref", "tuning"]
