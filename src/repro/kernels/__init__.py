"""Pallas TPU kernels for SRDS hot spots (validated in interpret mode).

flash_attention: backbone attention (fwd+bwd, causal/SWA/GQA)
rwkv6_scan:      RWKV6 WKV recurrence (chunked, VMEM-resident state)
elementwise:     fused DDIM step + fused Parareal update/residual
ops:             jit-ready dispatch wrappers;  ref: pure-jnp oracles
"""
from . import ops, ref

__all__ = ["ops", "ref"]
