"""RWKV6 (Finch) WKV recurrence — Pallas kernels (TPU chunked + GPU).

Chunked TPU design: grid = (batch*heads, T/chunk); the (Dk, Dv) recurrent
state lives in VMEM scratch and is carried across the sequential chunk axis
(TPU grids execute the innermost axis in order — the state never
round-trips to HBM between chunks, unlike a naive scan over pallas_calls).

The GPU (Triton) variant cannot carry scratch across grid steps (grid
cells are concurrent CUDA blocks), so its grid is (batch*heads,) and one
``lax.fori_loop`` streams all T timesteps with the (Dk, Dv) state as the
loop carry (registers); rows are cut/written with ``pl.load``/``pl.store``.
The chunk size is therefore a TPU-only tuning knob — the GPU kernel's
state residency does not depend on it.

Inside a chunk the recurrence is evaluated with an in-kernel ``lax.scan``
over timesteps (matvec per step).  We deliberately chose the *sequential*
intra-chunk form over the parallel "chunked linear attention" form: RWKV6's
data-dependent decays make the parallel form's decay-ratio factors
``exp(cumlog[t] - cumlog[i])`` overflow fp32 for strongly-decaying channels
(the reason fla-style GPU kernels need secondary renormalization).  With
head dims of 64, the per-step matvec (64x64) is VPU work either way and the
kernel stays memory-bound on r/k/v/w streaming — which the chunked state
residency addresses.  (See EXPERIMENTS.md §Perf for the measurement.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state, *, chunk, dk, dv):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)        # (C, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # (Dk,)
    dec = jnp.exp(-jnp.exp(w))              # (C, Dk)

    def step(s, inp):
        r_t, k_t, v_t, dec_t = inp
        a = k_t[:, None] * v_t[None, :]                       # (Dk, Dv)
        out = (r_t[None, :] @ (s + u[:, None] * a))[0]        # (Dv,)
        s = dec_t[:, None] * s + a
        return s, out

    s_fin, outs = jax.lax.scan(step, state[...], (r, k, v, dec))
    o_ref[0] = outs.astype(o_ref.dtype)
    state[...] = s_fin

    @pl.when(c == nc - 1)
    def _final():
        sT_ref[0] = s_fin.astype(sT_ref.dtype)


def _wkv_kernel_gpu(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref,
                    sT_ref, *, t):
    u = u_ref[...].astype(jnp.float32)            # (Dk,)

    def step(ti, s):
        row = lambda ref: pl.load(
            ref, (pl.dslice(ti, 1), slice(None)))[0].astype(jnp.float32)
        r_t, k_t, v_t = row(r_ref), row(k_ref), row(v_ref)
        dec_t = jnp.exp(-jnp.exp(row(w_ref)))
        a = k_t[:, None] * v_t[None, :]                       # (Dk, Dv)
        out = (r_t[None, :] @ (s + u[:, None] * a))[0]        # (Dv,)
        pl.store(o_ref, (pl.dslice(ti, 1), slice(None)),
                 out[None, :].astype(o_ref.dtype))
        return dec_t[:, None] * s + a

    s_fin = jax.lax.fori_loop(0, t, step, s0_ref[...].astype(jnp.float32))
    sT_ref[...] = s_fin.astype(sT_ref.dtype)


def _rwkv6_wkv_gpu(r, k, v, w, u, s0, *, interpret):
    bh, t, dk = r.shape
    dv = v.shape[-1]
    kern = functools.partial(_wkv_kernel_gpu, t=t)
    return pl.pallas_call(
        kern,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((None, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, t, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, dk), lambda b: (b, 0)),
            pl.BlockSpec((None, dk, dv), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, t, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, dk, dv), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        interpret=interpret,
        name="srds_rwkv6_wkv_gpu",
    )(r, k, v, w, u, s0)


def rwkv6_wkv_pallas(r, k, v, w, u, s0, *, chunk=32, plat="tpu",
                     interpret=False):
    """r,k,w: (BH, T, Dk); v: (BH, T, Dv); u: (BH, Dk); s0: (BH, Dk, Dv).

    Returns (out (BH, T, Dv), final_state (BH, Dk, Dv)).  On the TPU
    family ``T % chunk == 0`` (the ops wrapper picks a divisor via the
    tuning seam); the GPU family streams all T steps in-kernel and
    ignores ``chunk``.
    """
    if plat == "gpu":
        return _rwkv6_wkv_gpu(r, k, v, w, u, s0, interpret=interpret)
    bh, t, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    grid = (bh, t // chunk)
    kern = functools.partial(_wkv_kernel, chunk=chunk, dk=dk, dv=dv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="srds_rwkv6_wkv",
    )(r, k, v, w, u, s0)
