"""Flash attention Pallas-TPU kernels (forward + backward).

TPU-native design decisions (vs a CUDA port):
  * online-softmax accumulators live in VMEM scratch and are carried across
    the *innermost sequential grid dimension* (TPU grids iterate the last
    axis sequentially per core — the idiomatic replacement for a CUDA
    thread-block loop over KV tiles);
  * tiles default to (128, 128): the MXU systolic array is 128x128, and the
    lane dimension (head_dim) should be a multiple of 128 for full MXU
    utilization — the ops wrapper pads head_dim when needed;
  * GQA is handled in the BlockSpec index_map (kv head = q head // group),
    so grouped KV is never materialized/repeated in HBM;
  * causal and sliding-window masking skip fully-masked KV tiles with
    ``pl.when`` (no MXU work issued for skipped tiles).

Forward saves the per-row logsumexp; backward recomputes probabilities
tile-by-tile (two kernels: dQ over KV tiles; dK/dV over Q tiles).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _row_valid(bsz, start, limit):
    """(bsz, 1) bool mask for ragged-tile padding rows."""
    idx = start + jax.lax.broadcasted_iota(jnp.int32, (bsz, 1), 0)
    return idx < limit


def _clean(x, valid):
    """Zero padded rows with where (interpret mode poisons OOB reads with
    NaN, and NaN * 0 == NaN — multiplication cannot scrub them)."""
    return jnp.where(valid, x, 0.0)


def _mask(bq, bk, iq, ik, sq, sk, causal, window):
    """Boolean keep-mask for a (bq, bk) tile; positions right-aligned.

    Also masks ragged-tile padding rows/cols (q >= sq or k >= sk)."""
    qraw = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    qpos = qraw + (sk - sq)
    keep = jnp.logical_and(qraw < sq, kpos < sk)
    if causal:
        keep = jnp.logical_and(keep, kpos <= qpos)
    if window is not None:
        keep = jnp.logical_and(keep, kpos > qpos - window)
    return keep


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_sc, l_sc, *,
                scale, causal, window, sq, sk, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    # tile skipping: causal / sliding-window tiles with no live entry
    q_last = iq * bq + bq - 1 + (sk - sq)
    k_first = ik * bk
    live = True
    if causal:
        live = k_first <= q_last
    if window is not None:
        q_first = iq * bq + (sk - sq)
        k_last = ik * bk + bk - 1
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), _row_valid(bq, iq * bq, sq))
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # guard fully-masked rows: m_new == NEG_INF would give exp(0) == 1
        p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0
        o_ref[0] = (acc[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_sc[...] + jnp.log(l_safe)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, scale=None,
                        block_q=128, block_k=128, interpret=False):
    """q: (BH, Sq, D) already flattened over batch*q_heads; k/v: (BKV, Sk, D).

    ``group = BH // BKV`` kv-sharing factor (GQA) resolved via index_map.
    Returns (o (BH, Sq, D), lse (BH, Sq)).
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    scale = float(scale) if scale is not None else float(d) ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    grid = (bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk))

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_fwd",
    )(q, k, v)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, scale, causal, window, sq, sk, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_last = iq * bq + bq - 1 + (sk - sq)
    live = (ik * bk <= q_last) if causal else True
    if window is not None:
        q_first = iq * bq + (sk - sq)
        live = jnp.logical_and(live, ik * bk + bk - 1 > q_first - window)

    @pl.when(live)
    def _compute():
        q_valid = _row_valid(bq, iq * bq, sq)
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), q_valid)
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        do = _clean(do_ref[0].astype(jnp.float32), q_valid)
        lse = jnp.where(q_valid[:, 0], lse_ref[0], 0.0)
        delta = jnp.where(q_valid[:, 0], delta_ref[0], 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        dq_acc[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, sq, sk, bq, bk):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_last = iq * bq + bq - 1 + (sk - sq)
    live = (ik * bk <= q_last) if causal else True
    if window is not None:
        q_first = iq * bq + (sk - sq)
        live = jnp.logical_and(live, ik * bk + bk - 1 > q_first - window)

    @pl.when(live)
    def _compute():
        q_valid = _row_valid(bq, iq * bq, sq)
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), q_valid)
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        do = _clean(do_ref[0].astype(jnp.float32), q_valid)
        lse = jnp.where(q_valid[:, 0], lse_ref[0], 0.0)
        delta = jnp.where(q_valid[:, 0], delta_ref[0], 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=None,
                        scale=None, block_q=128, block_k=128, interpret=False):
    """Returns (dq (BH,Sq,D), dk_g (BH,Sk,D), dv_g (BH,Sk,D)).

    dk/dv are produced per *q-head* (GQA groups not yet reduced); the ops
    wrapper sums over the group dimension — keeping the kernel free of
    cross-grid-cell reductions.
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    scale = float(scale) if scale is not None else float(d) ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    kq = functools.partial(_dq_kernel, scale=scale, causal=causal,
                           window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    dq = pl.pallas_call(
        kq,
        grid=(bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk)),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_dq",
    )(q, k, v, do, lse, delta)

    kkv = functools.partial(_dkv_kernel, scale=scale, causal=causal,
                            window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    dk, dv = pl.pallas_call(
        kkv,
        grid=(bh, pl.cdiv(sk, bk), pl.cdiv(sq, bq)),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, ik, iq: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, ik, iq: (b, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_dkv",
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
