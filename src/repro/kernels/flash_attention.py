"""Flash attention Pallas kernels (forward + backward), TPU and GPU.

Two kernel families share the math (same ``_mask`` geometry, same
online-softmax update, same ragged-row hygiene) but differ in how the KV
reduction is structured, because the two lowerings disagree about grid
semantics:

TPU (Mosaic) family — ``_fwd_kernel`` / ``_dq_kernel`` / ``_dkv_kernel``:
  * online-softmax accumulators live in VMEM scratch and are carried across
    the *innermost sequential grid dimension* (TPU grids iterate the last
    axis sequentially per core — the idiomatic replacement for a CUDA
    thread-block loop over KV tiles);
  * tiles default to (128, 128): the MXU systolic array is 128x128, and the
    lane dimension (head_dim) should be a multiple of 128 for full MXU
    utilization — the ops wrapper pads head_dim when needed;
  * causal and sliding-window masking skip fully-masked KV tiles with
    ``pl.when`` (no MXU work issued for skipped tiles).

GPU (Triton) family — ``_fwd_kernel_gpu`` / ``_dq_kernel_gpu`` /
``_dkv_kernel_gpu``:
  * Triton grid cells are concurrent CUDA blocks — nothing carries across
    grid steps, so the reduction axis moves *inside* the kernel: grid is
    (batch*heads, q-tiles) and each program walks its live KV tiles with a
    ``lax.fori_loop`` whose accumulators are loop carries (registers);
  * the reduced operand arrives as one whole (padded) ref and tiles are
    cut with ``pl.load``/``pl.dslice``; the wrappers zero-pad the walked
    axis to a tile multiple while masks keep using the true lengths;
  * tile skipping becomes loop *bounds*: the causal/window live-tile
    predicates solved for the loop variable give [lo, hi) directly, so
    masked tiles are never visited at all;
  * ``num_warps``/``num_stages`` (tuning-seam params) reach Triton via
    ``compat.gpu_compiler_params``.

Both families are exercised in ``interpret=True`` mode on CPU (the parity
suite); tile sizes come from :mod:`repro.kernels.tuning`.

GQA is handled in the BlockSpec index_map (kv head = q head // group), so
grouped KV is never materialized/repeated in HBM — both families.

Forward saves the per-row logsumexp; backward recomputes probabilities
tile-by-tile (two kernels: dQ over KV tiles; dK/dV over Q tiles).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _row_valid(bsz, start, limit):
    """(bsz, 1) bool mask for ragged-tile padding rows."""
    idx = start + jax.lax.broadcasted_iota(jnp.int32, (bsz, 1), 0)
    return idx < limit


def _clean(x, valid):
    """Zero padded rows with where (interpret mode poisons OOB reads with
    NaN, and NaN * 0 == NaN — multiplication cannot scrub them)."""
    return jnp.where(valid, x, 0.0)


def _mask(bq, bk, iq, ik, sq, sk, causal, window):
    """Boolean keep-mask for a (bq, bk) tile; positions right-aligned.

    Also masks ragged-tile padding rows/cols (q >= sq or k >= sk)."""
    qraw = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    qpos = qraw + (sk - sq)
    keep = jnp.logical_and(qraw < sq, kpos < sk)
    if causal:
        keep = jnp.logical_and(keep, kpos <= qpos)
    if window is not None:
        keep = jnp.logical_and(keep, kpos > qpos - window)
    return keep


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_sc, l_sc, *,
                scale, causal, window, sq, sk, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    # tile skipping: causal / sliding-window tiles with no live entry
    q_last = iq * bq + bq - 1 + (sk - sq)
    k_first = ik * bk
    live = True
    if causal:
        live = k_first <= q_last
    if window is not None:
        q_first = iq * bq + (sk - sq)
        k_last = ik * bk + bk - 1
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), _row_valid(bq, iq * bq, sq))
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # guard fully-masked rows: m_new == NEG_INF would give exp(0) == 1
        p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0
        o_ref[0] = (acc[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_sc[...] + jnp.log(l_safe)


# --------------------------------------------------------------------------
# GPU (Triton) family: reduction axis inside the kernel, carries in registers
# --------------------------------------------------------------------------

def _pad_axis(x, axis, multiple):
    """Zero-pad ``x`` along ``axis`` to a multiple of ``multiple``."""
    n = x.shape[axis]
    pad = (-n) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _kv_bounds(iq, *, causal, window, sq, sk, bq, bk, nk):
    """[lo, hi) of live KV tiles for q-tile ``iq`` (loop-bound form of the
    TPU kernels' ``pl.when`` live predicates; positions right-aligned)."""
    q_last = iq * bq + bq - 1 + (sk - sq)
    hi = nk
    if causal:
        hi = jnp.clip(q_last // bk + 1, 0, nk)
    lo = 0
    if window is not None:
        q_first = iq * bq + (sk - sq)
        lo = jnp.maximum(0, (q_first - window + 1) // bk)
    return lo, hi


def _q_bounds(ik, *, causal, window, sq, sk, bq, bk, nq):
    """[lo, hi) of live Q tiles for kv-tile ``ik`` (the dK/dV loop)."""
    lo = 0
    if causal:
        lo = jnp.maximum(0, (ik * bk - (sk - sq)) // bq)
    hi = nq
    if window is not None:
        x = ik * bk + bk - 1 + window - (sk - sq)
        hi = jnp.clip((x + bq - 1) // bq, 0, nq)
    return lo, hi


def _load_tile(refp, start, size):
    """(size, D) f32 tile cut from a whole-axis 2D ref at row ``start``."""
    return pl.load(refp, (pl.dslice(start, size), slice(None))).astype(
        jnp.float32)


def _fwd_kernel_gpu(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                    scale, causal, window, sq, sk, bq, bk, nk):
    iq = pl.program_id(1)
    q = _clean(q_ref[...].astype(jnp.float32), _row_valid(bq, iq * bq, sq))
    d = q.shape[-1]
    lo, hi = _kv_bounds(iq, causal=causal, window=window, sq=sq, sk=sk,
                        bq=bq, bk=bk, nk=nk)

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        kv_valid = _row_valid(bk, ik * bk, sk)
        k = _clean(_load_tile(k_ref, ik * bk, bk), kv_valid)
        v = _clean(_load_tile(v_ref, ik * bk, bk), kv_valid)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # guard fully-masked rows: m_new == NEG_INF would give exp(0) == 1
        p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc, m, l = jax.lax.fori_loop(
        lo, hi, body, (jnp.zeros((bq, d), jnp.float32),
                       jnp.full((bq,), NEG_INF, jnp.float32),
                       jnp.zeros((bq,), jnp.float32)))
    l_safe = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(l_safe)


def _flash_fwd_gpu(q, k, v, *, causal, window, scale, bq, bk,
                   compiler_params, interpret):
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    # the in-kernel loop cuts KV tiles with pl.dslice: pad the walked axis
    # to a tile multiple (masks keep using the true sk)
    kp = _pad_axis(k, 1, bk)
    vp = _pad_axis(v, 1, bk)
    skp = kp.shape[1]
    nk = skp // bk
    kernel = functools.partial(_fwd_kernel_gpu, scale=scale, causal=causal,
                               window=window, sq=sq, sk=sk, bq=bq, bk=bk,
                               nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, pl.cdiv(sq, bq)),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, iq: (b, iq, 0)),
            pl.BlockSpec((None, skp, d), lambda b, iq, g=group: (b // g, 0, 0)),
            pl.BlockSpec((None, skp, d), lambda b, iq, g=group: (b // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda b, iq: (b, iq, 0)),
            pl.BlockSpec((None, bq), lambda b, iq: (b, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
        name="srds_flash_fwd_gpu",
    )(q, kp, vp)


def _dq_kernel_gpu(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
                   scale, causal, window, sq, sk, bq, bk, nk):
    iq = pl.program_id(1)
    q_valid = _row_valid(bq, iq * bq, sq)
    q = _clean(q_ref[...].astype(jnp.float32), q_valid)
    do = _clean(do_ref[...].astype(jnp.float32), q_valid)
    lse = jnp.where(q_valid[:, 0], lse_ref[...], 0.0)
    delta = jnp.where(q_valid[:, 0], delta_ref[...], 0.0)
    d = q.shape[-1]
    lo, hi = _kv_bounds(iq, causal=causal, window=window, sq=sq, sk=sk,
                        bq=bq, bk=bk, nk=nk)

    def body(ik, dq_acc):
        kv_valid = _row_valid(bk, ik * bk, sk)
        k = _clean(_load_tile(k_ref, ik * bk, bk), kv_valid)
        v = _clean(_load_tile(v_ref, ik * bk, bk), kv_valid)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        p = jnp.where(keep, jnp.exp(jnp.where(keep, s, NEG_INF)
                                    - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        return dq_acc + jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(lo, hi, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _dkv_kernel_gpu(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, scale, causal, window, sq, sk,
                    bq, bk, nq):
    ik = pl.program_id(1)
    kv_valid = _row_valid(bk, ik * bk, sk)
    k = _clean(k_ref[...].astype(jnp.float32), kv_valid)
    v = _clean(v_ref[...].astype(jnp.float32), kv_valid)
    d = k.shape[-1]
    lo, hi = _q_bounds(ik, causal=causal, window=window, sq=sq, sk=sk,
                       bq=bq, bk=bk, nq=nq)

    def body(iq, carry):
        dk_acc, dv_acc = carry
        q_valid = _row_valid(bq, iq * bq, sq)
        q = _clean(_load_tile(q_ref, iq * bq, bq), q_valid)
        do = _clean(_load_tile(do_ref, iq * bq, bq), q_valid)
        lse = jnp.where(q_valid[:, 0],
                        pl.load(lse_ref, (pl.dslice(iq * bq, bq),)), 0.0)
        delta = jnp.where(q_valid[:, 0],
                          pl.load(delta_ref, (pl.dslice(iq * bq, bq),)), 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        p = jnp.where(keep, jnp.exp(jnp.where(keep, s, NEG_INF)
                                    - lse[:, None]), 0.0)       # (bq, bk)
        dv_acc = dv_acc + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        dk_acc = dk_acc + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_acc, dv_acc

    dk, dv = jax.lax.fori_loop(
        lo, hi, body, (jnp.zeros((bk, d), jnp.float32),
                       jnp.zeros((bk, d), jnp.float32)))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _flash_bwd_gpu(q, k, v, do, lse, delta, *, causal, window, scale,
                   bq, bk, compiler_params, interpret):
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    kp = _pad_axis(k, 1, bk)
    vp = _pad_axis(v, 1, bk)
    skp, nk = kp.shape[1], kp.shape[1] // bk
    kq = functools.partial(_dq_kernel_gpu, scale=scale, causal=causal,
                           window=window, sq=sq, sk=sk, bq=bq, bk=bk, nk=nk)
    dq = pl.pallas_call(
        kq,
        grid=(bh, pl.cdiv(sq, bq)),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, iq: (b, iq, 0)),
            pl.BlockSpec((None, skp, d), lambda b, iq, g=group: (b // g, 0, 0)),
            pl.BlockSpec((None, skp, d), lambda b, iq, g=group: (b // g, 0, 0)),
            pl.BlockSpec((None, bq, d), lambda b, iq: (b, iq, 0)),
            pl.BlockSpec((None, bq), lambda b, iq: (b, iq)),
            pl.BlockSpec((None, bq), lambda b, iq: (b, iq)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, iq: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
        name="srds_flash_dq_gpu",
    )(q, kp, vp, do, lse, delta)

    # dK/dV walks Q tiles in-kernel: pad the q-side arrays instead
    qp = _pad_axis(q, 1, bq)
    dop = _pad_axis(do, 1, bq)
    lsep = _pad_axis(lse, 1, bq)
    deltap = _pad_axis(delta, 1, bq)
    sqp, nq = qp.shape[1], qp.shape[1] // bq
    kkv = functools.partial(_dkv_kernel_gpu, scale=scale, causal=causal,
                            window=window, sq=sq, sk=sk, bq=bq, bk=bk, nq=nq)
    dk, dv = pl.pallas_call(
        kkv,
        grid=(bh, pl.cdiv(sk, bk)),
        in_specs=[
            pl.BlockSpec((None, sqp, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((None, bk, d), lambda b, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((None, bk, d), lambda b, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((None, sqp, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((None, sqp), lambda b, ik: (b, 0)),
            pl.BlockSpec((None, sqp), lambda b, ik: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bk, d), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((None, bk, d), lambda b, ik: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
        name="srds_flash_dkv_gpu",
    )(qp, kp, vp, dop, lsep, deltap)
    return dq, dk, dv


def _gpu_params(num_warps, num_stages):
    kw = {}
    if num_warps is not None:
        kw["num_warps"] = int(num_warps)
    if num_stages is not None:
        kw["num_stages"] = int(num_stages)
    return compat.gpu_compiler_params(**kw)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, scale=None,
                        block_q=128, block_k=128, num_warps=None,
                        num_stages=None, plat="tpu", interpret=False):
    """q: (BH, Sq, D) already flattened over batch*q_heads; k/v: (BKV, Sk, D).

    ``group = BH // BKV`` kv-sharing factor (GQA) resolved via index_map.
    ``plat`` picks the kernel family ("tpu" grid-carried scratch vs "gpu"
    in-kernel loop; see module docstring) — resolved by the ops layer from
    the backend, orthogonal to ``interpret``.  ``num_warps``/``num_stages``
    only apply to the Triton family.  Returns (o (BH, Sq, D), lse (BH, Sq)).
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    scale = float(scale) if scale is not None else float(d) ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if plat == "gpu":
        return _flash_fwd_gpu(q, k, v, causal=causal, window=window,
                              scale=scale, bq=bq, bk=bk,
                              compiler_params=_gpu_params(num_warps,
                                                          num_stages),
                              interpret=interpret)
    grid = (bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk))

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_fwd",
    )(q, k, v)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, scale, causal, window, sq, sk, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_last = iq * bq + bq - 1 + (sk - sq)
    live = (ik * bk <= q_last) if causal else True
    if window is not None:
        q_first = iq * bq + (sk - sq)
        live = jnp.logical_and(live, ik * bk + bk - 1 > q_first - window)

    @pl.when(live)
    def _compute():
        q_valid = _row_valid(bq, iq * bq, sq)
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), q_valid)
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        do = _clean(do_ref[0].astype(jnp.float32), q_valid)
        lse = jnp.where(q_valid[:, 0], lse_ref[0], 0.0)
        delta = jnp.where(q_valid[:, 0], delta_ref[0], 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        dq_acc[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, sq, sk, bq, bk):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_last = iq * bq + bq - 1 + (sk - sq)
    live = (ik * bk <= q_last) if causal else True
    if window is not None:
        q_first = iq * bq + (sk - sq)
        live = jnp.logical_and(live, ik * bk + bk - 1 > q_first - window)

    @pl.when(live)
    def _compute():
        q_valid = _row_valid(bq, iq * bq, sq)
        kv_valid = _row_valid(bk, ik * bk, sk)
        q = _clean(q_ref[0].astype(jnp.float32), q_valid)
        k = _clean(k_ref[0].astype(jnp.float32), kv_valid)
        v = _clean(v_ref[0].astype(jnp.float32), kv_valid)
        do = _clean(do_ref[0].astype(jnp.float32), q_valid)
        lse = jnp.where(q_valid[:, 0], lse_ref[0], 0.0)
        delta = jnp.where(q_valid[:, 0], delta_ref[0], 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = _mask(bq, bk, iq, ik, sq, sk, causal, window)
        s = jnp.where(keep, s, NEG_INF)
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = jnp.where(keep, p * (dp - delta[:, None]) * scale, 0.0)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=None,
                        scale=None, block_q=128, block_k=128, num_warps=None,
                        num_stages=None, plat="tpu", interpret=False):
    """Returns (dq (BH,Sq,D), dk_g (BH,Sk,D), dv_g (BH,Sk,D)).

    dk/dv are produced per *q-head* (GQA groups not yet reduced); the ops
    wrapper sums over the group dimension — keeping the kernel free of
    cross-grid-cell reductions.  ``plat``/``num_warps``/``num_stages`` as
    in :func:`flash_attention_fwd`.
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    scale = float(scale) if scale is not None else float(d) ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    if plat == "gpu":
        return _flash_bwd_gpu(q, k, v, do, lse, delta, causal=causal,
                              window=window, scale=scale, bq=bq, bk=bk,
                              compiler_params=_gpu_params(num_warps,
                                                          num_stages),
                              interpret=interpret)

    kq = functools.partial(_dq_kernel, scale=scale, causal=causal,
                           window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    dq = pl.pallas_call(
        kq,
        grid=(bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk)),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, iq, ik: (b, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_dq",
    )(q, k, v, do, lse, delta)

    kkv = functools.partial(_dkv_kernel, scale=scale, causal=causal,
                            window=window, sq=sq, sk=sk, bq=bq, bk=bk)
    dk, dv = pl.pallas_call(
        kkv,
        grid=(bh, pl.cdiv(sk, bk), pl.cdiv(sq, bq)),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq, g=group: (b // g, ik, 0)),
            pl.BlockSpec((1, bq, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, bq), lambda b, ik, iq: (b, iq)),
            pl.BlockSpec((1, bq), lambda b, ik, iq: (b, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, ik, iq: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
            jax.ShapeDtypeStruct((bh, sk, d), q.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="srds_flash_dkv",
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
