"""Model zoo: unified transformer (dense/MoE/RWKV6/Hymba), DiT denoiser,
modality stubs; all pure-functional param-dict models."""
from .transformer import (LOCAL, ParallelCtx, decode_step, embed_inputs,
                          forward_train, init_params, make_dense_cache,
                          prefill)
from .dit import (dit_forward, init_dit, init_time_conditioned,
                  make_denoiser, time_conditioned_forward)

__all__ = [
    "LOCAL", "ParallelCtx", "decode_step", "embed_inputs", "forward_train",
    "init_params", "make_dense_cache", "prefill",
    "dit_forward", "init_dit", "init_time_conditioned", "make_denoiser",
    "time_conditioned_forward",
]
