"""Unified backbone: dense / MoE / RWKV6 / Hymba blocks behind one
functional API, with scan-over-layers (stacked params) so the HLO stays
one-layer-sized for the 512-device dry-run compile.

Modes:
  * ``forward_train``  — full-sequence, returns (logits, aux)
  * ``prefill``        — full-sequence, returns (last_logits, cache)
  * ``decode_step``    — one token against a cache, returns (logits, cache)

Expert parallelism, sequence parallelism and batch sharding are injected via
``ParallelCtx`` (None => single-device semantics, used by all smoke tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from . import hymba as hym
from . import rwkv6 as rwk
from .layers import (_dtype, apply_mlp, apply_norm, attention_decode,
                     attention_full, embed, init_attention, init_embedding,
                     init_mlp, init_norm, init_unembed, unembed)
from .moe import init_moe, moe_ep_local, moe_local


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Any = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    data_axis: str = "data"
    use_ep: bool = False
    sp: bool = False                 # sequence-parallel residual stream
    moe_capacity: float = 1.25
    moe_chunk: int = 8_192
    model_parallel: int = 1          # TP degree (for head/vocab padding)
    # Analysis mode: fully unroll scan-over-layers (and downstream scans) so
    # compiled.cost_analysis() counts every iteration — XLA counts while-loop
    # bodies ONCE (verified; see EXPERIMENTS.md §Dry-run methodology).
    scan_unroll: bool = False
    # ---- beyond-paper perf knobs (EXPERIMENTS.md §Perf) ----
    attn_chunk_kv: Optional[int] = None   # pure-JAX flash attention tile
    ce_masksum: bool = False              # CE gold-logit via mask-sum (no
                                          # vocab all-gather)
    moe_fixed_capacity: bool = False      # fixed per-expert windows (no
                                          # ragged_dot; TPU grouped-matmul)
    remat_policy: str = "dots"            # dots | nothing (full recompute)
    bf16_grad_sync: bool = False          # keep grads bf16 through the DP
                                          # all-reduce (clip via f32 scalar)
    fsdp: bool = False                    # shard large dense params on data
    kv_cache_dtype: str = "bfloat16"      # decode cache dtype (fp8 option)


LOCAL = ParallelCtx()


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, key, hq, hkv, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    if cfg.block == "rwkv6":
        return rwk.init_rwkv_block(key, cfg.d_model, cfg.rwkv_head_dim,
                                   cfg.d_ff, cfg.norm, dtype)
    p = {
        "ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
        "ln2": init_norm(ks[1], cfg.d_model, cfg.norm),
        "attn": init_attention(ks[2], cfg.d_model, hq, hkv, hd,
                               qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                               dtype=dtype),
    }
    if cfg.block == "hymba":
        d_inner = cfg.ssm_d_inner or cfg.d_model
        p["ssm"] = hym.init_ssm(ks[3], cfg.d_model, d_inner, cfg.ssm_state, dtype)
        p["n_attn"] = init_norm(ks[4], cfg.d_model, cfg.norm)
        p["n_ssm"] = init_norm(ks[5], cfg.d_model, cfg.norm)
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        return p
    if cfg.moe_experts:
        p["moe"] = init_moe(ks[3], cfg.d_model, cfg.moe_experts, cfg.moe_d_ff,
                            cfg.moe_top_k, cfg.act, dtype)
        if cfg.moe_dense_residual:
            p["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    else:
        p["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def init_params(cfg: ArchConfig, key, parallel: ParallelCtx = LOCAL):
    dtype = _dtype(cfg.dtype)
    mp = parallel.model_parallel
    hq, hkv = cfg.padded_heads(mp)
    vocab = cfg.padded_vocab(mp)
    k_embed, k_blocks, k_out, k_ln = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: _init_block(cfg, k, hq, hkv, dtype))(block_keys)
    params = {"blocks": blocks, "ln_f": init_norm(k_ln, cfg.d_model, cfg.norm)}
    if cfg.frontend != "audio":       # audio stub feeds features directly
        params["embed"] = init_embedding(k_embed, vocab, cfg.d_model, dtype)
    params["unembed"] = init_unembed(k_out, cfg.d_model, vocab, dtype)
    return params


# --------------------------------------------------------------------------
# block application (full sequence)
# --------------------------------------------------------------------------

def _constrain(x, parallel: ParallelCtx, spec):
    if parallel.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(parallel.mesh, spec))


def _residual_spec(parallel: ParallelCtx):
    seq = parallel.model_axis if parallel.sp else None
    return P(parallel.batch_axes, seq, None)


def _apply_moe(cfg: ArchConfig, p_moe, h, parallel: ParallelCtx):
    if not parallel.use_ep:
        return moe_local(p_moe, h, cfg.moe_top_k, cfg.act)
    b, s, d = h.shape
    mesh = parallel.mesh
    e_spec = P(parallel.data_axis, None,
               parallel.model_axis if parallel.model_axis else None)
    in_specs = (P(parallel.batch_axes, None, None),
                {"router": P(), "w_up": e_spec, "w_down": P(
                    parallel.data_axis,
                    parallel.model_axis if parallel.model_axis else None, None)}
                | ({"w_gate": e_spec} if "w_gate" in p_moe else {}))

    def body(h_loc, p_loc):
        t = h_loc.shape[0] * h_loc.shape[1]
        out, aux = moe_ep_local(
            p_loc, h_loc.reshape(t, d), cfg.moe_top_k,
            num_experts=cfg.moe_experts, data_axis=parallel.data_axis,
            model_axis=parallel.model_axis,
            capacity_factor=parallel.moe_capacity,
            chunk_tokens=parallel.moe_chunk, act=cfg.act,
            unroll=parallel.scan_unroll,
            fixed_capacity=parallel.moe_fixed_capacity)
        aux = jax.lax.pmean(aux, parallel.batch_axes)
        return out.reshape(h_loc.shape), aux

    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(parallel.batch_axes, None, None), P()),
                       check_vma=False)
    return fn(h, p_moe)


def _block_full(cfg: ArchConfig, p, x, state, parallel: ParallelCtx,
                hq, hkv, use_kernel):
    """One block, full-sequence.  Returns (x, cache_out, aux)."""
    hd = cfg.resolved_head_dim
    aux = jnp.float32(0.0)
    if cfg.block == "rwkv6":
        x, st = rwk.rwkv_block(p, x, state, cfg.rwkv_head_dim,
                               lambda pn, v: apply_norm(pn, v, cfg.norm),
                               use_kernel=use_kernel)
        return x, st, aux
    attn_kwargs = dict(num_heads=hq, num_kv_heads=hkv, head_dim=hd,
                       causal=cfg.causal, window=cfg.window,
                       theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                       chunk_kv=parallel.attn_chunk_kv,
                       unroll=parallel.scan_unroll)
    if cfg.block == "hymba":
        h_in = apply_norm(p["ln1"], x, cfg.norm)
        fused, kv, h_fin = hym.hymba_mix_full(
            {"attn": p["attn"], "ssm": p["ssm"], "n_attn": p["n_attn"],
             "n_ssm": p["n_ssm"]}, h_in, attn_kwargs, cfg.norm,
            h0=state, use_kernel=use_kernel)
        x = x + fused
        x = _constrain(x, parallel, _residual_spec(parallel))
        x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg.norm), cfg.act)
        return x, (kv, h_fin), aux
    # dense / moe
    attn_out, kv = attention_full(p["attn"], apply_norm(p["ln1"], x, cfg.norm),
                                  **attn_kwargs, use_kernel=use_kernel)
    x = x + attn_out
    x = _constrain(x, parallel, _residual_spec(parallel))
    h = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe_experts:
        moe_out, aux = _apply_moe(cfg, p["moe"], h, parallel)
        if cfg.moe_dense_residual:
            moe_out = moe_out + apply_mlp(p["mlp"], h, cfg.act)
        x = x + moe_out
    else:
        x = x + apply_mlp(p["mlp"], h, cfg.act)
    x = _constrain(x, parallel, _residual_spec(parallel))
    return x, kv, aux


# --------------------------------------------------------------------------
# embedding / input handling
# --------------------------------------------------------------------------

def embed_inputs(cfg: ArchConfig, params, batch):
    """Token / stub-frontend embedding. batch keys: tokens, image_embeds,
    features (per family)."""
    if cfg.frontend == "audio":
        return batch["features"].astype(_dtype(cfg.dtype))
    x = embed(params["embed"], batch["tokens"])
    if cfg.frontend == "vision" and "image_embeds" in batch:
        pfx = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([pfx, x[:, pfx.shape[1]:]], axis=1)
    return x


# --------------------------------------------------------------------------
# forward modes
# --------------------------------------------------------------------------

def _init_state_full(cfg: ArchConfig, batch_size, dtype):
    if cfg.block == "rwkv6":
        return rwk.init_rwkv_state(batch_size, cfg.d_model, cfg.rwkv_head_dim,
                                   dtype)
    if cfg.block == "hymba":
        d_inner = cfg.ssm_d_inner or cfg.d_model
        return jnp.zeros((batch_size, d_inner, cfg.ssm_state), jnp.float32)
    return None


def forward_hidden(cfg: ArchConfig, params, batch, *,
                   parallel: ParallelCtx = LOCAL, remat: bool = False,
                   use_kernel: Optional[bool] = None,
                   return_cache: bool = False):
    """Backbone up to the final norm. Returns (x (B,S,d), aux, cache|None).

    The unembedding is deliberately *not* applied here: at 152k vocab the
    full-sequence fp32 logits would be tens of GB — loss functions consume
    the hidden states and do chunked CE against params['unembed'] instead.
    """
    dtype = _dtype(cfg.dtype)
    hq, hkv = cfg.padded_heads(parallel.model_parallel)
    x = embed_inputs(cfg, params, batch)
    x = _constrain(x, parallel, _residual_spec(parallel))
    b = x.shape[0]
    state0 = _init_state_full(cfg, b, dtype)

    def body(carry, p_layer):
        x = carry
        x, cache, aux = _block_full(cfg, p_layer, x, state0, parallel, hq,
                                    hkv, use_kernel)
        return x, (cache if return_cache else None, aux)

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if parallel.remat_policy == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    x, (caches, auxes) = jax.lax.scan(body, x, params["blocks"],
                                      unroll=parallel.scan_unroll)
    x = apply_norm(params["ln_f"], x, cfg.norm)
    return x, jnp.sum(auxes), caches


def forward_train(cfg: ArchConfig, params, batch, *,
                  parallel: ParallelCtx = LOCAL, remat: bool = False,
                  use_kernel: Optional[bool] = None, return_cache: bool = False):
    """Full-sequence forward. Returns (logits (B,S,V), aux, cache|None)."""
    x, aux, caches = forward_hidden(cfg, params, batch, parallel=parallel,
                                    remat=remat, use_kernel=use_kernel,
                                    return_cache=return_cache)
    logits = unembed(params["unembed"], x)
    logits = _constrain(logits, parallel,
                        P(parallel.batch_axes, None, parallel.model_axis))
    return logits, aux, caches


def make_dense_cache(cfg: ArchConfig, batch, seq_len, parallel: ParallelCtx = LOCAL):
    import jax.numpy as _jnp
    dtype = _dtype(cfg.dtype)
    kv_dtype = {"bfloat16": _jnp.bfloat16, "float32": _jnp.float32,
                "float8_e4m3fn": _jnp.float8_e4m3fn}[parallel.kv_cache_dtype]
    hq, hkv = cfg.padded_heads(parallel.model_parallel)
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    if cfg.block == "rwkv6":
        st = rwk.init_rwkv_state(batch, cfg.d_model, cfg.rwkv_head_dim, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (l,) + a.shape), st)
    if cfg.block == "hymba":
        d_inner = cfg.ssm_d_inner or cfg.d_model
        c = hym.init_hymba_cache(batch, d_inner, cfg.ssm_state,
                                 cfg.window or seq_len, hkv, hd, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (l,) + a.shape), c)
    zeros = jnp.zeros((l, batch, seq_len, hkv, hd), kv_dtype)
    return (zeros, zeros)


def decode_step(cfg: ArchConfig, params, token_batch, cache, pos, *,
                parallel: ParallelCtx = LOCAL,
                use_kernel: Optional[bool] = None):
    """One-token decode. token_batch: {tokens: (B, 1)} (or features (B,1,d));
    cache: stacked per-layer cache; pos: scalar int32 current position.
    Returns (logits (B, V), new_cache)."""
    if cfg.is_encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only; no decode step")
    hq, hkv = cfg.padded_heads(parallel.model_parallel)
    hd = cfg.resolved_head_dim
    x = embed_inputs(cfg, params, token_batch)

    def body(carry, layer_in):
        x = carry
        p, c = layer_in
        if cfg.block == "rwkv6":
            st = rwk.RWKVState(*c)
            x, new_c = rwk.rwkv_block(p, x, st, cfg.rwkv_head_dim,
                                      lambda pn, v: apply_norm(pn, v, cfg.norm),
                                      use_kernel=use_kernel)
            return x, new_c
        if cfg.block == "hymba":
            h_in = apply_norm(p["ln1"], x, cfg.norm)
            fused, new_c = hym.hymba_mix_decode(
                {"attn": p["attn"], "ssm": p["ssm"], "n_attn": p["n_attn"],
                 "n_ssm": p["n_ssm"]}, h_in, hym.HymbaCache(*c), pos,
                num_heads=hq, num_kv_heads=hkv, head_dim=hd,
                window=cfg.window, theta=cfg.rope_theta, norm_kind=cfg.norm)
            x = x + fused
            x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg.norm), cfg.act)
            return x, new_c
        k_c, v_c = c
        attn_out, k_c, v_c = attention_decode(
            p["attn"], apply_norm(p["ln1"], x, cfg.norm), k_c, v_c, pos,
            num_heads=hq, num_kv_heads=hkv, head_dim=hd, window=cfg.window,
            theta=cfg.rope_theta, qk_norm=cfg.qk_norm)
        x = x + attn_out
        h = apply_norm(p["ln2"], x, cfg.norm)
        if cfg.moe_experts:
            moe_out, _ = _apply_moe(cfg, p["moe"], h, parallel)
            if cfg.moe_dense_residual:
                moe_out = moe_out + apply_mlp(p["mlp"], h, cfg.act)
            x = x + moe_out
        else:
            x = x + apply_mlp(p["mlp"], h, cfg.act)
        return x, (k_c, v_c)

    cache_tuple = tuple(cache) if isinstance(cache, (tuple, list)) else cache
    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache_tuple),
                                unroll=parallel.scan_unroll)
    x = apply_norm(params["ln_f"], x, cfg.norm)
    logits = unembed(params["unembed"], x[:, -1])
    return logits, new_cache


def prefill(cfg: ArchConfig, params, batch, *, parallel: ParallelCtx = LOCAL,
            use_kernel: Optional[bool] = None):
    """Full-sequence prefill: returns (last_logits (B,V), cache)."""
    logits, aux, caches = forward_train(cfg, params, batch, parallel=parallel,
                                        use_kernel=use_kernel,
                                        return_cache=True)
    if cfg.block in ("rwkv6", "hymba"):
        # caches are final states per layer (already stacked by scan)
        cache = caches
        if cfg.block == "hymba":
            kv, h_fin = caches
            b = h_fin.shape[1]
            hq, hkv_ = cfg.padded_heads(parallel.model_parallel)
            w = cfg.window or batch["tokens"].shape[1]
            # build ring from the last `window` positions
            k_all, v_all = kv
            s = k_all.shape[2]
            k_ring = k_all[:, :, max(0, s - w):]
            v_ring = v_all[:, :, max(0, s - w):]
            ring_pos = jnp.arange(s - w, s, dtype=jnp.int32)
            # slot i holds abs pos p with p % w == i: slice index j maps to
            # pos (s-w)+j, so shift right by (s-w) mod w
            roll = (s - w) % w
            k_ring = jnp.roll(k_ring, roll, axis=2)
            v_ring = jnp.roll(v_ring, roll, axis=2)
            ring_pos = jnp.roll(ring_pos, roll)
            l = cfg.num_layers
            cache = hym.HymbaCache(
                ssm_h=h_fin, k_ring=k_ring, v_ring=v_ring,
                ring_pos=jnp.broadcast_to(ring_pos, (l, w)))
    else:
        cache = caches
    return logits[:, -1], cache
