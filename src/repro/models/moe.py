"""Mixture-of-Experts layer with hand-written expert parallelism.

Two execution paths sharing one parameter layout:

``moe_local``
    Single-shard reference: top-k routing, stable-sort by expert,
    ``jax.lax.ragged_dot`` grouped matmuls, unsort + combine.  Used by smoke
    tests and as the oracle for the EP path.

``moe_ep_local``
    Expert parallelism for the production mesh, written for ``shard_map``:
    experts are sharded over the ``data`` axis (E_local = E / D) and each
    expert's FFN width over the ``model`` axis.  Tokens are exchanged with a
    capacity-bounded ``all_to_all`` (send buffer (D, C, d)); the token axis
    is processed in chunks (lax.scan) to bound the a2a buffer — at
    kimi-k2 scale an unchunked dispatch would need ~9 GB of transient HBM
    per device, chunking holds it near C_chunk*k*cf/D * d.

Routing semantics (both paths): softmax router, top-k, weights renormalized
over the selected experts, capacity drop in the EP path accounted in the
returned aux (dropped tokens contribute their residual stream unchanged —
standard dropping behaviour).  Router gradients flow through the combine
weights (no aux-loss-free tricks; a load-balance aux loss is returned).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat


def init_moe(key, d_model, num_experts, d_ff, top_k, act="swiglu",
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, num_experts)) * s_in
                   ).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (num_experts, d_model, d_ff)) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (num_experts, d_ff, d_model)) * s_out
                   ).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[3], (num_experts, d_model, d_ff))
                       * s_in).astype(dtype)
    return p


def _route(router_w, x_flat, top_k):
    logits = (x_flat.astype(jnp.float32) @ router_w)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, top_k)                  # (T, k)
    top_v = top_v / jnp.sum(top_v, axis=-1, keepdims=True)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    e = router_w.shape[1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(me * ce)
    return top_v, top_i, aux


def _expert_ffn(xs, w_gate, w_up, w_down, group_sizes, act):
    if act == "swiglu":
        g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype)
        h = h * jax.lax.ragged_dot(xs, w_up, group_sizes)
    else:
        h = jax.lax.ragged_dot(xs, w_up, group_sizes)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(xs.dtype)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def moe_local(p, x, top_k, act="swiglu"):
    """Single-shard MoE. x: (..., d). Returns (out, aux_loss)."""
    shape = x.shape
    d = shape[-1]
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    e = p["router"].shape[1]

    top_v, top_i, aux = _route(p["router"], x_flat, top_k)
    flat_e = top_i.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    tok = order // top_k
    xs = x_flat[tok]                                            # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)
    ys = _expert_ffn(xs, p.get("w_gate"), p["w_up"], p["w_down"],
                     group_sizes, act)
    # unsort: scatter back to assignment order
    inv = jnp.argsort(order, stable=True)
    ys = ys[inv].reshape(t, top_k, d)
    out = jnp.sum(ys * top_v[..., None].astype(ys.dtype), axis=1)
    return out.reshape(shape), aux


# --------------------------------------------------------------------------
# Expert-parallel path (inside shard_map)
# --------------------------------------------------------------------------

def moe_ep_local(p_local, x_local, top_k, *, num_experts, data_axis,
                 model_axis: Optional[str], capacity_factor=1.25,
                 chunk_tokens=8_192, act="swiglu", unroll: bool = False,
                 fixed_capacity: bool = False, expert_slack: float = 2.0):
    """Expert-parallel MoE body (call inside shard_map).

    p_local: expert weights already sliced: w_up (E_local, d, f_local) etc;
             router replicated (d, E).
    x_local: (T_local, d) this shard's tokens.
    Returns (out (T_local, d), aux_loss_local).
    """
    d_sz = compat.axis_size(data_axis)
    e_local = num_experts // d_sz
    t_local, d_model = x_local.shape
    chunk = min(chunk_tokens, t_local)
    n_chunks = -(-t_local // chunk)
    pad = n_chunks * chunk - t_local
    x_pad = jnp.pad(x_local, ((0, pad), (0, 0))) if pad else x_local
    cap = int(max(1, math.ceil(chunk * top_k / d_sz * capacity_factor)))

    def one_chunk(carry, x_c):
        top_v, top_i, aux = _route(p_local["router"], x_c, top_k)
        a_e = top_i.reshape(-1)                          # (A,) A = chunk*k
        a_tok = jnp.arange(a_e.shape[0], dtype=jnp.int32) // top_k
        dest = a_e // e_local                            # destination shard
        local_e = a_e % e_local
        # position within destination (capacity-bounded)
        onehot = jax.nn.one_hot(dest, d_sz, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
        keep = pos < cap
        slot_dest = jnp.where(keep, dest, d_sz)          # OOB -> dropped
        # scatter into send buffers
        send_x = jnp.zeros((d_sz, cap, d_model), x_c.dtype)
        send_e = jnp.full((d_sz, cap), e_local, jnp.int32)   # pad-expert id
        send_x = send_x.at[slot_dest, pos].set(x_c[a_tok], mode="drop")
        send_e = send_e.at[slot_dest, pos].set(local_e, mode="drop")
        # all-to-all over the expert/data axis
        recv_x = jax.lax.all_to_all(send_x, data_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, data_axis, 0, 0, tiled=True)
        rx = recv_x.reshape(d_sz * cap, d_model)
        re_ = recv_e.reshape(-1)
        # local grouped FFN: sort by local expert (pad-expert sorts last)
        order = jnp.argsort(re_, stable=True)
        xs = rx[order]
        gs = jnp.bincount(re_, length=e_local + 1).astype(jnp.int32)
        w_gate = p_local.get("w_gate")
        if fixed_capacity:
            # fixed per-expert capacity windows (TPU grouped-matmul style):
            # dynamic-slice a cap_e-row window per expert and run a dense
            # (E_l, cap_e, d) x (E_l, d, f) batched matmul.  Avoids
            # jax.lax.ragged_dot, whose CPU lowering computes every group
            # for every row (E_l x FLOPs inflation — see EXPERIMENTS.md);
            # rows beyond cap_e are dropped (standard capacity semantics).
            rows = xs.shape[0]
            cap_e = int(math.ceil(rows / max(e_local, 1) * expert_slack))
            starts = jnp.cumsum(gs) - gs                       # (E_l+1,)
            idx = (starts[:e_local, None]
                   + jnp.arange(cap_e)[None, :])               # (E_l, cap_e)
            within = jnp.arange(cap_e)[None, :] < gs[:e_local, None]
            idx_c = jnp.minimum(idx, rows - 1)
            xg = jnp.where(within[..., None], xs[idx_c], 0)    # (E_l,cap_e,d)
            if act == "swiglu":
                g = jnp.einsum("ecd,edf->ecf", xg, w_gate)
                h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype)
                h = h * jnp.einsum("ecd,edf->ecf", xg, p_local["w_up"])
            else:
                h = jnp.einsum("ecd,edf->ecf", xg, p_local["w_up"])
                h = jax.nn.gelu(h.astype(jnp.float32)).astype(xg.dtype)
            yg = jnp.einsum("ecf,efd->ecd", h, p_local["w_down"])
            ys = jnp.zeros((rows, d_model), yg.dtype)
            ys = ys.at[jnp.where(within, idx_c, rows + 1)].set(
                jnp.where(within[..., None], yg, 0), mode="drop")
        else:
            # append a zero pad-expert so group_sizes cover all rows
            def pad_w(w):
                return (None if w is None else
                        jnp.concatenate([w, jnp.zeros_like(w[:1])], axis=0))
            ys = _expert_ffn(xs, pad_w(w_gate), pad_w(p_local["w_up"]),
                             pad_w(p_local["w_down"]), gs, act)
        if model_axis is not None:   # f sharded over model: partial sums
            ys = jax.lax.psum(ys, model_axis)
        inv = jnp.argsort(order, stable=True)
        ys = ys[inv].reshape(d_sz, cap, d_model)
        back = jax.lax.all_to_all(ys, data_axis, 0, 0, tiled=True)
        # gather results for kept assignments; dropped -> 0
        y_a = jnp.where(keep[:, None], back[slot_dest.clip(0, d_sz - 1), pos], 0)
        y_a = y_a.reshape(chunk, top_k, d_model)
        out_c = jnp.sum(y_a * top_v[..., None].astype(y_a.dtype), axis=1)
        return carry + aux, out_c

    aux_total, out = jax.lax.scan(one_chunk, jnp.float32(0.0),
                                  x_pad.reshape(n_chunks, chunk, d_model),
                                  unroll=unroll)
    out = out.reshape(n_chunks * chunk, d_model)[:t_local]
    return out, aux_total / n_chunks
