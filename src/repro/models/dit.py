"""DiT denoiser (adaLN-zero) — the paper-representative diffusion backbone.

Patchified image -> transformer with per-block time-conditioned modulation
-> unpatchify to an epsilon prediction.  Exposes ``make_denoiser`` returning
the ``model_fn(x, t)`` closure consumed by every sampler in repro.core.

Also provides ``TimeConditioned`` wrapping for any zoo backbone: continuous
embedding-space diffusion with the backbone as the trunk (how SRDS composes
with the assigned architectures — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import (apply_mlp, apply_norm, attention_full, init_attention,
                     init_mlp, init_norm, sinusoidal_time_embed)


def init_dit(cfg: ArchConfig, key):
    """cfg.family == 'dit'; patch_size/in_channels set; vocab unused."""
    d = cfg.d_model
    p_in = cfg.patch_size * cfg.patch_size * cfg.in_channels
    ks = jax.random.split(key, 8)
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    s = 1.0 / math.sqrt(d)

    def blk(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn": init_attention(k1, d, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, dtype=dtype),
            "mlp": init_mlp(k2, d, cfg.d_ff, "gelu", dtype),
            # adaLN-zero: 6 modulation vectors from the time embedding
            "mod": (jax.random.normal(k3, (d, 6 * d)) * 0.0).astype(dtype),
            "mod_b": jnp.zeros((6 * d,), dtype),
        }

    blocks = jax.vmap(blk)(jax.random.split(ks[0], cfg.num_layers))
    return {
        "patch_in": (jax.random.normal(ks[1], (p_in, d)) * (p_in ** -0.5)).astype(dtype),
        "pos": (jax.random.normal(ks[2], (4096, d)) * 0.02).astype(dtype),
        "t_mlp1": (jax.random.normal(ks[3], (256, d)) * (256 ** -0.5)).astype(dtype),
        "t_mlp2": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "blocks": blocks,
        "ln_f": init_norm(ks[5], d),
        "mod_f": (jax.random.normal(ks[6], (d, 2 * d)) * 0.0).astype(dtype),
        "mod_fb": jnp.zeros((2 * d,), dtype),
        "patch_out": (jnp.zeros((d, p_in))).astype(dtype),  # zero-init final
    }


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def dit_forward(cfg: ArchConfig, params, x_img, t, *, use_kernel=None,
                unroll: bool = False, shard_axis: Optional[str] = None):
    """x_img: (B, H, W, C); t: (B,) conditioning times. Returns eps (B,H,W,C).

    With ``shard_axis`` this is the *per-shard* body of a patch-sharded
    forward inside a ``shard_map``: ``x_img`` is the local row-shard
    (``H_total / axis_size`` rows), positions are offset by ``axis_index``
    (row-major patch order makes row-shards contiguous position ranges),
    and attention all-gathers the projected K/V over the axis so every
    local query row attends to the full sequence.  Everything else —
    patch embed, adaLN modulation, MLP, unpatchify — is per-position and
    needs no communication.
    """
    b, h, w, c = x_img.shape
    p = cfg.patch_size
    gh, gw = h // p, w // p
    dtype = params["patch_in"].dtype
    patches = x_img.reshape(b, gh, p, gw, p, c).transpose(0, 1, 3, 2, 4, 5)
    patches = patches.reshape(b, gh * gw, p * p * c).astype(dtype)
    if shard_axis is None:
        pos = params["pos"][:gh * gw]
        kv_gather = None
    else:
        off = jax.lax.axis_index(shard_axis) * (gh * gw)
        pos = jax.lax.dynamic_slice_in_dim(params["pos"], off, gh * gw, 0)

        def kv_gather(a):   # (B, S_local, Hkv, D) -> (B, S_total, Hkv, D)
            return jax.lax.all_gather(a, shard_axis, axis=1, tiled=True)
    x = patches @ params["patch_in"] + pos[None]

    temb = sinusoidal_time_embed(t, 256).astype(dtype)
    temb = jax.nn.silu((temb @ params["t_mlp1"]).astype(jnp.float32)).astype(dtype)
    temb = temb @ params["t_mlp2"]                                 # (B, d)

    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim

    def body(carry, pb):
        x = carry
        mod = jax.nn.silu(temb.astype(jnp.float32)).astype(dtype) @ pb["mod"] + pb["mod_b"]
        sa, ga, sm_, gm, s2, g2 = jnp.split(mod, 6, axis=-1)
        h_in = _modulate(apply_norm({"scale": jnp.ones((cfg.d_model,))}, x), sa, ga)
        attn, _ = attention_full(pb["attn"], h_in, num_heads=hq,
                                 num_kv_heads=hkv, head_dim=hd, causal=False,
                                 theta=None, use_kernel=use_kernel,
                                 kv_gather=kv_gather)
        x = x + gm[:, None] * attn
        h2 = _modulate(apply_norm({"scale": jnp.ones((cfg.d_model,))}, x), sm_, s2)
        x = x + g2[:, None] * apply_mlp(pb["mlp"], h2, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
    mod = jax.nn.silu(temb.astype(jnp.float32)).astype(dtype) @ params["mod_f"] + params["mod_fb"]
    sf, gf = jnp.split(mod, 2, axis=-1)
    x = _modulate(apply_norm(params["ln_f"], x), sf, gf)
    out = x @ params["patch_out"]                                  # (B, n, p*p*c)
    out = out.reshape(b, gh, gw, p, p, c).transpose(0, 1, 3, 2, 4, 5)
    return out.reshape(b, h, w, c).astype(x_img.dtype)


def make_denoiser(cfg: ArchConfig, params, *, use_kernel=None,
                  shard_axis: Optional[str] = None, mesh=None):
    """Returns model_fn(x, t) with scalar-or-batched t (samplers pass scalar).

    With ``shard_axis`` it instead returns a sharding-aware
    :class:`repro.core.denoiser.Denoiser`: sample rows (the H dim of
    ``(K, H, W, C)``) patch-shard over that mesh axis
    (``in_spec = out_spec = P(None, shard_axis)``), the per-shard body is
    :func:`dit_forward` with its K/V all-gather, and ``fn`` stays the
    single-device global forward (the bit-exactness reference).  Every
    driver — ``srds_sample``, the sharded/pipelined samplers, the serving
    engine — consumes it through the seam with zero DiT-specific code.
    ``mesh`` (optional) pre-binds the denoiser for standalone calls.
    """

    def model_fn(x, t):
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return dit_forward(cfg, params, x, tb, use_kernel=use_kernel)

    if shard_axis is None:
        return model_fn

    from jax.sharding import PartitionSpec as P

    from repro.core.denoiser import Denoiser

    def shard_fn(x, t):
        if x.shape[1] % cfg.patch_size:
            raise ValueError(
                f"local row-shard of {x.shape[1]} rows is not divisible by "
                f"patch_size={cfg.patch_size}; pick a {shard_axis!r} axis "
                "size with (H / axis_size) % patch_size == 0")
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return dit_forward(cfg, params, x, tb, use_kernel=use_kernel,
                           shard_axis=shard_axis)

    den = Denoiser(fn=model_fn, shard_fn=shard_fn,
                   in_spec=P(None, shard_axis), out_spec=P(None, shard_axis),
                   mesh_axes={shard_axis: 1})
    return den.bind(mesh) if mesh is not None else den


# --------------------------------------------------------------------------
# TimeConditioned wrapper: any zoo backbone as an embedding-space denoiser
# --------------------------------------------------------------------------

def init_time_conditioned(cfg: ArchConfig, key, parallel=None):
    from .transformer import LOCAL, init_params
    k1, k2, k3 = jax.random.split(key, 3)
    base = init_params(cfg, k1, parallel or LOCAL)
    d = cfg.d_model
    dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    base["time_in"] = (jax.random.normal(k2, (256, d)) * (256 ** -0.5)).astype(dtype)
    base["eps_out"] = (jax.random.normal(k3, (d, d)) * (d ** -0.5) * 0.02).astype(dtype)
    return base


def time_conditioned_forward(cfg: ArchConfig, params, x, t, *, parallel=None,
                             use_kernel=None):
    """x: (B, S, d_model) continuous latents; t: (B,).  eps of same shape.

    Runs the backbone's blocks bidirectionally (denoisers see the whole
    sequence) with the time embedding added to every position.
    """
    import dataclasses as dc

    from .transformer import LOCAL, _block_full, _init_state_full

    par = parallel or LOCAL
    cfg_nc = dc.replace(cfg, causal=False)
    temb = sinusoidal_time_embed(t, 256).astype(x.dtype) @ params["time_in"]
    h = x + temb[:, None, :]
    hq, hkv = cfg.padded_heads(par.model_parallel)
    state0 = _init_state_full(cfg_nc, x.shape[0],
                              jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16)

    def body(carry, p_layer):
        hh, _, _ = _block_full(cfg_nc, p_layer, carry, state0, par, hq, hkv,
                               use_kernel)
        return hh, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = apply_norm(params["ln_f"], h, cfg.norm)
    return (h @ params["eps_out"]).astype(x.dtype)
