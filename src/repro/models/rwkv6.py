"""RWKV6 "Finch" block: data-dependent token-shift + WKV recurrence with
data-dependent per-channel decay, and the squared-ReLU channel-mix.

Faithful to arXiv:2404.05892 structure (ddlerp token shift via a low-rank
MLP producing the five r/k/v/w/g mixes; decay logits via a LoRA on top of a
per-channel base; bonus ``u``; per-head groupnorm; silu gate).  The WKV
recurrence runs through :func:`repro.kernels.ops.rwkv6_wkv` (Pallas kernel
on TPU / oracle elsewhere) for inference, and the pure-jnp scan for
training (kernel bwd = ref autodiff anyway).

State per layer (decode): (x_prev_tmix (B,d), wkv (B,H,dk,dk), x_prev_cmix (B,d)).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref

LORA_R = 32


class RWKVState(NamedTuple):
    x_tmix: jnp.ndarray    # (B, d)
    wkv: jnp.ndarray       # (B, H, dk, dk)
    x_cmix: jnp.ndarray    # (B, d)


def init_rwkv_block(key, d_model, head_dim, d_ff, norm_kind="rmsnorm",
                    dtype=jnp.bfloat16):
    from .layers import init_norm
    h = d_model // head_dim
    ks = jax.random.split(key, 13)
    s = 1.0 / math.sqrt(d_model)
    n = lambda k, shp, sc=s: (jax.random.normal(k, shp) * sc).astype(dtype)
    tmix = {
        # token-shift ddlerp
        "mu_base": jnp.zeros((d_model,), dtype),
        "mu_rkvwg": jnp.zeros((5, d_model), dtype),
        "A_mix": n(ks[0], (d_model, 5 * LORA_R)),
        "B_mix": n(ks[1], (5, LORA_R, d_model), 1.0 / math.sqrt(LORA_R)),
        # projections
        "wr": n(ks[2], (d_model, d_model)),
        "wk": n(ks[3], (d_model, d_model)),
        "wv": n(ks[4], (d_model, d_model)),
        "wg": n(ks[5], (d_model, d_model)),
        "wo": n(ks[6], (d_model, d_model)),
        # decay: base + lora; bonus u
        "w_base": jnp.zeros((d_model,), jnp.float32) - 0.5,
        "A_w": n(ks[7], (d_model, LORA_R)),
        "B_w": n(ks[8], (LORA_R, d_model), 1.0 / math.sqrt(LORA_R)),
        "u": (jax.random.normal(ks[9], (h, head_dim)) * 0.3).astype(jnp.float32),
        "gn_scale": jnp.ones((d_model,), jnp.float32),
    }
    cmix = {
        "mu_ck": jnp.zeros((d_model,), dtype),
        "mu_cr": jnp.zeros((d_model,), dtype),
        "wk_c": n(ks[10], (d_model, d_ff)),
        "wv_c": n(ks[11], (d_ff, d_model), 1.0 / math.sqrt(d_ff)),
        "wr_c": n(ks[12], (d_model, d_model)),
    }
    return {"ln1": init_norm(ks[0], d_model, norm_kind),
            "ln2": init_norm(ks[1], d_model, norm_kind),
            "tmix": tmix, "cmix": cmix}


def _shift(x, x_prev):
    """x: (B,S,d); x_prev: (B,d) carried from the previous segment."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _group_norm(x, scale, h, eps=1e-5):
    b, s, d = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, h, d // h)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(b, s, d) * scale).astype(x.dtype)


def time_mix(p, x, state: RWKVState, head_dim, *, use_kernel=None):
    """x: (B, S, d). Returns (out, new_state_parts)."""
    b, s, d = x.shape
    h = d // head_dim
    xp = _shift(x, state.x_tmix)
    xx = xp - x
    base = x + xx * p["mu_base"]
    z = jnp.tanh(base @ p["A_mix"]).reshape(b, s, 5, LORA_R)
    mixes = p["mu_rkvwg"][None, None] + jnp.einsum(
        "bsfr,frd->bsfd", z, p["B_mix"].astype(z.dtype)).astype(x.dtype)
    xr, xk, xv, xw, xg = [x + xx * mixes[:, :, i] for i in range(5)]

    r = (xr @ p["wr"]).reshape(b, s, h, head_dim).transpose(0, 2, 1, 3)
    k = (xk @ p["wk"]).reshape(b, s, h, head_dim).transpose(0, 2, 1, 3)
    v = (xv @ p["wv"]).reshape(b, s, h, head_dim).transpose(0, 2, 1, 3)
    g = xg @ p["wg"]
    w_logit = p["w_base"] + jnp.tanh(xw.astype(jnp.float32) @ p["A_w"].astype(jnp.float32)) @ p["B_w"].astype(jnp.float32)
    # clamp for numerical sanity of exp(-exp(w))
    w_logit = jnp.clip(w_logit, -8.0, 4.0).reshape(b, s, h, head_dim).transpose(0, 2, 1, 3)

    wkv, s_fin = kops.rwkv6_wkv(r, k, v, w_logit, p["u"], state.wkv,
                                use_kernel=use_kernel)
    wkv = wkv.transpose(0, 2, 1, 3).reshape(b, s, d)
    out = _group_norm(wkv, p["gn_scale"], h)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
    return out @ p["wo"], x[:, -1], s_fin


def channel_mix(p, x, state: RWKVState):
    xp = _shift(x, state.x_cmix)
    xk = x + (xp - x) * p["mu_ck"]
    xr = x + (xp - x) * p["mu_cr"]
    kk = jnp.square(jax.nn.relu((xk @ p["wk_c"]).astype(jnp.float32))).astype(x.dtype)
    out = jax.nn.sigmoid((xr @ p["wr_c"]).astype(jnp.float32)).astype(x.dtype) * (kk @ p["wv_c"])
    return out, x[:, -1]


def rwkv_block(p, x, state: RWKVState, head_dim, norm_fn, *, use_kernel=None):
    """Full pre-norm RWKV6 block. Returns (x_out, new_state)."""
    h1, xt, wkv = time_mix(p["tmix"], norm_fn(p["ln1"], x), state, head_dim,
                           use_kernel=use_kernel)
    x = x + h1
    h2, xc = channel_mix(p["cmix"], norm_fn(p["ln2"], x), state)
    x = x + h2
    return x, RWKVState(x_tmix=xt, wkv=wkv, x_cmix=xc)


def init_rwkv_state(batch, d_model, head_dim, dtype=jnp.bfloat16):
    h = d_model // head_dim
    return RWKVState(
        x_tmix=jnp.zeros((batch, d_model), dtype),
        wkv=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        x_cmix=jnp.zeros((batch, d_model), dtype),
    )
