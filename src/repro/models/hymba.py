"""Hymba block (arXiv:2411.13676): parallel attention + Mamba-style SSM
heads within one layer, outputs normalized and mean-fused.

Adaptations (documented in DESIGN.md):
  * all layers use sliding-window attention (the SSM path carries global
    context — Hymba's own argument); the paper's three full-attention
    layers are dropped so the layer stack stays homogeneous for
    scan-over-layers (compile-time at 512 devices) and long_500k memory
    stays O(window);
  * the SSM is a diagonal selective SSM (data-dependent dt/B/C, learned
    A < 0, skip D) without the depthwise conv — conv state handling adds a
    second decode cache for marginal modelling value at dry-run fidelity.

Decode state: (ssm_h (B, d_inner, n), ring KV cache of size window).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_mlp, apply_norm, attention_decode, attention_full


class HymbaCache(NamedTuple):
    ssm_h: jnp.ndarray      # (B, d_inner, n)
    k_ring: jnp.ndarray     # (B, W, Hkv, Dh)
    v_ring: jnp.ndarray     # (B, W, Hkv, Dh)
    ring_pos: jnp.ndarray   # (W,) absolute position stored in each slot (-1 empty)


def init_ssm(key, d_model, d_inner, n_state, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    si = 1.0 / math.sqrt(d_inner)
    return {
        "w_in": (jax.random.normal(ks[0], (d_model, 2 * d_inner)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[1], (d_inner, 1)) * si).astype(jnp.float32),
        "b_dt": jnp.full((1,), -2.0, jnp.float32),   # softplus(-2) ~ 0.12
        "w_B": (jax.random.normal(ks[2], (d_inner, n_state)) * si).astype(jnp.float32),
        "w_C": (jax.random.normal(ks[3], (d_inner, n_state)) * si).astype(jnp.float32),
        # explicit f32: under jax_enable_x64 (set by some test modules)
        # linspace would otherwise produce f64 params and poison the f32
        # selective-scan carry
        "A_log": (jnp.log(jnp.linspace(1.0, float(n_state), n_state,
                                       dtype=jnp.float32))[None, :]
                  * jnp.ones((d_inner, 1), jnp.float32)),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": (jax.random.normal(ks[4], (d_inner, d_model)) * si).astype(dtype),
    }


def _ssm_scan(p, xs, h0):
    """Selective scan. xs: (B, S, d_inner) f32; h0: (B, d_inner, n).

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t * x_t ;  y_t = (h_t C_t) + D x_t
    """
    a = -jnp.exp(p["A_log"])                                  # (din, n)
    dt = jax.nn.softplus(xs @ p["w_dt"] + p["b_dt"])          # (B,S,1)
    bb = xs @ p["w_B"]                                        # (B,S,n)
    cc = xs @ p["w_C"]                                        # (B,S,n)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                             # (B,din),(B,1),(B,n)
        decay = jnp.exp(a[None] * dt_t[:, :, None])           # (B,din,n)
        h = decay * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + p["D"] * x_t
        return h, y

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (xs, dt, bb, cc))
    h_fin, ys = jax.lax.scan(step, h0, inps)
    return jnp.moveaxis(ys, 0, 1), h_fin                      # (B,S,din)


def ssm_forward(p, x, h0=None):
    """x: (B,S,d). Returns (out (B,S,d), h_fin)."""
    b, s, _ = x.shape
    d_inner = p["w_in"].shape[1] // 2
    n = p["w_B"].shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, d_inner, n), jnp.float32)
    zx = x @ p["w_in"]
    z, xs = jnp.split(zx, 2, axis=-1)
    ys, h_fin = _ssm_scan(p, xs.astype(jnp.float32), h0)
    ys = ys.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return ys @ p["w_out"], h_fin


def hymba_mix_full(p, x, cfg_attn, norm_kind, h0=None, use_kernel=None):
    """Parallel attn+SSM mixer (train/prefill). Returns (out, (kv, h_fin))."""
    attn_out, kv = attention_full(p["attn"], x, **cfg_attn, use_kernel=use_kernel)
    ssm_out, h_fin = ssm_forward(p["ssm"], x, h0)
    fused = 0.5 * (apply_norm(p["n_attn"], attn_out, norm_kind)
                   + apply_norm(p["n_ssm"], ssm_out, norm_kind))
    return fused, kv, h_fin


def ring_update(cache: HymbaCache, k_new, v_new, pos, window):
    slot = pos % window
    k_ring = jax.lax.dynamic_update_slice_in_dim(cache.k_ring, k_new.astype(cache.k_ring.dtype), slot, axis=1)
    v_ring = jax.lax.dynamic_update_slice_in_dim(cache.v_ring, v_new.astype(cache.v_ring.dtype), slot, axis=1)
    ring_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.ring_pos, jnp.asarray([pos], cache.ring_pos.dtype), slot, axis=0)
    return k_ring, v_ring, ring_pos


def hymba_mix_decode(p, x, cache: HymbaCache, pos, *, num_heads, num_kv_heads,
                     head_dim, window, theta, norm_kind):
    """Single-token decode with ring KV + SSM state."""
    from .layers import project_qkv
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = project_qkv(p["attn"], x, num_heads, num_kv_heads,
                                  head_dim, positions, theta)
    k_ring, v_ring, ring_pos = ring_update(cache, k_new, v_new, pos, window)
    group = num_heads // num_kv_heads
    qf = q.astype(jnp.float32).reshape(b, 1, num_kv_heads, group, head_dim)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qf,
                        k_ring.astype(jnp.float32)) / math.sqrt(head_dim)
    valid = jnp.logical_and(ring_pos >= 0,
                            jnp.logical_and(ring_pos <= pos,
                                            ring_pos > pos - window))
    logits = jnp.where(valid[None, None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", probs, v_ring.astype(jnp.float32))
    attn_out = o.reshape(b, 1, num_heads * head_dim).astype(x.dtype) @ p["attn"]["wo"]

    ssm_out, h_fin = ssm_forward(p["ssm"], x, cache.ssm_h)
    fused = 0.5 * (apply_norm(p["n_attn"], attn_out, norm_kind)
                   + apply_norm(p["n_ssm"], ssm_out, norm_kind))
    return fused, HymbaCache(ssm_h=h_fin, k_ring=k_ring, v_ring=v_ring,
                             ring_pos=ring_pos)


def init_hymba_cache(batch, d_inner, n_state, window, num_kv_heads, head_dim,
                     dtype=jnp.bfloat16):
    return HymbaCache(
        ssm_h=jnp.zeros((batch, d_inner, n_state), jnp.float32),
        k_ring=jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        v_ring=jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        ring_pos=jnp.full((window,), -1, jnp.int32),
    )
