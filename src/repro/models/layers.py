"""Shared model layers: norms, rotary, attention (GQA/qk-norm/bias/SWA),
MLPs.  Pure-functional: params are plain dict pytrees; every init_* returns
params and every apply takes (params, x, ...).

Attention routes through the Pallas flash kernel on TPU and the jnp oracle
elsewhere (``repro.kernels.ops.FORCE_REF`` or explicit ``use_kernel``);
decode-time single-token attention uses a dedicated masked path (matvec
bound, no kernel needed).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(key, dim, kind="rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, *,
                   qkv_bias=False, qk_norm=False, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, num_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, num_kv_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, num_kv_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (num_heads * head_dim, d_model)) * s).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = init_norm(key, head_dim)
        p["k_norm"] = init_norm(key, head_dim)
    return p


def project_qkv(p, x, num_heads, num_kv_heads, head_dim, positions, theta,
                qk_norm=False):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    if qk_norm:
        q = apply_norm(p["q_norm"], q)
        k = apply_norm(p["k_norm"], k)
    if theta is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_full(p, x, *, num_heads, num_kv_heads, head_dim, causal=True,
                   window=None, theta=10_000.0, qk_norm=False,
                   positions=None, use_kernel=None, chunk_kv=None,
                   unroll=False, kv_gather=None):
    """Full-sequence attention (training / prefill). x: (B, S, d).

    ``chunk_kv``: pure-JAX flash (online softmax over KV tiles) — the
    memory-faithful stand-in for the Pallas kernel on non-TPU backends.
    ``kv_gather``: sequence-parallel hook — inside a shard_map body where
    ``x`` is the local sequence shard, it gathers the projected K/V along
    the sequence axis (``(B, S_local, Hkv, D) -> (B, S_total, Hkv, D)``) so
    every local query attends to the full sequence (the DiT patch-sharding
    layout).  Callers own positional correctness: with a gather, rope
    positions must be the *global* ones or ``theta=None`` (DiT)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = project_qkv(p, x, num_heads, num_kv_heads, head_dim, positions,
                          theta, qk_norm)
    if kv_gather is not None:
        k = kv_gather(k)
        v = kv_gather(v)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    if chunk_kv is not None and not use_kernel:
        o = kref.attention_chunked(qt, kt, vt, causal=causal, window=window,
                                   chunk=chunk_kv, unroll=unroll)
    else:
        o = kops.attention(qt, kt, vt, causal=causal, window=window,
                           use_kernel=use_kernel)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, num_heads * head_dim)
    return o @ p["wo"], (k, v)


def attention_decode(p, x, k_cache, v_cache, pos, *, num_heads, num_kv_heads,
                     head_dim, window=None, theta=10_000.0, qk_norm=False):
    """Single-token decode. x: (B, 1, d); caches: (B, S_max, Hkv, D);
    pos: scalar current position.  Returns (out (B,1,d), k_new, v_new).

    Pure masked softmax (matvec-bound; the Pallas kernel brings nothing at
    Sq=1 — the flash-decoding win at scale comes from KV-sequence sharding,
    handled in repro/serve via shard_map LSE-combine).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                                  positions, theta, qk_norm)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    s_max = k_cache.shape[1]
    group = num_heads // num_kv_heads
    qf = q.astype(jnp.float32).reshape(b, 1, num_kv_heads, group, head_dim)
    kf = k_cache.astype(jnp.float32)                     # (B, S, Hkv, D)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qf, kf) / math.sqrt(head_dim)
    kpos = jnp.arange(s_max)
    valid = kpos <= pos
    if window is not None:
        valid = jnp.logical_and(valid, kpos > pos - window)
    logits = jnp.where(valid[None, None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", probs, v_cache.astype(jnp.float32))
    o = o.reshape(b, 1, num_heads * head_dim).astype(x.dtype)
    return o @ p["wo"], k_cache, v_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, act="swiglu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {"w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype)}
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(p, x, act="swiglu"):
    if act == "swiglu":
        h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        h = h * (x @ p["w_up"])
    else:
        h = jax.nn.gelu((x @ p["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(p, tokens):
    return p["table"][tokens]


def init_unembed(key, d_model, vocab, dtype=jnp.bfloat16):
    s = 1.0 / math.sqrt(d_model)
    return {"w": (jax.random.normal(key, (d_model, vocab)) * s).astype(dtype)}


def unembed(p, x):
    return (x @ p["w"]).astype(jnp.float32)


def sinusoidal_time_embed(t: jnp.ndarray, dim: int, max_period=10_000.0):
    """t: (B,) float -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)
