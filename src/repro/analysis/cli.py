"""reprolint command line: ``python -m repro.analysis [paths...]``.

Exit codes (consumed by scripts/check.sh and the CI lint leg):
  0 — clean (suppressed findings allowed)
  1 — findings
  2 — the linter itself could not run (bad usage, crashed rule, unreadable
      tree); check.sh treats any nonzero as a loud failure, never a pass.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.analysis.core import DEFAULT_PATHS, lint_paths, rule_table


def _build_parser() -> argparse.ArgumentParser:
    last = rule_table()[-1][0]
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=f"reprolint: AST invariant checker for the SRDS stack "
                    f"(rules RL001-{last}; see README 'Static analysis').")
    p.add_argument("paths", nargs="*", default=None,
                   help=f"files/directories to lint "
                        f"(default: {' '.join(DEFAULT_PATHS)})")
    p.add_argument("--root", default=None,
                   help="repo root anchoring relative paths and the "
                        "project-level rules (default: cwd)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="stdout format (default: text)")
    p.add_argument("--output", default=None, metavar="FILE",
                   help="additionally write the full JSON report to FILE "
                        "(CI uploads this as an artifact)")
    p.add_argument("--select", default=None, metavar="CODES",
                   help="comma-separated rule codes to run (default: all)")
    p.add_argument("--ignore", default=None, metavar="CODES",
                   help="comma-separated rule codes to skip")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def _codes(raw: Optional[str]):
    return [c.strip() for c in raw.split(",") if c.strip()] if raw else None


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for code, name, summary in rule_table():
            print(f"{code}  {name:<24} {summary}")
        return 0

    paths = args.paths or list(DEFAULT_PATHS)
    try:
        report = lint_paths(paths, root=args.root,
                            select=_codes(args.select),
                            ignore=_codes(args.ignore))
    except Exception as exc:   # never die silently: check.sh depends on it
        print(f"reprolint: internal error: {type(exc).__name__}: {exc}",
              file=sys.stderr)
        return 2

    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(report.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")

    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        for f in report.findings:
            print(f"{f.location()}: {f.code} [{f.rule}] {f.message}")
        for e in report.errors:
            print(f"reprolint: ERROR: {e}", file=sys.stderr)
        n, m = len(report.findings), len(report.suppressed)
        if report.clean and not report.errors:
            print(f"reprolint: clean — {report.files_scanned} files, "
                  f"{m} suppressed finding(s)")
        else:
            print(f"reprolint: {n} finding(s), {m} suppressed, "
                  f"{report.files_scanned} files scanned", file=sys.stderr)

    if report.errors:
        return 2
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
