"""reprolint framework: findings, suppressions, module model, rule registry.

The linter is pure stdlib on purpose — ``import repro.analysis`` must work
(and the whole tree must lint) on a box with **no JAX installed**, so CI can
run the policy gate as a fast, dependency-free leg and ``scripts/check.sh``
never needs the heavy environment just to reject a policy violation.  Rules
therefore reason about *source* (AST + the import graph), never about live
objects.

Layout:

* :class:`Finding` — one violation: rule code, message, file, line, col.
* :class:`Suppressions` — ``# reprolint: disable=CODE[,CODE...]`` inline
  directives (same line, or a standalone comment on the line directly
  above) and ``# reprolint: disable-file=CODE`` file-level directives.
  Suppressed findings are *recorded*, not discarded: they ride the report
  so the fixture meta-test can hold "clean modulo recorded suppressions".
* :class:`ModuleInfo` — parsed module + resolved import aliases: the map
  from every local name to the dotted path it came from, so rules see
  through ``from jax import tree_map``, ``from jax.experimental import
  shard_map as sm`` and plain module aliases (the class of call sites the
  old ``check.sh`` grep could not).
* ``@module_rule`` / ``@project_rule`` — the registry.  Module rules run
  per parsed file; project rules run once per invocation (repo-level
  hygiene like RL007's tracked-artifact check).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding", "Suppressions", "ModuleInfo", "LintReport",
    "module_rule", "project_rule", "iter_rules", "rule_table",
    "lint_paths", "discover_files", "qualname", "collect_aliases",
    "DEFAULT_PATHS", "EXCLUDED_DIRS",
]

# Directories never walked when a *directory* is linted.  ``lint_fixtures``
# is the linter's own seeded-violation corpus (tests pass those files
# explicitly); explicit file arguments always bypass the exclusions.
EXCLUDED_DIRS = frozenset({
    "__pycache__", ".git", ".pytest_cache", "lint_fixtures", ".venv", "node_modules",
})

# What `python -m repro.analysis` lints when given no paths.
DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "scripts")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str                 # e.g. "RL001"
    message: str
    path: str                 # repo-relative, posix separators
    line: int                 # 1-based
    col: int = 0              # 0-based (ast convention)
    rule: str = ""            # short rule name, e.g. "compat-drift"

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Inline suppressions
# --------------------------------------------------------------------------

_INLINE_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\s]+?|all)\s*(?:#|$)")
_FILE_RE = re.compile(r"#\s*reprolint:\s*disable-file=([A-Za-z0-9_,\s]+?|all)\s*(?:#|$)")


def _parse_codes(raw: str) -> frozenset:
    return frozenset(c.strip().upper() for c in raw.split(",") if c.strip())


class Suppressions:
    """Per-file suppression directives, parsed from raw source lines."""

    def __init__(self, source: str):
        self.by_line: Dict[int, frozenset] = {}
        self.file_level: frozenset = frozenset()
        for i, text in enumerate(source.splitlines(), start=1):
            m = _FILE_RE.search(text)
            if m:
                self.file_level = self.file_level | _parse_codes(m.group(1))
                continue
            m = _INLINE_RE.search(text)
            if m:
                codes = _parse_codes(m.group(1))
                self.by_line[i] = self.by_line.get(i, frozenset()) | codes
                # a standalone directive comment suppresses the next line
                # too (black-wrapped statements can't always host a trailer)
                if text.lstrip().startswith("#"):
                    self.by_line[i + 1] = \
                        self.by_line.get(i + 1, frozenset()) | codes

    def covers(self, code: str, line: int) -> bool:
        code = code.upper()
        for scope in (self.file_level, self.by_line.get(line, frozenset())):
            if "ALL" in scope or code in scope:
                return True
        return False


# --------------------------------------------------------------------------
# Import-graph resolution
# --------------------------------------------------------------------------

def collect_aliases(tree: ast.AST,
                    package: Optional[str] = None) -> Dict[str, str]:
    """Local name -> fully-qualified dotted origin, for every import.

    ``import a.b.c``            binds ``a`` -> ``a``
    ``import a.b.c as x``       binds ``x`` -> ``a.b.c``
    ``from a.b import c``       binds ``c`` -> ``a.b.c``
    ``from a.b import c as x``  binds ``x`` -> ``a.b.c``
    ``from . import engine``    resolves relative to ``package`` when known
                                (the module's containing package).
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".", 1)[0]
                    aliases.setdefault(root, root)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level and package:
                pkg_parts = package.split(".")
                # level 1 = the containing package; each extra level climbs
                anchor = pkg_parts[: max(len(pkg_parts) - (node.level - 1), 0)]
                base = ".".join(anchor + ([node.module] if node.module
                                          else []))
            for a in node.names:
                if a.name == "*":
                    continue
                full = f"{base}.{a.name}" if base else a.name
                aliases[a.asname or a.name] = full
    return aliases


def qualname(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted path of a Name/Attribute chain with aliases substituted.

    ``sm.shard_map`` with ``sm -> jax.experimental.shard_map`` resolves to
    ``jax.experimental.shard_map.shard_map``.  Returns None for chains
    rooted in anything but a plain name (calls, subscripts, literals).
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


@dataclasses.dataclass
class ModuleInfo:
    path: str                      # repo-relative posix path
    abspath: str
    module: Optional[str]          # dotted name when under a src root
    source: str
    tree: ast.AST
    aliases: Dict[str, str]
    suppressions: Suppressions

    @property
    def is_test_file(self) -> bool:
        return os.path.basename(self.path).startswith("test_")


def _module_name(relpath: str) -> Tuple[Optional[str], Optional[str]]:
    """(dotted module name, containing package) for files under ``src/``."""
    p = relpath.replace(os.sep, "/")
    if not p.startswith("src/") or not p.endswith(".py"):
        return None, None
    mod = p[len("src/"):-len(".py")]
    if mod.endswith("/__init__"):
        mod = mod[: -len("/__init__")]
        pkg = mod                       # a package is its own anchor
    else:
        pkg = mod.rsplit("/", 1)[0] if "/" in mod else None
    return (mod.replace("/", "."),
            pkg.replace("/", ".") if pkg else None)


def load_module(abspath: str, root: str) -> Optional[ModuleInfo]:
    rel = os.path.relpath(abspath, root).replace(os.sep, "/")
    try:
        with open(abspath, "r", encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=abspath)
    except (OSError, SyntaxError, ValueError):
        return None
    module, package = _module_name(rel)
    return ModuleInfo(path=rel, abspath=abspath, module=module, source=source,
                      tree=tree, aliases=collect_aliases(tree, package),
                      suppressions=Suppressions(source))


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

_MODULE_RULES: List[Callable] = []
_PROJECT_RULES: List[Callable] = []


def _register(registry: List[Callable], code: str, name: str, summary: str):
    def deco(fn):
        fn.code = code
        fn.rule_name = name
        fn.summary = summary
        registry.append(fn)
        return fn
    return deco


def module_rule(code: str, name: str, summary: str):
    """Register a per-file rule: ``fn(mod: ModuleInfo) -> Iterable[Finding]``."""
    return _register(_MODULE_RULES, code, name, summary)


def project_rule(code: str, name: str, summary: str):
    """Register a once-per-run rule: ``fn(root, files) -> Iterable[Finding]``."""
    return _register(_PROJECT_RULES, code, name, summary)


def iter_rules() -> List[Callable]:
    # importing the rules module registers them; local import breaks the
    # cycle (rules.py imports this module's decorators)
    from repro.analysis import rules as _rules  # noqa: F401
    return sorted(_MODULE_RULES + _PROJECT_RULES, key=lambda r: r.code)


def rule_table() -> List[Tuple[str, str, str]]:
    return [(r.code, r.rule_name, r.summary) for r in iter_rules()]


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

def discover_files(paths: Sequence[str], root: str) -> List[str]:
    """Python files to lint.  Directories are walked (minus EXCLUDED_DIRS);
    explicitly named files are taken as-is, excluded or not."""
    out: List[str] = []
    seen = set()
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            if ap not in seen:
                seen.add(ap)
                out.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in EXCLUDED_DIRS)
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        f = os.path.join(dirpath, fn)
                        if f not in seen:
                            seen.add(f)
                            out.append(f)
    return out


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]
    suppressed: List[Finding]
    files_scanned: int
    errors: List[str]

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "files_scanned": self.files_scanned,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "errors": list(self.errors),
            "rules": [{"code": c, "name": n, "summary": s}
                      for c, n, s in rule_table()],
        }


def _selected(code: str, select, ignore) -> bool:
    if select and code.upper() not in {c.upper() for c in select}:
        return False
    if ignore and code.upper() in {c.upper() for c in ignore}:
        return False
    return True


def lint_paths(paths: Sequence[str], root: Optional[str] = None,
               select: Optional[Sequence[str]] = None,
               ignore: Optional[Sequence[str]] = None) -> LintReport:
    """Run every registered rule over ``paths``; returns the full report.

    ``root`` anchors repo-relative paths, module-name resolution and the
    project-level rules (default: cwd).  Findings covered by an inline or
    file-level suppression land in ``report.suppressed``.
    """
    # rules import registers them; local import avoids a cycle at package
    # import time (rules.py imports this module's decorators)
    from repro.analysis import rules as _rules  # noqa: F401

    root = os.path.abspath(root or os.getcwd())
    files = discover_files(paths, root)
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[str] = []
    modules: List[ModuleInfo] = []
    for f in files:
        mod = load_module(f, root)
        if mod is None:
            errors.append(f"could not parse {os.path.relpath(f, root)}")
            continue
        modules.append(mod)

    for mod in modules:
        for rule in _MODULE_RULES:
            if not _selected(rule.code, select, ignore):
                continue
            try:
                hits = list(rule(mod))
            except Exception as exc:  # a crashing rule must fail loud
                errors.append(f"rule {rule.code} crashed on {mod.path}: "
                              f"{type(exc).__name__}: {exc}")
                continue
            for h in hits:
                (suppressed if mod.suppressions.covers(h.code, h.line)
                 else findings).append(h)

    supp_by_path = {m.path: m.suppressions for m in modules}
    for rule in _PROJECT_RULES:
        if not _selected(rule.code, select, ignore):
            continue
        try:
            hits = list(rule(root, modules))
        except Exception as exc:
            errors.append(f"rule {rule.code} crashed: "
                          f"{type(exc).__name__}: {exc}")
            continue
        for h in hits:
            sup = supp_by_path.get(h.path)
            (suppressed if sup is not None and sup.covers(h.code, h.line)
             else findings).append(h)

    key = lambda f: (f.path, f.line, f.col, f.code)
    return LintReport(findings=sorted(set(findings), key=key),
                      suppressed=sorted(set(suppressed), key=key),
                      files_scanned=len(modules), errors=errors)
