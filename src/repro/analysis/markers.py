"""Source-level markers consumed by reprolint (zero runtime behavior).

This module must stay dependency-free (no JAX, no numpy): it is imported
by hot serving code *and* by the linter's fixture corpus on machines where
only the stdlib exists.
"""
from __future__ import annotations

__all__ = ["hot_loop"]


def hot_loop(fn):
    """Mark ``fn`` as a device hot loop for static analysis.

    A no-op at runtime.  reprolint's RL003 (host-sync discipline) flags
    implicit device->host transfers — ``float()``/``int()``/``bool()``/
    ``.item()``/``np.asarray()``/``jax.device_get`` on device values —
    inside decorated functions, protecting contracts like the serving
    engine's one-sync-per-refinement invariant statically instead of only
    by call-count tests.  Host fetches must go through a ``*host_fetch``
    seam (see :func:`repro.serve.diffusion._host_fetch`).
    """
    fn.__reprolint_hot_loop__ = True
    return fn
