"""reprolint rules RL001-RL010: the repo's standing policies, mechanically.

Each rule enforces one policy from ROADMAP.md "Standing policies" (the rule
code is cross-referenced there and in README "Static analysis"):

* RL001 compat-drift          — drifted JAX APIs only through repro.compat
* RL002 engine-seam-ownership — Parareal math only in repro.core.engine,
                                frontier/window control only in repro.core.window
* RL003 host-sync-discipline  — no implicit device->host syncs inside
                                ``@hot_loop`` functions outside the
                                ``_host_fetch`` seam
* RL004 donation-after-use    — a buffer passed in a ``donate_argnums``
                                position of a jitted callable is dead; rule
                                flags later reads in the same function
* RL005 fused-path-gating     — Pallas dispatch via
                                ``kernels.ops.fused_default()`` /
                                ``engine.resolve_fused``, not ad-hoc
                                ``jax.default_backend() == "tpu"`` checks
* RL006 test-tier-markers     — subprocess-spawning / multi-device tests
                                carry ``slow``/``distributed`` markers
* RL007 tracked-artifacts     — build caches and dry-run outputs are never
                                tracked in git
* RL008 model-eval-seam       — drivers and the serving engine evaluate the
                                backbone only through the
                                ``repro.core.denoiser.Denoiser`` seam, never
                                by calling a bare ``model_fn(x, t)``
* RL009 accel-seam-ownership  — Anderson/secant mixing math (dense linalg
                                solves, gamma systems) lives only in
                                ``repro.core.accel``; drivers consume the
                                ``Accelerator`` seam
* RL010 kernel-tile-literals  — kernel tile/block/chunk sizes come from the
                                ``repro.kernels.tuning`` seam; hardcoded
                                integer tile kwargs (``block_q=32``) at call
                                sites outside ``repro.kernels`` are flagged

All rules are pure-AST (no JAX import anywhere in this package): they see
through import aliases via :func:`repro.analysis.core.qualname`, which is
what lets RL001 catch ``from jax import tree_map`` and ``from
jax.experimental import shard_map as sm`` — the false-negative class the
old ``check.sh`` grep shipped with.
"""
from __future__ import annotations

import ast
import os
import subprocess
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, ModuleInfo, module_rule,
                                 project_rule, qualname)


def _find(mod: ModuleInfo, node: ast.AST, code: str, rule: str,
          message: str) -> Finding:
    return Finding(code=code, message=message, path=mod.path,
                   line=getattr(node, "lineno", 1),
                   col=getattr(node, "col_offset", 0), rule=rule)


def _in(path: str, *suffixes: str) -> bool:
    p = path.replace(os.sep, "/")
    return any(p.endswith(s) for s in suffixes)


# ==========================================================================
# RL001 — compat drift
# ==========================================================================

# Exact drifted callables (resolved through the import graph).
_DRIFTED_EXACT = {
    "jax.tree_map": "repro.compat.tree.map",
    "jax.make_mesh": "repro.compat.make_mesh",
    "jax.shard_map": "repro.compat.shard_map",
    "jax.lax.axis_size": "repro.compat.axis_size",
}
# Legacy jax.tree_util spellings with a compat.tree equivalent.
_DRIFTED_TREE_UTIL = {
    "tree_map": "repro.compat.tree.map",
    "tree_map_with_path": "repro.compat.tree.map_with_path",
    "tree_flatten": "repro.compat.tree.flatten",
    "tree_unflatten": "repro.compat.tree.unflatten",
    "tree_leaves": "repro.compat.tree.leaves",
    "tree_structure": "repro.compat.tree.structure",
    "tree_all": "repro.compat.tree (extend the shim)",
    "tree_reduce": "repro.compat.tree (extend the shim)",
}
# Any touch of the legacy shard_map module is drifted (moved in 0.5).
_DRIFTED_PREFIXES = ("jax.experimental.shard_map",)

_RL001_ALLOWED = ("src/repro/compat.py",)


def _drifted_target(qn: Optional[str]) -> Optional[str]:
    if not qn:
        return None
    if qn in _DRIFTED_EXACT:
        return _DRIFTED_EXACT[qn]
    if qn.startswith("jax.tree_util."):
        leaf = qn.split(".")[-1]
        if leaf in _DRIFTED_TREE_UTIL:
            return _DRIFTED_TREE_UTIL[leaf]
    for pref in _DRIFTED_PREFIXES:
        if qn == pref or qn.startswith(pref + "."):
            return "repro.compat.shard_map"
    return None


@module_rule("RL001", "compat-drift",
             "drifted JAX APIs (shard_map/make_mesh/tree_map/cost_analysis/"
             "axis_size) called outside repro.compat")
def rl001_compat_drift(mod: ModuleInfo) -> Iterable[Finding]:
    if _in(mod.path, *_RL001_ALLOWED):
        return
    seen: Set[Tuple[int, int]] = set()

    def emit(node, qn, blessed):
        key = (node.lineno, node.col_offset)
        if key in seen:
            return None
        seen.add(key)
        return _find(mod, node, "RL001", "compat-drift",
                     f"drifted JAX API `{qn}` outside repro.compat — use "
                     f"`{blessed}` (ROADMAP standing policy: supported JAX "
                     f"range 0.4.x through >=0.5)")

    for node in ast.walk(mod.tree):
        # import statements that bind a drifted name (aliased or not)
        if isinstance(node, ast.Import):
            for a in node.names:
                blessed = _drifted_target(a.name)
                if blessed:
                    f = emit(node, a.name, blessed)
                    if f:
                        yield f
        elif isinstance(node, ast.ImportFrom) and not node.level:
            base = node.module or ""
            for a in node.names:
                full = f"{base}.{a.name}" if base else a.name
                blessed = _drifted_target(full) or _drifted_target(base)
                if blessed:
                    f = emit(node, full, blessed)
                    if f:
                        yield f
        # use sites: attribute chains and bare aliased names
        elif isinstance(node, ast.Attribute):
            qn = qualname(node, mod.aliases)
            blessed = _drifted_target(qn)
            if blessed:
                f = emit(node, qn, blessed)
                if f:
                    yield f
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            qn = mod.aliases.get(node.id)
            blessed = _drifted_target(qn)
            if blessed:
                f = emit(node, qn, blessed)
                if f:
                    yield f
        # `.cost_analysis()` drifted list[dict] -> dict: only the compat
        # wrapper may call the raw method
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "cost_analysis":
            qn = qualname(node.func, mod.aliases)
            if qn is None or not qn.startswith("repro.compat"):
                f = emit(node, "<compiled>.cost_analysis()",
                         "repro.compat.cost_analysis(compiled)")
                if f:
                    yield f


# ==========================================================================
# RL002 — engine-seam ownership
# ==========================================================================

_ENGINE = "repro.core.engine"
_WINDOW = "repro.core.window"
_RL002_OWNERS = ("src/repro/core/engine.py", "src/repro/core/window.py")
# kernels implement the update math itself (ref oracle + Pallas bodies)
_RL002_KERNEL_EXEMPT = "/repro/kernels/"

# Names whose *definition* outside the owner module is a re-derivation of
# the Parareal seam (ROADMAP: "Parareal math lives in exactly one module").
_ENGINE_OWNED_DEFS = frozenset({
    "parareal_update", "corrector_sweep", "coarse_init_sweep",
    "suffix_refinement", "run_parareal", "convergence_norm",
    "blockwise_norm", "still_refining", "has_converged", "prefix_frontier",
})

_FINE_TOKENS = ("y", "y_i", "yi", "fine")
_COARSE_TOKENS = ("cur", "prev", "coarse", "g_cur", "g_prev", "g_new",
                  "g_old", "gcur", "gprev")


def _leaf_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id.lower()
    if isinstance(node, ast.Attribute):
        return node.attr.lower()
    return None


def _is_fine(tok: str) -> bool:
    return tok in _FINE_TOKENS or "fine" in tok


def _is_coarse(tok: str) -> bool:
    return tok in _COARSE_TOKENS or "coarse" in tok or tok.startswith("g_")


def _parareal_shape(node: ast.BinOp) -> bool:
    """``a + b - c`` / ``a - b + c`` whose operand names spell the
    predictor-corrector update (one fine term, two coarse terms)."""
    ops: List[ast.AST] = []
    if isinstance(node.op, ast.Sub) and isinstance(node.left, ast.BinOp) \
            and isinstance(node.left.op, ast.Add):
        ops = [node.left.left, node.left.right, node.right]
    elif isinstance(node.op, ast.Add) and isinstance(node.right, ast.BinOp) \
            and isinstance(node.right.op, ast.Sub):
        ops = [node.left, node.right.left, node.right.right]
    else:
        return False
    toks = [_leaf_name(o) for o in ops]
    if any(t is None for t in toks):
        return False
    return (sum(1 for t in toks if _is_fine(t)) >= 1
            and sum(1 for t in toks if _is_coarse(t)) >= 2)


@module_rule("RL002", "engine-seam-ownership",
             "Parareal math / frontier control re-derived outside "
             "repro.core.engine / repro.core.window")
def rl002_engine_seam(mod: ModuleInfo) -> Iterable[Finding]:
    if _in(mod.path, *_RL002_OWNERS):
        return
    kernel_exempt = _RL002_KERNEL_EXEMPT in mod.path.replace(os.sep, "/")

    for node in ast.walk(mod.tree):
        # (a) private-helper access through the seam boundary
        if isinstance(node, ast.ImportFrom) and not node.level and \
                node.module in (_ENGINE, _WINDOW):
            for a in node.names:
                if a.name.startswith("_"):
                    yield _find(
                        mod, node, "RL002", "engine-seam-ownership",
                        f"private engine-seam helper `{node.module}."
                        f"{a.name}` imported outside its owner module — "
                        f"consume the public seam instead")
        elif isinstance(node, ast.Attribute) and node.attr.startswith("_"):
            qn = qualname(node, mod.aliases)
            if qn and (qn.startswith(_ENGINE + "._")
                       or qn.startswith(_WINDOW + "._")):
                yield _find(
                    mod, node, "RL002", "engine-seam-ownership",
                    f"private engine-seam helper `{qn}` referenced outside "
                    f"its owner module — consume the public seam instead")
        # (b) re-derivation by name: defining an engine-owned function
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name in _ENGINE_OWNED_DEFS and not kernel_exempt:
            yield _find(
                mod, node, "RL002", "engine-seam-ownership",
                f"`def {node.name}` outside repro.core.engine re-derives "
                f"the Parareal seam — import it from repro.core.engine "
                f"(ROADMAP: Parareal math lives in exactly one module)")
        # (c) re-implementation of parareal_update by expression shape
        elif isinstance(node, ast.BinOp) and not kernel_exempt and \
                _parareal_shape(node):
            yield _find(
                mod, node, "RL002", "engine-seam-ownership",
                "predictor-corrector update re-derived by shape "
                "(`fine + G_cur - G_prev`) — call "
                "repro.core.engine.parareal_update instead")


# ==========================================================================
# RL003 — host-sync discipline inside @hot_loop
# ==========================================================================

_HOST_MODULES = ("np", "numpy", "math")
_HOST_BUILTINS = frozenset({
    "len", "min", "max", "sum", "sorted", "enumerate", "range", "list",
    "tuple", "dict", "set", "zip", "abs", "any", "all", "str", "repr",
    "print", "isinstance", "getattr", "hasattr", "float", "int", "bool",
    "round", "divmod", "reversed", "map", "filter",
})
_DEVICE_MODULES = ("jnp", "jax", "lax")
_CONVERTERS = frozenset({"float", "int", "bool"})
_NP_CONVERTERS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                            "numpy.array"})


def _is_hot_loop(dec: ast.AST, aliases: Dict[str, str]) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    qn = qualname(target, aliases)
    return bool(qn) and qn.split(".")[-1] == "hot_loop"


def _is_host_fetch(func: ast.AST, aliases: Dict[str, str]) -> bool:
    qn = qualname(func, aliases)
    return bool(qn) and qn.split(".")[-1].endswith("host_fetch")


def _target_keys(node: ast.AST) -> List[str]:
    """Assignment-target taint keys: plain names and `self.x`-style dotted
    attributes (the serving engine mutates device state through self)."""
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        base = _expr_key(node)
        return [base] if base else []
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in node.elts:
            out.extend(_target_keys(e))
        return out
    if isinstance(node, ast.Starred):
        return _target_keys(node.value)
    return []


def _expr_key(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Taint:
    """Forward host/device taint over one @hot_loop function body.

    Conservative in the device direction: a call whose callee isn't a known
    host producer (numpy/math/builtins/`_host_fetch`) and takes no
    host-tainted argument is assumed to return device values — exactly the
    posture that protects the one-sync-per-refinement contract."""

    def __init__(self, aliases: Dict[str, str]):
        self.aliases = aliases
        self.host: Set[str] = set()
        self.device: Set[str] = set()

    def classify(self, node: ast.AST) -> str:           # host|device|unknown
        if isinstance(node, ast.Constant):
            return "host"
        if isinstance(node, (ast.List, ast.Tuple, ast.Set, ast.Dict,
                             ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp, ast.JoinedStr)):
            return "host"
        key = _expr_key(node)
        if key is not None:
            if key in self.device:
                return "device"
            if key in self.host:
                return "host"
            # attribute of a tainted base inherits the base's taint
            parts = key.split(".")
            for i in range(len(parts) - 1, 0, -1):
                base = ".".join(parts[:i])
                if base in self.device:
                    return "device"
                if base in self.host:
                    return "host"
            return "unknown"
        if isinstance(node, ast.Subscript):
            return self.classify(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.classify(node.operand)
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare, ast.IfExp)):
            kids = [self.classify(k) for k in ast.iter_child_nodes(node)
                    if isinstance(k, ast.expr)]
            if "device" in kids:
                return "device"
            if kids and all(k == "host" for k in kids):
                return "host"
            return "unknown"
        if isinstance(node, ast.Call):
            return self.classify_call(node)
        return "unknown"

    def classify_call(self, node: ast.Call) -> str:
        if _is_host_fetch(node.func, self.aliases):
            return "host"
        qn = qualname(node.func, self.aliases)
        root = qn.split(".")[0] if qn else None
        args = list(node.args) + [kw.value for kw in node.keywords]
        arg_taints = [self.classify(a) for a in args]
        if root in _HOST_MODULES or \
                (isinstance(node.func, ast.Name)
                 and node.func.id in _HOST_BUILTINS):
            return "host"
        # a host-side method of a host object stays host
        if isinstance(node.func, ast.Attribute) and \
                self.classify(node.func.value) == "host":
            return "host"
        if root in _DEVICE_MODULES or (qn and qn.startswith("jax.")):
            return "device"
        # pragmatic: feeding a host value in marks the result host (the
        # serving engine's `policy.advance(lo, fetched_block_resid, B)`)
        if "host" in arg_taints:
            return "host"
        return "device"

    def assign(self, targets: Sequence[ast.AST], value: ast.AST) -> None:
        taint = self.classify(value)
        for t in targets:
            for key in _target_keys(t):
                self.host.discard(key)
                self.device.discard(key)
                if taint == "host":
                    self.host.add(key)
                elif taint == "device":
                    self.device.add(key)


def _iter_stmts(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
    """Statements of one scope in source order, recursing into compound
    statements but NOT into nested function/class scopes."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                yield from _iter_stmts(sub)
        for h in getattr(stmt, "handlers", []) or []:
            yield from _iter_stmts(h.body)


def _stmt_exprs(stmt: ast.stmt) -> Iterable[ast.AST]:
    """The expressions evaluated by ``stmt`` itself (compound statements
    contribute only their headers — their bodies are separate statements)."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        yield stmt.target
        yield stmt.iter
    elif isinstance(stmt, (ast.While, ast.If)):
        yield stmt.test
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            yield item.context_expr
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        for d in stmt.decorator_list:
            yield d
    elif isinstance(stmt, ast.Try):
        return
    else:
        yield stmt


@module_rule("RL003", "host-sync-discipline",
             "implicit device->host sync inside a @hot_loop function "
             "outside the _host_fetch seam")
def rl003_host_sync(mod: ModuleInfo) -> Iterable[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not any(_is_hot_loop(d, mod.aliases) for d in node.decorator_list):
            continue
        taint = _Taint(mod.aliases)
        for stmt in _iter_stmts(node.body):
            # flag sync-inducing calls in this statement first (reads
            # happen before the statement's own stores take effect)
            for expr in _stmt_exprs(stmt):
                for sub in ast.walk(expr):
                    if isinstance(sub, ast.Call):
                        yield from _rl003_check_call(mod, sub, taint)
            if isinstance(stmt, ast.Assign):
                taint.assign(stmt.targets, stmt.value)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                taint.assign([stmt.target], stmt.value)
            elif isinstance(stmt, ast.AugAssign):
                taint.assign([stmt.target], stmt.value)
            elif isinstance(stmt, ast.For):
                taint.assign([stmt.target], stmt.iter)


def _rl003_check_call(mod: ModuleInfo, call: ast.Call,
                      taint: _Taint) -> Iterable[Finding]:
    qn = qualname(call.func, mod.aliases)
    # device_get anywhere in a hot loop bypasses the blessed seam
    if qn and qn.split(".")[-1] == "device_get":
        yield _find(mod, call, "RL003", "host-sync-discipline",
                    "`jax.device_get` inside a @hot_loop — route the "
                    "fetch through the blessed `_host_fetch` seam (one "
                    "sync per refinement)")
        return
    args = call.args
    if isinstance(call.func, ast.Name) and call.func.id in _CONVERTERS \
            and len(args) == 1:
        if taint.classify(args[0]) == "device":
            yield _find(mod, call, "RL003", "host-sync-discipline",
                        f"`{call.func.id}()` of a device value inside a "
                        f"@hot_loop forces an implicit sync — fetch through "
                        f"`_host_fetch` once per refinement instead")
    elif qn in _NP_CONVERTERS and args:
        if taint.classify(args[0]) == "device":
            yield _find(mod, call, "RL003", "host-sync-discipline",
                        f"`{qn}()` of a device value inside a @hot_loop "
                        f"forces an implicit sync — fetch through "
                        f"`_host_fetch` once per refinement instead")
    elif isinstance(call.func, ast.Attribute) and call.func.attr == "item" \
            and not args:
        if taint.classify(call.func.value) == "device":
            yield _find(mod, call, "RL003", "host-sync-discipline",
                        "`.item()` on a device value inside a @hot_loop "
                        "forces an implicit sync — fetch through "
                        "`_host_fetch` once per refinement instead")


# ==========================================================================
# RL004 — donation-after-use
# ==========================================================================

def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Literal donate_argnums of a jax.jit(...) call (None when dynamic)."""
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, int)
                    for e in v.elts):
                return tuple(e.value for e in v.elts)
            return None   # dynamic (e.g. self._donate): not statically known
    return None


def _is_jit(func: ast.AST, aliases: Dict[str, str]) -> bool:
    qn = qualname(func, aliases)
    return bool(qn) and qn.split(".")[-1] in ("jit", "pjit")


def _module_donated(mod: ModuleInfo) -> Dict[str, Tuple[int, ...]]:
    """Functions donated via decorator: @jax.jit(donate_argnums=...) or
    @functools.partial(jax.jit, donate_argnums=...)."""
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            is_partial_jit = (
                qualname(dec.func, mod.aliases) in
                ("functools.partial", "partial")
                and dec.args and _is_jit(dec.args[0], mod.aliases))
            if _is_jit(dec.func, mod.aliases) or is_partial_jit:
                pos = _donate_positions(dec)
                if pos:
                    out[node.name] = pos
    return out


@module_rule("RL004", "donation-after-use",
             "buffer passed in a donate_argnums position and read "
             "afterwards in the same function")
def rl004_donation(mod: ModuleInfo) -> Iterable[Finding]:
    decorated = _module_donated(mod)
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from _rl004_scan_scope(mod, list(node.body), decorated)
    yield from _rl004_scan_scope(mod, list(mod.tree.body), decorated)


def _rl004_scan_scope(mod: ModuleInfo, body: List[ast.stmt],
                      decorated: Dict[str, Tuple[int, ...]]
                      ) -> Iterable[Finding]:
    """Linear forward scan of one scope: record jit-with-donation bindings,
    mark donated argument names dead at each call, flag loads of dead names,
    resurrect names on store (``x, s = step(x, y)`` is the safe idiom)."""
    donated: Dict[str, Tuple[int, ...]] = dict(decorated)
    dead: Dict[str, int] = {}       # donated name -> donating call's line

    def stores_of(stmt: ast.stmt) -> Set[str]:
        out: Set[str] = set()
        for t in getattr(stmt, "targets", []) or []:
            out.update(_target_keys(t))
        if isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
            out.update(_target_keys(stmt.target))
        return out

    for stmt in _iter_stmts(body):
        # record jitted-with-donation callables bound in this scope
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call) \
                    and _is_jit(sub.value.func, mod.aliases):
                pos = _donate_positions(sub.value)
                if pos:
                    for key in _target_keys(sub.targets[0]):
                        donated[key] = pos

        # loads of already-dead names: dead was filled by EARLIER
        # statements, so the donating statement's own arg use never
        # self-flags — but passing a dead buffer to a second call does
        for expr in _stmt_exprs(stmt):
            for sub in ast.walk(expr):
                if isinstance(sub, (ast.Name, ast.Attribute)) and \
                        isinstance(getattr(sub, "ctx", None), ast.Load):
                    key = _expr_key(sub)
                    if key in dead:
                        yield _find(
                            mod, sub, "RL004", "donation-after-use",
                            f"`{key}` was donated to a jitted callable "
                            f"(donate_argnums) at line {dead[key]} and read "
                            f"afterwards — donated buffers are dead; rebind "
                            f"the result (`x, ... = fn(x, ...)`) or drop "
                            f"the donation")
                        dead.pop(key, None)   # one report per donation

        # this statement's donating calls mark their args dead ...
        for expr in _stmt_exprs(stmt):
            for sub in ast.walk(expr):
                if isinstance(sub, ast.Call):
                    callee = _expr_key(sub.func)
                    if callee in donated:
                        for i in donated[callee]:
                            if i < len(sub.args):
                                key = _expr_key(sub.args[i])
                                if key is not None:
                                    dead[key] = sub.lineno
        # ... and its stores resurrect rebound names
        for key in stores_of(stmt):
            dead.pop(key, None)


# ==========================================================================
# RL005 — fused-path gating
# ==========================================================================

_RL005_ALLOWED = ("src/repro/kernels/ops.py", "src/repro/compat.py")


@module_rule("RL005", "fused-path-gating",
             "direct backend/platform string check gating the Pallas path "
             "instead of kernels.ops.fused_default()/engine.resolve_fused")
def rl005_fused_gating(mod: ModuleInfo) -> Iterable[Finding]:
    if _in(mod.path, *_RL005_ALLOWED):
        return

    def const_strs(n: ast.AST) -> List[str]:
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            return [n.value]
        if isinstance(n, (ast.Tuple, ast.List, ast.Set)):
            out: List[str] = []
            for e in n.elts:
                out.extend(const_strs(e))
            return out
        return []

    def is_backend_probe(n: ast.AST) -> bool:
        if isinstance(n, ast.Call):
            qn = qualname(n.func, mod.aliases)
            return bool(qn) and qn.split(".")[-1] in (
                "default_backend", "get_backend")
        if isinstance(n, ast.Attribute) and n.attr == "platform":
            return True
        return False

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if not any(is_backend_probe(s) for s in sides):
            continue
        strs: List[str] = []
        for s in sides:
            strs.extend(const_strs(s))
        if "tpu" in strs:
            yield _find(
                mod, node, "RL005", "fused-path-gating",
                "backend==\"tpu\" string check gates the fused Pallas path "
                "— use repro.kernels.ops.fused_default() / "
                "repro.core.engine.resolve_fused(None) so dispatch policy "
                "lives in one place (ROADMAP item 5: GPU parity)")


# ==========================================================================
# RL006 — test-tier markers
# ==========================================================================

_SUBPROCESS_FUNCS = frozenset({"run", "Popen", "call", "check_call",
                               "check_output"})
_TIER_MARKS = frozenset({"slow", "distributed"})


def _marks_of(exprs: Sequence[ast.AST], aliases: Dict[str, str]) -> Set[str]:
    marks: Set[str] = set()
    for e in exprs:
        target = e.func if isinstance(e, ast.Call) else e
        qn = qualname(target, aliases)
        if qn and qn.startswith("pytest.mark."):
            marks.add(qn.split(".")[2])
    return marks


def _mesh_devices(call: ast.Call) -> int:
    """Literal device count of a make_mesh((a, b, ...), ...) call, or 0."""
    if not call.args:
        return 0
    shape = call.args[0]
    if isinstance(shape, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, int)
            for e in shape.elts):
        n = 1
        for e in shape.elts:
            n *= e.value
        return n
    if isinstance(shape, ast.Constant) and isinstance(shape.value, int):
        return shape.value
    return 0


def _rl006_trigger(fn: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    # a test taking the `monkeypatch` fixture and building a mesh is
    # presumed to be faking the mesh constructor (compat-branch tests do
    # exactly this) — subprocess spawns can't be faked that way and are
    # still flagged
    fakes_mesh = any(a.arg == "monkeypatch"
                     for a in getattr(fn, "args").args)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        qn = qualname(node.func, aliases)
        leaf = qn.split(".")[-1] if qn else None
        if leaf == "run_subprocess":
            return "spawns a subprocess (run_subprocess)"
        if qn and qn.startswith("subprocess.") and \
                leaf in _SUBPROCESS_FUNCS:
            return f"spawns a subprocess ({qn})"
        if leaf == "make_mesh" and _mesh_devices(node) > 1 \
                and not fakes_mesh:
            return f"builds a {_mesh_devices(node)}-device mesh"
    return None


@module_rule("RL006", "test-tier-markers",
             "subprocess-spawning or multi-device tests must carry "
             "slow/distributed markers so check.sh --fast stays honest")
def rl006_test_tiers(mod: ModuleInfo) -> Iterable[Finding]:
    if not mod.is_test_file:
        return
    module_marks: Set[str] = set()
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "pytestmark"
                for t in node.targets):
            vals = node.value.elts if isinstance(
                node.value, (ast.List, ast.Tuple)) else [node.value]
            module_marks |= _marks_of(vals, mod.aliases)
    if module_marks & _TIER_MARKS:
        return

    def check_fn(fn, extra_marks: Set[str]):
        if not fn.name.startswith("test"):
            return
        marks = extra_marks | _marks_of(fn.decorator_list, mod.aliases)
        if marks & _TIER_MARKS:
            return
        why = _rl006_trigger(fn, mod.aliases)
        if why:
            yield _find(
                mod, fn, "RL006", "test-tier-markers",
                f"`{fn.name}` {why} but carries no slow/distributed "
                f"marker — the check.sh --fast tier would run it "
                f"(register intent with @pytest.mark.slow / "
                f"@pytest.mark.distributed)")

    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from check_fn(node, set())
        elif isinstance(node, ast.ClassDef):
            cls_marks = _marks_of(node.decorator_list, mod.aliases)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from check_fn(sub, cls_marks)


# ==========================================================================
# RL007 — tracked build/experiment artifacts (project rule)
# ==========================================================================

def artifact_violations(tracked: Iterable[str]) -> List[str]:
    """Offending paths among an iterable of tracked repo paths (the pure
    core of RL007 — unit-testable without git)."""
    bad: List[str] = []
    for p in tracked:
        parts = p.replace(os.sep, "/").split("/")
        if "__pycache__" in parts or ".pytest_cache" in parts \
                or p.endswith(".pyc") \
                or p.replace(os.sep, "/").startswith("experiments/dryrun"):
            bad.append(p)
    return bad


def _git_tracked(root: str) -> Optional[List[str]]:
    try:
        out = subprocess.run(["git", "-C", root, "ls-files"],
                             capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.splitlines()


@project_rule("RL007", "tracked-artifacts",
              "build caches (__pycache__/.pyc/.pytest_cache) and dry-run "
              "experiment outputs must never be tracked in git")
def rl007_artifacts(root: str, modules) -> Iterable[Finding]:
    tracked = _git_tracked(root)
    if tracked is None:       # not a git checkout: nothing to assert
        return
    for p in artifact_violations(tracked):
        # message preserved from the scripts/check.sh shell-grep era
        yield Finding(
            code="RL007", rule="tracked-artifacts", path=p, line=1, col=0,
            message="artifact lint FAILED: build/experiment artifacts are "
                    "tracked in git — git rm --cached it and keep "
                    ".gitignore covering the pattern")


# ==========================================================================
# RL008 — model-eval seam (drivers/serve call the Denoiser, not model_fn)
# ==========================================================================

# Only drivers and the serving engine are in scope — models may of course
# call their own forward, and tests/benchmarks call whatever they probe.
# Fixture files keep the rule's natural scope by name (the RL006 precedent:
# naming places a fixture inside the scope the rule derives structurally).
_RL008_SCOPES = ("src/repro/core/", "src/repro/serve/")
# solvers.py is the seam's one consumer (it receives the eval callable the
# driver composed); denoiser.py is the seam itself.
_RL008_ALLOWED = ("src/repro/core/solvers.py", "src/repro/core/denoiser.py")


def _rl008_in_scope(path: str) -> bool:
    p = path.replace(os.sep, "/")
    if any(s in p for s in _RL008_SCOPES):
        return True
    return os.path.basename(p).startswith("rl008")


@module_rule("RL008", "model-eval-seam",
             "direct model_fn(x, t)-shaped call in a driver or the serving "
             "engine instead of the repro.core.denoiser.Denoiser seam")
def rl008_model_eval_seam(mod: ModuleInfo) -> Iterable[Finding]:
    if not _rl008_in_scope(mod.path) or _in(mod.path, *_RL008_ALLOWED):
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _leaf_name(node.func)
        if leaf is None or "model_fn" not in leaf:
            continue
        if len(node.args) != 2 or any(
                isinstance(a, ast.Starred) for a in node.args):
            continue
        yield _find(
            mod, node, "RL008", "model-eval-seam",
            f"direct model eval `{leaf}(x, t)` outside the Denoiser seam — "
            f"adapt via repro.core.denoiser.as_denoiser and evaluate "
            f"through the Denoiser (standalone call, .inner_eval() inside "
            f"a driver shard_map, or .shard_eval() under denoiser_spec) so "
            f"time/data/model parallelism compose driver-free")


# ==========================================================================
# RL009 — accel-seam ownership (mixing math lives in repro.core.accel)
# ==========================================================================

# Same scope story as RL008: only drivers and the serving engine must
# consume the Accelerator seam — models/tests/benchmarks do whatever they
# probe.  Fixture files opt into the scope by name (the RL006/RL008
# precedent).
_ACCEL = "repro.core.accel"
_RL009_OWNER = ("src/repro/core/accel.py",)
_RL009_SCOPES = ("src/repro/core/", "src/repro/serve/")
# Names whose *definition* outside the owner is a re-derivation of the
# mixing seam (leading underscores stripped before matching).
_ACCEL_OWNED_DEFS = frozenset({"resolve_accel", "solve_gamma",
                               "anderson_mix"})
# Dense linear-algebra entry points: the secant/normal-equations solve is
# the acceleration seam's signature — no other core/serve module does
# dense linalg (frontier control, sweeps and solvers are all elementwise
# or reductions).
_LINALG_SOLVERS = frozenset({"solve", "lstsq", "inv", "pinv", "cholesky",
                             "qr", "svd"})


def _rl009_in_scope(path: str) -> bool:
    p = path.replace(os.sep, "/")
    if any(s in p for s in _RL009_SCOPES):
        return True
    return os.path.basename(p).startswith("rl009")


@module_rule("RL009", "accel-seam-ownership",
             "Anderson/secant mixing math (dense linalg solves, gamma "
             "systems) re-derived outside repro.core.accel")
def rl009_accel_seam(mod: ModuleInfo) -> Iterable[Finding]:
    if not _rl009_in_scope(mod.path) or _in(mod.path, *_RL009_OWNER):
        return
    for node in ast.walk(mod.tree):
        # (a) private-helper access across the seam boundary
        if isinstance(node, ast.ImportFrom) and not node.level and \
                node.module == _ACCEL:
            for a in node.names:
                if a.name.startswith("_"):
                    yield _find(
                        mod, node, "RL009", "accel-seam-ownership",
                        f"private accel-seam helper `{_ACCEL}.{a.name}` "
                        f"imported outside its owner module — consume the "
                        f"public seam (Accelerator.apply/init_state/"
                        f"reset_lanes, resolve_accel) instead")
        elif isinstance(node, ast.Attribute) and node.attr.startswith("_"):
            qn = qualname(node, mod.aliases)
            if qn and qn.startswith(_ACCEL + "._"):
                yield _find(
                    mod, node, "RL009", "accel-seam-ownership",
                    f"private accel-seam helper `{qn}` referenced outside "
                    f"its owner module — consume the public seam instead")
        # (b) re-derivation by name: defining a seam-owned function
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name.lstrip("_") in _ACCEL_OWNED_DEFS:
            yield _find(
                mod, node, "RL009", "accel-seam-ownership",
                f"`def {node.name}` outside repro.core.accel re-derives "
                f"the acceleration seam — import it from repro.core.accel "
                f"(mixing math lives in exactly one module)")
        # (c) re-implementation by shape: a dense least-squares/secant
        # solve in a driver or the serving engine IS mixing math
        elif isinstance(node, ast.Call):
            qn = qualname(node.func, mod.aliases)
            if qn and ".linalg." in qn and \
                    qn.split(".")[-1] in _LINALG_SOLVERS:
                yield _find(
                    mod, node, "RL009", "accel-seam-ownership",
                    f"dense linear-algebra solve `{qn}` in a driver/serve "
                    f"module — Anderson/secant mixing math belongs to "
                    f"repro.core.accel; select an Accelerator "
                    f"(SRDSConfig(accel=...)) and let the engine apply it")


# ==========================================================================
# RL010 — kernel tile literals (launch sizes come from the tuning seam)
# ==========================================================================

# Kernel launch-shape kwargs owned by repro.kernels.tuning.  A hardcoded
# integer for any of these at a call site outside the kernels package is a
# size that silently stops tracking the tuner's per-backend tables — the
# exact drift the seam exists to prevent.  Names passed *as variables*
# (resolved configs, sweep candidates) are fine; only integer literals are
# flagged.
_RL010_TILE_KWARGS = frozenset({"block_q", "block_k", "block_rows",
                                "tile_rows", "chunk", "chunk_target",
                                "num_warps", "num_stages"})
# The kernels package is the seam's owner: its heuristics, wrappers and
# raw pallas_call entry points ARE where the defaults live.
_RL010_OWNER = "src/repro/kernels/"


def _rl010_exempt(path: str) -> bool:
    return _RL010_OWNER in path.replace(os.sep, "/")


def _int_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and \
            not isinstance(node.value, bool)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _int_literal(node.operand)
    return False


@module_rule("RL010", "kernel-tile-literals",
             "hardcoded kernel tile/block/chunk integer kwarg outside "
             "repro.kernels — sizes come from the tuning seam")
def rl010_tile_literals(mod: ModuleInfo) -> Iterable[Finding]:
    if _rl010_exempt(mod.path):
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg in _RL010_TILE_KWARGS and _int_literal(kw.value):
                yield _find(
                    mod, node, "RL010", "kernel-tile-literals",
                    f"hardcoded kernel tile size `{kw.arg}=...` at a call "
                    f"site outside repro.kernels — launch shapes resolve "
                    f"through repro.kernels.tuning (pass tuner= / "
                    f"KernelTuner(overrides=...) so per-backend tables "
                    f"and heuristics stay authoritative)")
