"""``repro.analysis`` — reprolint, the SRDS stack's AST invariant checker.

An import-graph-aware static analysis pass enforcing the repo's standing
policies (ROADMAP.md) as per-finding rule codes RL001-RL010, replacing the
grep pipelines that used to live in ``scripts/check.sh``:

==========  ======================  =============================================
code        rule                    policy
==========  ======================  =============================================
RL001       compat-drift            drifted JAX APIs only via ``repro.compat``
RL002       engine-seam-ownership   Parareal math only in ``repro.core.engine``;
                                    frontier control only in ``repro.core.window``
RL003       host-sync-discipline    no implicit device->host syncs in ``@hot_loop``
RL004       donation-after-use      donated buffers are dead after the call
RL005       fused-path-gating       Pallas dispatch via ``fused_default()``
RL006       test-tier-markers       subprocess/multi-device tests marked slow/distributed
RL007       tracked-artifacts       no build caches / dryrun outputs in git
RL008       model-eval-seam         backbone evals only through the ``Denoiser`` seam
RL009       accel-seam-ownership    mixing math only in ``repro.core.accel``
RL010       kernel-tile-literals    tile/block sizes via ``repro.kernels.tuning``
==========  ======================  =============================================

Run ``python -m repro.analysis [paths...]`` (text or ``--format=json``);
suppress a finding inline with ``# reprolint: disable=RL001`` (same line or
a standalone comment directly above), or file-wide with
``# reprolint: disable-file=RL001``.

The package is deliberately **stdlib-only** — it imports neither JAX nor
numpy — so the lint gate runs as a dependency-free CI leg and on any
developer box, installed environment or not.
"""
from repro.analysis.core import (DEFAULT_PATHS, Finding, LintReport,
                                 lint_paths, rule_table)
from repro.analysis.markers import hot_loop

__all__ = ["Finding", "LintReport", "lint_paths", "rule_table", "hot_loop",
           "DEFAULT_PATHS"]
