from .async_loop import AsyncServeLoop
from .clock import Clock, MonotonicClock, VirtualClock
from .diffusion import (CompletionRecord, DiffusionSamplingEngine,
                        IterationEMA, SampleRequest, SampleResponse)
from .engine import Request, ServingEngine, make_decode_fn, make_prefill_fn
from .scheduler import (EDF, FIFO, CostAware, Policy, SimReport, Tier,
                        bursty_trace, poisson_trace, simulate)
