from .diffusion import (DiffusionSamplingEngine, SampleRequest,
                        SampleResponse)
from .engine import Request, ServingEngine, make_decode_fn, make_prefill_fn
