"""The real-time asynchronous serving loop: host scheduling overlapped
with device compute.

:func:`repro.serve.scheduler.simulate` is the *synchronous*
discrete-event driver: each refinement dispatches its step program and
immediately blocks on the ``(K,)``/``(K+B,)`` residual fetch, so the
device idles while the host runs admission, eviction and bookkeeping.
That is the right shape for bit-deterministic virtual-clock studies —
and the wrong one for wall-clock latency, where every microsecond the
device waits on the host is lost p95.

:class:`AsyncServeLoop` closes the gap with a **pipelined**
dispatch/resolve cycle over the engine's split hot loop
(:meth:`~repro.serve.diffusion.DiffusionSamplingEngine.step_dispatch` /
:meth:`~repro.serve.diffusion.DiffusionSamplingEngine.step_resolve`):

1. run the admission round (policy rejection, preemption, slot filling);
2. **dispatch** the next refinement's step program — JAX's asynchronous
   dispatch returns immediately with device futures;
3. **resolve** the *oldest* still-unresolved refinement — the host
   blocks on that one residual fetch while the device is already
   executing the step dispatched in (2).

So the fetch that used to serialize host and device now overlaps the
next refinement's compute, on a single host thread: no locks, no
executor, and the one-sync-per-refinement contract (reprolint RL003)
holds unchanged — dispatch performs zero syncs, resolve performs exactly
the one residual fetch.

The price of speculation is bounded and never observable: when a
refinement's fetch reveals a lane converged, the *next* refinement was
already dispatched with that lane still active.  That extra refinement
is wasted device work (charged physically, never effectively), but the
lane's completed sample is cut from the resolved step's own final-block
snapshot, so every response is bit-identical to what the synchronous
engine returns — on a virtual clock the async loop reproduces
``simulate()``'s samples and iteration counts exactly (asserted in
``tests/test_async_serve.py``).

The loop is clock-agnostic (:mod:`repro.serve.clock`): on the default
:class:`~repro.serve.clock.VirtualClock` it is a deterministic test
harness for the pipelined path; on a
:class:`~repro.serve.clock.MonotonicClock` it is the real-time serving
loop — arrivals become visible as wall time passes, idle waits really
sleep, latency/SLO stamps read real seconds, and wall deadlines
(``SampleRequest.deadline_wall``) drive EDF ordering, CostAware
admission rejection and mid-flight eviction through
``engine.request_deadline``.  ``benchmarks/table10_wallclock.py`` is the
wall-clock twin of ``table10_slo.py`` built on this loop.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serve.diffusion import (DiffusionSamplingEngine, SampleRequest,
                                   SampleResponse)
from repro.serve.scheduler import FIFO, Policy, SimReport, build_report

__all__ = ["AsyncServeLoop"]


class AsyncServeLoop:
    """Pipelined serving driver over one engine and one admission policy.

    The policy interface is exactly :class:`repro.serve.scheduler.
    Policy` — FIFO/EDF/CostAware (and any user policy) run unmodified in
    both the synchronous simulator and this loop; only the stepping
    discipline differs.  ``max_inflight`` bounds the dispatched-but-
    unresolved refinements per micro-batch (2 = dispatch the next step
    while the previous fetch is in flight; 1 degenerates to the
    synchronous discipline, useful for A/B-ing the overlap itself).
    """

    def __init__(self, engine: DiffusionSamplingEngine,
                 policy: Optional[Policy] = None, max_inflight: int = 2):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.engine = engine
        self.policy = policy if policy is not None else FIFO()
        self.max_inflight = max_inflight

    def run(self, trace: Sequence[SampleRequest]) -> SimReport:
        """Serve ``trace`` to completion; returns the same
        :class:`~repro.serve.scheduler.SimReport` shape ``simulate()``
        produces, with latencies in the engine clock's seconds (real
        ones under a wall clock).

        Requests become visible at their ``arrival_time`` on the
        engine's clock — under a wall clock that means genuinely waiting
        for them (an idle loop sleeps to the next arrival; a loaded one
        discovers them as refinements resolve).  Between refinements the
        policy may reject waiting requests (e.g. a ``deadline_wall``
        already hopeless at admission — evaluated lazily when the policy
        selects them for a free slot, see the inline note) and evict
        running ones whose wall deadline passed mid-refinement.  Engine
        metrics are reset first,
        so back-to-back runs on one warm engine are independent.
        """
        engine, policy = self.engine, self.policy
        engine.reset_metrics()

        pending: List[Tuple[int, SampleRequest]] = \
            [(engine.submit(r), r)
             for r in sorted(trace, key=lambda r: r.arrival_time)]
        submitted = [rid for rid, _ in pending]
        engine.pull_queue()       # the loop owns admission, not drain()
        first_arrival = pending[0][1].arrival_time if pending else 0.0
        engine.advance_clock(first_arrival)

        waiting: List[Tuple[int, SampleRequest]] = []
        responses: Dict[int, SampleResponse] = {}
        rejected: List[int] = []
        preempted: List[int] = []
        running: Dict[int, SampleRequest] = {}
        outstanding: Deque = deque()      # unresolved tokens, oldest first

        def arrivals(now: float) -> None:
            while pending and pending[0][1].arrival_time <= now:
                waiting.append(pending.pop(0))

        while pending or waiting or engine.busy() or outstanding:
            now = engine.clock
            arrivals(now)

            # ---- preemption round (policy-driven; wall-deadline eviction
            # fires here, between refinements, even mid-pipeline: the
            # evicted lane's still-in-flight refinement resolves as
            # speculative waste) ----
            victims = policy.preempt_victims(now, sorted(running.items()),
                                             waiting, engine)
            for rid in victims:
                engine.evict(rid)
                preempted.append(rid)
                del running[rid]

            # ---- admission control + slot filling ----
            # Rejection is evaluated lazily, at selection time, rather
            # than scanning the whole waiting set every round the way
            # simulate() does.  The shedding decisions are the same ones
            # (a request is only ever served through admission, and a
            # hopeless request is at least as hopeless when its slot
            # finally opens), but the cost-model work (CostAware's
            # predict_completion per waiter) runs O(admissions) instead of
            # O(rounds x waiters) — on a wall clock that host time is real
            # and would otherwise sit on the pipelined critical path.
            while True:
                admissible = [i for i, (rid, req) in enumerate(waiting)
                              if engine.free_slots(req) > 0]
                if not admissible:
                    break
                sub = [waiting[i] for i in admissible]
                j = policy.select(now, sub, engine)
                if j is None:
                    break
                rid, req = waiting.pop(admissible[j])
                if policy.reject(now, rid, req, engine):
                    rejected.append(rid)
                    continue
                engine.admit(rid, req)
                running[rid] = req

            # ---- the overlap: dispatch the next refinement BEFORE
            # blocking on the previous one's residual fetch ----
            tok = engine.step_dispatch(max_inflight=self.max_inflight)
            if tok is not None:
                outstanding.append(tok)
            if outstanding and (tok is None or len(outstanding) > 1):
                # the device is (or just started) computing the younger
                # step(s); this fetch runs concurrently with them
                for rid, resp in engine.step_resolve(outstanding.popleft()):
                    responses[rid] = resp
                    running.pop(rid, None)
                continue
            if tok is not None:
                continue          # pipeline still filling — keep priming

            # nothing dispatched, nothing to resolve
            if waiting:
                if pending:
                    # the policy is holding back (legal — e.g. waiting to
                    # co-batch); wait for the arrival that may unblock it
                    engine.advance_clock(pending[0][1].arrival_time)
                    continue
                raise RuntimeError(
                    f"policy {policy.name!r} admitted nothing on an idle "
                    f"engine")
            if pending:
                # idle: wait (really sleep, on a wall clock) to the next
                # arrival
                engine.advance_clock(pending[0][1].arrival_time)

        return build_report(policy, responses, rejected, preempted,
                            submitted, engine, first_arrival)
