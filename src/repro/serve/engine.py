"""Serving: jitted prefill/decode step factories + a batched engine.

Decode-cache distribution follows the flash-decoding layout injected by
repro.parallel.sharding (KV sequence sharded over ``model``): the decode
einsums contract over the sharded sequence dim, so GSPMD lowers them to
local partial attention + tiny (B,H)-sized all-reduces — verified against
the compiled HLO in the dry-run (no KV all-gather; see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import (LOCAL, ParallelCtx, decode_step,
                                      make_dense_cache, prefill)


def make_prefill_fn(cfg: ArchConfig, parallel: ParallelCtx = LOCAL,
                    in_shardings=None, out_shardings=None, use_kernel=None):
    def fn(params, batch):
        return prefill(cfg, params, batch, parallel=parallel,
                       use_kernel=use_kernel)

    return jax.jit(fn, in_shardings=in_shardings, out_shardings=out_shardings)


def make_decode_fn(cfg: ArchConfig, parallel: ParallelCtx = LOCAL,
                   in_shardings=None, out_shardings=None, use_kernel=None,
                   donate_cache: bool = True):
    def fn(params, token_batch, cache, pos):
        return decode_step(cfg, params, token_batch, cache, pos,
                           parallel=parallel, use_kernel=use_kernel)

    donate = (2,) if donate_cache else ()
    return jax.jit(fn, donate_argnums=donate, in_shardings=in_shardings,
                   out_shardings=out_shardings)


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out: Optional[List[int]] = None


class ServingEngine:
    """Minimal batched greedy-decoding engine for the examples/tests.

    Requests are padded into a fixed batch; prefill builds the cache;
    decode proceeds in lockstep (one batched decode_step per token).
    """

    def __init__(self, cfg: ArchConfig, params, batch_size: int,
                 max_seq: int, parallel: ParallelCtx = LOCAL,
                 use_kernel=None):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.parallel = parallel
        self._prefill = make_prefill_fn(cfg, parallel, use_kernel=use_kernel)
        self._decode = make_decode_fn(cfg, parallel, use_kernel=use_kernel)

    def generate(self, requests: List[Request]) -> List[List[int]]:
        assert len(requests) <= self.batch_size
        bsz = self.batch_size
        plen = max(int(r.prompt.shape[0]) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        toks = jnp.zeros((bsz, plen), jnp.int32)
        for i, r in enumerate(requests):
            # token buffers are int32 end-to-end; prompts arriving as int64
            # (x64 mode) would otherwise trip the scatter dtype FutureWarning
            toks = toks.at[i, plen - r.prompt.shape[0]:].set(
                jnp.asarray(r.prompt, jnp.int32))
        # cache sized for prompt + generation budget
        total = plen + max_new
        batch = {"tokens": toks}
        last_logits, cache = self._prefill(self.params, batch)
        if self.cfg.block == "attn_mlp":
            k_c, v_c = cache
            pad = total - k_c.shape[2]
            k_c = jnp.pad(k_c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v_c = jnp.pad(v_c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache = (k_c, v_c)
        outs = [[] for _ in requests]
        tok = jnp.argmax(last_logits[:, :self.cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        for i in range(len(requests)):
            outs[i].append(int(tok[i]))
        for step in range(1, max_new):
            logits, cache = self._decode(self.params,
                                         {"tokens": tok[:, None]}, cache,
                                         jnp.int32(plen + step - 1))
            tok = jnp.argmax(logits[:, :self.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            for i in range(len(requests)):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(tok[i]))
        return outs
