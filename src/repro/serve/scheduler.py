"""Arrival-aware SLO scheduling for the diffusion sampling service.

This module owns *policy*; :class:`repro.serve.diffusion.
DiffusionSamplingEngine` owns *mechanism* (slots, micro-batches, the
virtual clock).  :func:`simulate` is a discrete-event driver: it replays
an arrival trace through a real engine, advancing the engine's
deterministic virtual clock (physical model evals x ``sec_per_eval``), so
every latency/SLO number is bit-reproducible — no wall-clock, no threads,
entirely host-stepped.

Three admission policies ship:

* :class:`FIFO` — arrival order (the pre-scheduler behaviour, now explicit);
* :class:`EDF` — earliest absolute deadline first; with deadlines
  proportional to expected service this approximates shortest-job-first
  and dodges FIFO's head-of-line blocking (lower p95 latency on mixed
  queues — ``benchmarks/table10_slo.py`` measures it);
* :class:`CostAware` — EDF order plus admission control and (optionally)
  preemption driven by the engine's own per-iteration eval accounting
  (:func:`repro.core.engine.iteration_cost` via
  ``engine.predict_completion``): requests whose *optimistic* predicted
  completion already misses their deadline are rejected up front instead
  of burning slots, and — with ``preempt=True`` — running requests whose
  deadline has already passed are evicted when a still-feasible request
  is waiting.

Guarantees / non-guarantees (mirroring the serving layer's):

* every *completed* request's sample is bit-exact vs the single-request
  ``srds_sample`` — policies only reorder/deny admission, they never touch
  a running lane's math (eviction frees a lane; frozen-lane masking keeps
  batch-mates untouched);
* ``simulate`` on a fixed trace + policy + engine config is
  bit-deterministic across runs (trace generators use seeded
  ``numpy.random.Generator`` streams; the event loop has no ties broken by
  id/hash order);
* the cost model now sees *cross-group device contention*: busy
  micro-batches step round-robin on the one device, so
  ``predict_completion`` charges every other currently-busy group one
  step at its current frontier cost per refinement round the request
  needs.  Within those terms it stays *optimistic* (the frontier is
  assumed to advance every refinement, contending groups are priced at
  today's only-shrinking step cost and assumed not to grow, and the
  iteration estimate is the most optimistic of the engine's learned
  per-tier :class:`~repro.serve.diffusion.IterationEMA` estimate and the
  caller's ``iters_hint``): CostAware rejection sheds requests that
  would miss their SLO under the currently visible load.  It does NOT
  guarantee admitted requests meet their deadlines, and "never
  over-rejects" is relative to the estimates — an unusually easy request
  in a hard tier can beat the iteration estimate, and a contending group
  can drain earlier than charged.

Adding a policy: subclass :class:`Policy` and implement ``select(now,
queue, engine)`` returning the index of the queue entry to admit next
(``None`` to hold everything back this round); optionally override
``reject`` (admission control) and ``preempt_victims`` (eviction).  The
driver guarantees ``select`` is only consulted when the chosen request's
compatibility group has a free slot, and re-consults after every
admission, so policies never need to model slot state themselves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.diffusion import (DiffusionSamplingEngine, SampleRequest,
                                   SampleResponse)

__all__ = ["Policy", "FIFO", "EDF", "CostAware", "Tier", "poisson_trace",
           "bursty_trace", "SimReport", "simulate", "build_report"]


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------

class Policy:
    """Admission-policy interface (see module docstring for the contract)."""

    name = "policy"

    def select(self, now: float, queue: List[Tuple[int, SampleRequest]],
               engine: DiffusionSamplingEngine) -> Optional[int]:
        """Index into ``queue`` of the entry to admit next, or None."""
        raise NotImplementedError

    def reject(self, now: float, rid: int, req: SampleRequest,
               engine: DiffusionSamplingEngine) -> bool:
        """Admission control: True drops the request unserved."""
        return False

    def preempt_victims(self, now: float,
                        running: List[Tuple[int, SampleRequest]],
                        queue: List[Tuple[int, SampleRequest]],
                        engine: DiffusionSamplingEngine) -> List[int]:
        """rids of running requests to evict before this admission round."""
        return []


class FIFO(Policy):
    """Admit in arrival order (ties broken by submission order, which the
    queue already encodes)."""

    name = "fifo"

    def select(self, now, queue, engine):
        if not queue:
            return None
        return min(range(len(queue)),
                   key=lambda i: (queue[i][1].arrival_time, i))


class EDF(Policy):
    """Earliest absolute deadline first; deadline-free requests sort last
    (deadline = +inf), among themselves by arrival.  Deadlines resolve
    through ``engine.request_deadline`` so the policy is clock-agnostic:
    virtual deadlines on a virtual-clock engine, ``deadline_wall`` on a
    wall-clock one."""

    name = "edf"

    def select(self, now, queue, engine):
        if not queue:
            return None
        return min(range(len(queue)),
                   key=lambda i: (engine.request_deadline(queue[i][1]),
                                  queue[i][1].arrival_time, i))


class CostAware(EDF):
    """EDF ordering + cost-model admission control (+ optional preemption).

    ``slack`` scales the predicted service time before comparing against
    the deadline (slack > 1 rejects more aggressively; the default 1.0
    rejects only provably-hopeless requests under the optimistic model).
    """

    name = "cost"

    def __init__(self, slack: float = 1.0, preempt: bool = False):
        self.slack = slack
        self.preempt = preempt

    def reject(self, now, rid, req, engine):
        deadline = engine.request_deadline(req)
        if not math.isfinite(deadline):
            return False
        predicted = engine.predict_completion(req, now)
        return now + self.slack * (predicted - now) > deadline

    def preempt_victims(self, now, running, queue, engine):
        if not self.preempt or not queue:
            return []
        # a feasible waiting request starved of slots in ITS compatibility
        # group justifies evicting a same-group runner whose deadline is
        # already unrecoverably past; runners in other groups (or in groups
        # with free slots) are left to finish late-but-complete, and at most
        # one runner is evicted per starved waiter — never more slots than
        # the waiters need
        starved: dict = {}
        for _, req in queue:
            # same slack-scaled feasibility test reject() applies, so we
            # never evict a runner for a waiter this round then rejects
            predicted = engine.predict_completion(req, now)
            if (engine.free_slots(req) == 0
                    and now + self.slack * (predicted - now)
                    <= engine.request_deadline(req)):
                key = engine.compat_key(req)
                starved[key] = starved.get(key, 0) + 1
        victims = []
        for rid, req in running:
            key = engine.compat_key(req)
            if now > engine.request_deadline(req) and starved.get(key, 0) > 0:
                victims.append(rid)
                starved[key] -= 1
        return victims


# --------------------------------------------------------------------------
# synthetic arrival traces
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tier:
    """A quality/latency class of traffic: requests in a tier share a
    tolerance, an SLO, and (for the cost model) an expected iteration
    count — mirroring how a deployment would publish per-tier SLOs."""
    tol: float
    slo_ms: Optional[float] = None
    iters_hint: Optional[int] = None
    weight: float = 1.0


def _draw_tiers(rng: np.random.Generator, tiers: Sequence[Tier],
                n: int) -> List[Tier]:
    w = np.asarray([t.weight for t in tiers], np.float64)
    idx = rng.choice(len(tiers), size=n, p=w / w.sum())
    return [tiers[i] for i in idx]


def _mk_request(i: int, t: float, tier: Tier, seed0: int) -> SampleRequest:
    return SampleRequest(seed=seed0 + i, tol=tier.tol, arrival_time=float(t),
                         slo_ms=tier.slo_ms, iters_hint=tier.iters_hint)


def poisson_trace(n: int, rate: float, tiers: Sequence[Tier],
                  seed: int = 0, start: float = 0.0,
                  seed0: int = 0) -> List[SampleRequest]:
    """``n`` arrivals of a Poisson process with ``rate`` req/s, tiers drawn
    by weight.  Deterministic for a fixed ``seed`` (PCG64 stream)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    times = start + np.cumsum(gaps)
    drawn = _draw_tiers(rng, tiers, n)
    return [_mk_request(i, times[i], drawn[i], seed0) for i in range(n)]


def bursty_trace(n_bursts: int, burst_size: int, period: float,
                 tiers: Sequence[Tier], seed: int = 0, jitter: float = 0.0,
                 start: float = 0.0, seed0: int = 0) -> List[SampleRequest]:
    """``n_bursts`` bursts of ``burst_size`` near-simultaneous arrivals,
    ``period`` seconds apart (uniform jitter inside the burst) — the
    thundering-herd shape that separates EDF from FIFO."""
    rng = np.random.default_rng(seed)
    out: List[SampleRequest] = []
    i = 0
    for b in range(n_bursts):
        t0 = start + b * period
        offs = np.sort(rng.uniform(0.0, jitter, size=burst_size)) \
            if jitter > 0 else np.zeros(burst_size)
        for tier, off in zip(_draw_tiers(rng, tiers, burst_size), offs):
            out.append(_mk_request(i, t0 + off, tier, seed0))
            i += 1
    return out


# --------------------------------------------------------------------------
# the discrete-event driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SimReport:
    """Outcome of one trace replay.  ``responses`` holds completed requests
    only; rejected/preempted rids are listed separately.  Percentiles are
    over completed-request latencies, in the replaying engine's clock
    seconds — deterministic virtual ones out of :func:`simulate`, real
    wall ones out of :class:`repro.serve.async_loop.AsyncServeLoop`."""
    policy: str
    responses: Dict[int, SampleResponse]
    rejected: List[int]
    preempted: List[int]
    latency_p50: float
    latency_p95: float
    latency_p99: float
    slo_attainment: float     # met / all submitted (rejected+preempted miss)
    goodput_rps: float        # SLO-met completions per virtual second
    makespan: float           # virtual seconds from first arrival to idle
    effective_evals: int
    physical_evals: int


def simulate(engine: DiffusionSamplingEngine, trace: Sequence[SampleRequest],
             policy: Optional[Policy] = None,
             sec_per_eval: Optional[float] = None) -> SimReport:
    """Replay ``trace`` through ``engine`` under ``policy`` (default FIFO).

    The event loop alternates admission rounds and engine steps: requests
    become visible at their ``arrival_time`` on the engine's virtual clock;
    between steps the policy may reject waiting requests, evict running
    ones, and picks who takes each free slot.  When the engine is idle and
    nothing has arrived, the clock jumps to the next arrival.  Resets the
    engine's metrics first so back-to-back runs on one warm engine are
    independent and bit-deterministic.

    **Determinism guarantee:** ``simulate()`` is a host-stepped
    discrete-event replay on the engine's deterministic
    :class:`~repro.serve.clock.VirtualClock` — time advances only by
    charged eval cost and arrival jumps, so a fixed (trace, policy,
    engine config) reproduces byte-identical samples, latencies and
    percentiles on every run.  It uses the engine's *synchronous* step
    (dispatch + resolve fused) and is entirely unaffected by the
    asynchronous wall-clock serving loop
    (:class:`repro.serve.async_loop.AsyncServeLoop`), which lives beside
    it, not under it.  An engine built on any non-virtual clock is
    refused here — wall-clock evidence belongs to the async loop and
    ``benchmarks/table10_wallclock.py``.
    """
    if engine._clock.is_wall:
        raise ValueError(
            "simulate() is the bit-deterministic discrete-event driver and "
            "requires a VirtualClock engine; wall-clock serving goes "
            "through repro.serve.async_loop.AsyncServeLoop")
    policy = policy if policy is not None else FIFO()
    saved_spe = engine.sec_per_eval
    if sec_per_eval is not None:
        engine.sec_per_eval = sec_per_eval
    try:
        return _simulate(engine, trace, policy)
    finally:
        # a what-if calibration override must not leak into later runs
        engine.sec_per_eval = saved_spe


def _simulate(engine: DiffusionSamplingEngine,
              trace: Sequence[SampleRequest], policy: Policy) -> SimReport:
    engine.reset_metrics()

    pending = sorted(trace, key=lambda r: r.arrival_time)
    pending = [(engine.submit(r), r) for r in pending]
    submitted = [rid for rid, _ in pending]
    engine.pull_queue()       # simulate owns admission, not drain()
    first_arrival = pending[0][1].arrival_time if pending else 0.0
    engine.advance_clock(first_arrival)

    waiting: List[Tuple[int, SampleRequest]] = []
    responses: Dict[int, SampleResponse] = {}
    rejected: List[int] = []
    preempted: List[int] = []
    running: Dict[int, SampleRequest] = {}

    def arrivals(now: float):
        while pending and pending[0][1].arrival_time <= now:
            waiting.append(pending.pop(0))

    while pending or waiting or engine.busy():
        now = engine.clock
        arrivals(now)
        if not waiting and not engine.busy():
            # idle: jump to the next arrival
            engine.advance_clock(pending[0][1].arrival_time)
            continue

        # ---- preemption round (policy-driven) ----
        victims = policy.preempt_victims(now, sorted(running.items()),
                                         waiting, engine)
        for rid in victims:
            engine.evict(rid)
            preempted.append(rid)
            del running[rid]

        # ---- admission control + slot filling ----
        keep: List[Tuple[int, SampleRequest]] = []
        for rid, req in waiting:
            if policy.reject(now, rid, req, engine):
                rejected.append(rid)
            else:
                keep.append((rid, req))
        waiting[:] = keep
        while True:
            admissible = [i for i, (rid, req) in enumerate(waiting)
                          if engine.free_slots(req) > 0]
            if not admissible:
                break
            sub = [waiting[i] for i in admissible]
            j = policy.select(now, sub, engine)
            if j is None:
                break
            rid, req = waiting.pop(admissible[j])
            engine.admit(rid, req)
            running[rid] = req

        if waiting and not engine.busy():
            if pending:
                # the policy is holding back (legal — e.g. waiting to
                # co-batch); jump to the next arrival that may unblock it
                engine.advance_clock(pending[0][1].arrival_time)
                continue
            # nothing running, nothing admitted, nothing left to arrive: a
            # select() that holds requests back forever would hang the clock
            raise RuntimeError(
                f"policy {policy.name!r} admitted nothing on an idle engine")

        # ---- one engine step (advances the clock) ----
        for rid, resp in engine.step_once():
            responses[rid] = resp
            running.pop(rid, None)

    return build_report(policy, responses, rejected, preempted, submitted,
                        engine, first_arrival)


def build_report(policy: Policy, responses: Dict[int, SampleResponse],
                 rejected: List[int], preempted: List[int],
                 submitted: List[int], engine: DiffusionSamplingEngine,
                 first_arrival: float) -> SimReport:
    """Assemble a :class:`SimReport` from one finished trace replay —
    shared by the synchronous :func:`simulate` and the asynchronous
    :class:`repro.serve.async_loop.AsyncServeLoop`, so virtual and
    wall-clock runs report through one schema."""
    lats = [r.latency for r in responses.values()]
    p50, p95, p99 = (np.percentile(lats, [50, 95, 99]) if lats
                     else (0.0, 0.0, 0.0))
    met = sum(1 for r in responses.values() if r.slo_met)
    makespan = max(engine.clock - first_arrival, 0.0)
    return SimReport(
        policy=policy.name,
        responses=responses,
        rejected=rejected,
        preempted=preempted,
        latency_p50=float(p50),
        latency_p95=float(p95),
        latency_p99=float(p99),
        slo_attainment=met / max(len(submitted), 1),
        goodput_rps=met / makespan if makespan > 0 else 0.0,
        makespan=makespan,
        effective_evals=engine.effective_evals,
        physical_evals=engine.physical_evals)
