"""Pluggable serving clocks: deterministic virtual time vs real wall time.

The serving engine charges time through exactly one seam — a
:class:`Clock` — so the *same* mechanism code (admission, stepping,
eviction, latency stamping) runs in two regimes:

* :class:`VirtualClock` (the default): the deterministic discrete-event
  clock the engine has always had.  Time advances **only** when the
  engine charges it (physical model evals x ``sec_per_eval``) or jumps
  it to the next arrival, so every latency/SLO number out of
  :func:`repro.serve.scheduler.simulate` is a bit-reproducible function
  of the trace — no wall-clock noise, no threads.  ``charge()`` adds,
  ``wait_until()`` warps forward, ``now()`` reads the accumulator.

* :class:`MonotonicClock`: real time, for the asynchronous serving loop
  (:class:`repro.serve.async_loop.AsyncServeLoop`).  ``now()`` reads
  ``time.monotonic()`` relative to the clock's epoch (so traces written
  as small offsets-from-zero replay unchanged), ``charge()`` is a no-op
  — real time passes on its own while the device computes — and
  ``wait_until()`` genuinely sleeps.  Numbers measured on this clock are
  wall-clock evidence and inherently noisy; benchmarks gate *ordering*
  invariants on it, never absolute seconds (see
  ``benchmarks/table10_wallclock.py``).

The split keeps the repo's standing determinism guarantee intact:
``simulate()`` refuses non-virtual clocks (bit-determinism is its
contract), while the async loop accepts either — a ``VirtualClock``
async loop is how the pipelined dispatch/resolve path is tested
bit-exactly against the synchronous engine.
"""
from __future__ import annotations

import time

__all__ = ["Clock", "VirtualClock", "MonotonicClock"]


class Clock:
    """The engine's time seam.  ``is_wall`` tells deadline resolution
    which of a request's deadlines applies (``deadline`` is virtual
    seconds, ``deadline_wall`` is seconds on this clock — see
    :meth:`repro.serve.diffusion.SampleRequest.absolute_deadline`)."""

    is_wall: bool = False

    def now(self) -> float:
        raise NotImplementedError

    def charge(self, seconds: float) -> None:
        """Account ``seconds`` of device compute against the clock."""
        raise NotImplementedError

    def wait_until(self, t: float) -> None:
        """Idle until the clock reads at least ``t`` (never backwards)."""
        raise NotImplementedError

    def reset(self) -> None:
        """Re-zero the clock (between back-to-back runs on one engine)."""
        raise NotImplementedError


class VirtualClock(Clock):
    """Deterministic discrete-event time: an accumulator the engine
    advances by charged eval cost.  ``simulate()`` requires this clock."""

    is_wall = False

    def __init__(self) -> None:
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def charge(self, seconds: float) -> None:
        self._t += seconds

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)

    def reset(self) -> None:
        self._t = 0.0


class MonotonicClock(Clock):
    """Real time via ``time.monotonic()``, zeroed at construction (or the
    last ``reset()``).  ``charge()`` is a no-op: wall time elapses while
    the device computes whether or not the host accounts for it."""

    is_wall = True

    def __init__(self) -> None:
        self._epoch = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._epoch

    def charge(self, seconds: float) -> None:
        pass

    def wait_until(self, t: float) -> None:
        delay = t - self.now()
        if delay > 0:
            time.sleep(delay)

    def reset(self) -> None:
        self._epoch = time.monotonic()
