"""Batched diffusion sampling service over the batch-aware SRDS engine.

:class:`DiffusionSamplingEngine` mirrors :class:`repro.serve.engine.
ServingEngine` for diffusion workloads: callers ``submit`` sampling
requests carrying their own ``(tol, num_steps, seed)`` — and, for
SLO-aware serving, an ``arrival_time`` plus a ``deadline``/``slo_ms`` —
the engine packs *compatible* requests into fixed-size micro-batches of
``batch_size`` slots, and drives the Parareal refinement loop one
iteration at a time across each batch.

The compatibility key is ``(num_steps, solver, schedule, sample shape)``:
requests agreeing on all four share one block decomposition and one
compiled init/step program; anything else runs in its own micro-batch
group, so a mixed workload can never silently share (and retrace) a
compiled program that doesn't match its math.

Slot recycling is the throughput story: convergence is gated **per slot**
(the engine's per-sample semantics — every slot's refinement is
bit-identical to an independent :func:`repro.core.parareal.srds_sample`
call with that request's tolerance), so the moment a sample converges its
slot is freed and the next queued request is admitted into it, instead of
the whole batch idling until the slowest sample finishes.  Under lockstep
whole-batch gating a micro-batch pays ``K * max_k(iters_k)`` refinements;
with recycling it pays ``sum_k(iters_k)`` (plus a drain tail), which is
where the "effective model evals per sample" win in
``benchmarks/table9_batched.py`` comes from.

The refinement step is a **sliding-window hot loop** behind the
:class:`repro.core.window.FrontierPolicy` seam: each step program is
compiled for the group's quantized *frontier*, statically skipping the
frozen block prefix's fine solves and corrector sweep.  With the default
``ExactPrefix`` policy the frontier is the provably bitwise-frozen prefix
(every lane's first ``prefix_frontier(j)`` blocks are final after ``j``
refinements — bit-exact).  With the opt-in ``ResidualWindow`` policy the
frontier additionally advances past blocks whose per-block residual
passed ``window_tol`` (ParaDiGMS-style, *approximate* — the error knob
and guarantees live in :mod:`repro.core.window`); the ``(num_blocks,)``
per-block residual vector piggybacks on the existing per-refinement
fetch, so the host loop still performs exactly ONE device sync per
refinement (the batched ``(K,)`` residual — concatenated with the block
residuals under ``ResidualWindow``) plus one per completion (that lane's
final state only — never the ``(B, K, *shape)`` trajectory).  All device
buffers — ``x_tail``/``prev_coarse`` in both the init-sweep and the step
programs — are donated to XLA so trajectory-sized allocations are reused
in place.

Arrival-aware serving rides a pluggable **clock**
(:mod:`repro.serve.clock`).  The default :class:`~repro.serve.clock.
VirtualClock` is the deterministic discrete-event clock the engine has
always had: every engine step advances it by its *physical* model-eval
cost times ``sec_per_eval`` (the deployment's calibrated per-eval wall
time), so latency, SLO-attainment and goodput numbers are
bit-reproducible discrete-event quantities, not wall-clock noise.  A
:class:`~repro.serve.clock.MonotonicClock` engine instead stamps those
same fields from real time — the regime of the asynchronous serving
loop (:class:`repro.serve.async_loop.AsyncServeLoop`), which overlaps
host scheduling with device compute by dispatching the next
refinement's step program (:meth:`DiffusionSamplingEngine.
step_dispatch`) before blocking on the previous refinement's residual
fetch (:meth:`DiffusionSamplingEngine.step_resolve`).  The admission
*policy* (who gets a freed slot, who is rejected or preempted) lives in
:mod:`repro.serve.scheduler`; this module only exposes the mechanism:
``admit`` / ``step_once`` (= dispatch + resolve, fused) / ``evict`` /
``free_slots``.  Completion-time prediction feeds on
:class:`IterationEMA`, an online per-tier iterations estimate learned
from the engine's own completions (falling back to the caller's
``iters_hint``, then worst-case ``max_iters``).

What the engine does / does not guarantee:

* per-request exactness: each returned sample equals the single-request
  SRDS result for that ``(tol, num_steps, seed, solver, schedule)`` —
  admission order, batch-mates and preemption of *other* requests do not
  perturb it (converged/empty lanes are frozen with ``jnp.where``, never
  fed back).  *Bitwise* for elementwise-deterministic denoisers; matmul
  denoisers carry the repo's standing shape-dependent-gemm carve-out
  (roundoff-level: XLA picks gemm kernels by batch shape, and with
  ``truncate`` the group frontier sets the fine-solve width, so lane bits
  can depend on batch composition at roundoff scale — build with
  ``truncate=False`` for width-independence at full cost).  Under the
  opt-in ``ResidualWindow`` policy the guarantee weakens further: the
  group window is shared, so batch-mates influence *which* blocks freeze
  and results are approximate (bounded by ``window_tol``) and
  composition-dependent — exactness-critical workloads keep the default
  ``ExactPrefix``.  Building the engine with an accelerating ``accel``
  (:mod:`repro.core.accel`) similarly trades exactness for iterations:
  mixed iterates are tolerance-equivalent, not bitwise, and mixing is
  per-lane (vmapped), so batch-mates still cannot perturb each other
  beyond the existing window/gemm caveats;
* eval accounting is *effective* (per-active-slot): lockstep SPMD still
  computes masked lanes, so physical compute equals effective compute only
  while the queue keeps every slot busy — exactly the heavy-traffic regime
  the service targets.  ``stats()`` reports both so the gap is visible;
* no cross-key batching: requests on different grids/solvers/schedules/
  shapes run in separate micro-batch groups (one compiled program each);
* deterministic solvers only for the exactness guarantee — the frozen-noise
  ``ddpm`` solver draws noise shaped like the *batch*, so its lanes differ
  from single-request runs (same distribution, different realization).
  ``submit`` therefore **rejects** ``ddpm`` requests unless the engine was
  built with ``allow_inexact=True`` (an explicit caller opt-in).

Parallelism hooks (both ride :mod:`repro.compat` wrappers):

* ``axis`` — shard the *block* dim of each refinement's fine solves
  (``shard_map`` + one ``all_gather`` per iteration, the
  :func:`repro.core.pipelined.srds_sharded_local` layout);
* ``data_axis`` — shard the *slot batch* (K) over a data mesh axis: lanes
  are independent, so the fine solves split with no collectives at all
  (specs from :func:`repro.parallel.sharding.microbatch_spec`).  Both
  axes compose on a 2D mesh.

Model evals go through the :class:`repro.core.denoiser.Denoiser` seam: a
model-parallel denoiser (e.g. the patch-sharded DiT from
:func:`repro.models.dit.make_denoiser`) contributes its own ``in_spec``
sample axes to the fine program's specs via
:func:`repro.parallel.sharding.denoiser_spec`, so time x data x model all
compose on one 3D mesh (:func:`repro.launch.mesh.make_srds_mesh`) with
zero engine-specific model code.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.analysis.markers import hot_loop
from repro.serve.clock import Clock, VirtualClock
from repro.core.accel import resolve_accel
from repro.core.engine import (IterationCost, blockwise_norm,
                               coarse_init_sweep, convergence_norm,
                               iteration_cost, predicted_evals,
                               prefix_frontier, resolve_blocks,
                               resolve_fused, suffix_refinement,
                               truncated_evals)
from repro.core.denoiser import as_denoiser
from repro.core.schedules import DiffusionSchedule, make_schedule
from repro.core.solvers import ModelFn, SolverConfig, solve, solver_names
from repro.core.window import FixedBudget, resolve_policy
from repro.parallel.sharding import denoiser_spec, microbatch_spec

__all__ = ["SampleRequest", "SampleResponse", "CompletionRecord",
           "DiffusionSamplingEngine", "IterationEMA"]


def _host_fetch(x) -> np.ndarray:
    """The single device->host transfer point of the serving hot loop.

    ``step()`` calls it exactly once per refinement (the batched ``(K,)``
    residual vector) plus once per *completed* request (that lane's final
    state only — never the whole trajectory).  Tests monkeypatch this to
    count syncs and hold the one-sync-per-iteration contract.
    """
    return np.asarray(jax.device_get(x))


class IterationEMA:
    """Online per-tier expected-iterations predictor.

    Replaces trust in the caller's static ``iters_hint`` once real
    completions exist: an exponential moving average of observed refinement
    counts, keyed per tier — ``(compat_key, tol)`` — so a mixed workload
    learns one estimate per (grid, solver, schedule, shape, tolerance)
    class.  Feeds :meth:`DiffusionSamplingEngine.predict_completion` (and
    through it the CostAware scheduler); before the first observation of a
    tier the predictor abstains and callers fall back to ``iters_hint``
    then worst-case ``max_iters``, preserving the optimistic-rejection
    soundness story.
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._mean: Dict[tuple, float] = {}

    def observe(self, key: tuple, iterations: int) -> None:
        prev = self._mean.get(key)
        # incremental form: exact fixed point when observations repeat
        self._mean[key] = float(iterations) if prev is None \
            else prev + self.alpha * (float(iterations) - prev)

    def predict(self, key: tuple) -> Optional[float]:
        return self._mean.get(key)

    def reset(self) -> None:
        self._mean.clear()


@dataclasses.dataclass
class SampleRequest:
    """One sampling job: draw x_init ~ N(0, I) from ``seed`` and run SRDS
    to the requester's tolerance on a ``num_steps`` grid.

    ``arrival_time`` (seconds on the engine's clock — virtual by default,
    real under a :class:`~repro.serve.clock.MonotonicClock`) and
    ``deadline``/``deadline_wall``/``slo_ms`` make the request
    schedulable: ``deadline`` is absolute on the *virtual* clock,
    ``deadline_wall`` is absolute on a *wall* (monotonic) clock, and
    ``slo_ms`` is relative to arrival so it is meaningful on either.  An
    engine resolves whichever absolute deadline matches its own clock
    (:meth:`DiffusionSamplingEngine.request_deadline`) and falls back to
    ``slo_ms``; nothing set means "best effort" (infinite deadline).
    ``solver``/``schedule``/``shape`` override the engine defaults and
    become part of the compatibility key.  ``iters_hint`` is the caller's
    expected refinement count for cost-model admission (policies fall back
    to the worst-case ``max_iters`` when absent).
    """
    seed: int
    tol: float = 1e-3
    num_steps: Optional[int] = None      # None -> engine default grid
    arrival_time: float = 0.0            # seconds on the engine clock
    slo_ms: Optional[float] = None       # relative deadline (ms past arrival)
    deadline: Optional[float] = None     # absolute virtual-clock deadline
    deadline_wall: Optional[float] = None  # absolute wall-clock deadline
    solver: Optional[SolverConfig] = None   # None -> engine default
    schedule: Optional[str] = None       # None -> engine default
    shape: Optional[Tuple[int, ...]] = None  # None -> engine default
    iters_hint: Optional[int] = None     # expected SRDS iterations (cost model)

    def absolute_deadline(self, wall: bool = False) -> float:
        """Absolute deadline in the given clock regime: ``wall=True``
        resolves ``deadline_wall`` (ignoring the virtual ``deadline``),
        the default resolves ``deadline`` (ignoring ``deadline_wall``);
        both fall back to arrival-relative ``slo_ms``, then +inf.  Engine
        code goes through ``engine.request_deadline(req)`` so the regime
        always matches the engine's own clock."""
        absolute = self.deadline_wall if wall else self.deadline
        if absolute is not None:
            return float(absolute)
        if self.slo_ms is not None:
            return self.arrival_time + self.slo_ms / 1e3
        return math.inf


@dataclasses.dataclass
class SampleResponse:
    sample: Optional[np.ndarray]         # None only for status="preempted"
    iterations: int
    final_delta: float
    delta_history: np.ndarray            # (iterations,) — converged prefix
    model_evals: int                     # effective evals charged to this job
    status: str = "ok"                   # "ok" | "preempted"
    arrival_time: float = 0.0
    finish_time: float = 0.0             # virtual-clock completion
    latency: float = 0.0                 # finish - arrival (virtual seconds)
    deadline: float = math.inf
    slo_met: bool = True


@dataclasses.dataclass(frozen=True)
class CompletionRecord:
    """Host-side latency ledger entry (one per finished/preempted request)."""
    rid: int
    arrival_time: float
    finish_time: float
    deadline: float
    latency: float
    slo_met: bool
    status: str


def _solver_fp(solver: SolverConfig):
    """Hashable fingerprint of a SolverConfig (noise_key may be an array)."""
    nk = solver.noise_key
    nk_fp = None if nk is None else np.asarray(nk).tobytes()
    return (solver.name, solver.eta, solver.use_fused_kernel, solver.unroll,
            nk_fp)


class _Slot:
    __slots__ = ("rid", "req", "iters", "history", "evals")

    def __init__(self, rid: int, req: SampleRequest):
        self.rid = rid
        self.req = req
        self.iters = 0
        self.history: List[float] = []
        # realized per-lane eval charge (residual-window billing: the
        # executed group-window schedule, accumulated step by step)
        self.evals = 0


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unresolved refinement of a micro-batch.

    Everything the host needs to account the step *after* its residual
    fetch lands: the un-fetched device residual (``fetch`` — ``(K,)``,
    or ``(K+B,)`` with the per-block residuals under a residual-window
    policy), the post-step final-block snapshot (``snap``, ``(K,
    *shape)`` on device — a completed lane's sample is cut from here, so
    the trajectory buffers can be donated to the *next* dispatched step
    while this one is still unresolved), and the dispatch-time lane
    census (``lanes``: slot index, rid, per-lane effective-eval charge —
    a lane that completed or was evicted between dispatch and resolve is
    recognized by its rid and skipped: its refinement here was
    speculative waste, charged physically but never effectively).
    """
    batch: "_MicroBatch"
    fetch: object                        # device (K,) or (K+B,) residuals
    snap: object                         # device (K, *shape) final tails
    lanes: List[Tuple[int, int, int]]    # (slot k, rid, effective evals)
    windowed: bool                       # residual-window step?
    lo: int                              # window lower bound at dispatch
    phys: int                            # physical evals (incl. lane inits)
    init_eff: int                        # effective evals of lane inits
    epoch: int                           # batch.window_epoch at dispatch


class _MicroBatch:
    """State of one compatibility group's K-slot batch (one compiled
    init/step program).  The engine owns admission/step ordering; this
    class owns the device tensors and per-slot bookkeeping."""

    def __init__(self, engine: "DiffusionSamplingEngine", n: int,
                 schedule: str, shape: Tuple[int, ...], solver: SolverConfig):
        self.engine = engine
        self.n = n
        self.schedule = schedule
        self.shape = shape
        self.solver = solver
        (self.init_fn, self.step_for, self.B, self.S) = \
            engine._build_program(n, schedule, shape, solver)
        self.cost: IterationCost = iteration_cost(n, engine.num_blocks,
                                                  solver.evals_per_step)
        self.max_iters = engine.max_iters if engine.max_iters is not None \
            else self.B
        # truncated step programs are compiled per quantized frontier value;
        # the quantum bounds the cache at ~4 programs per group
        self.trunc_q = engine.truncate_quantum \
            if engine.truncate_quantum is not None else max(1, self.B // 4)
        self.policy = engine.window
        # residual-window group state: the dynamic window lower bound,
        # advanced from the fetched per-block residuals; reset to 0 when a
        # fresh lane is admitted (its blocks are all unconverged)
        self.lo = 0
        # dispatched-but-unresolved refinement count (async pipelining)
        # and the admission epoch guarding window re-opens across them
        self.inflight = 0
        self.window_epoch = 0
        K = engine.batch_size
        self.x_init = jnp.zeros((K,) + shape, engine.dtype)
        self.x_tail = jnp.zeros((self.B, K) + shape, engine.dtype)
        self.prev_coarse = jnp.zeros_like(self.x_tail)
        # accelerator mixing state (None under NoAccel — the step
        # programs then neither take nor return it, keeping them
        # byte-identical to the unaccelerated engine)
        self.astate = engine.accel.init_state(
            jnp.stack([self.x_tail, self.x_tail]), self.max_iters,
            batched=True) if engine.accel.accelerates else None
        self.active = np.zeros((K,), bool)
        self.slots: List[Optional[_Slot]] = [None] * K
        self.newly: List[int] = []

    # ------------------------------------------------------------- capacity

    def free_slots(self) -> int:
        return sum(1 for s in self.slots if s is None)

    def busy(self) -> bool:
        return any(s is not None for s in self.slots)

    # ------------------------------------------------------------ admission

    def admit(self, rid: int, req: SampleRequest) -> int:
        """Place a request into a free slot (init happens at the next step)."""
        for k, s in enumerate(self.slots):
            if s is None:
                x0 = jax.random.normal(jax.random.PRNGKey(req.seed),
                                       self.shape, self.engine.dtype)
                self.x_init = self.x_init.at[k].set(x0)
                self.slots[k] = _Slot(rid, req)
                self.active[k] = True
                self.newly.append(k)
                # a fresh lane's blocks are all unconverged: the shared
                # residual window must re-open (existing lanes' frozen
                # blocks thaw — sound, they only refine further); the
                # epoch bump keeps an in-flight step's resolve from
                # re-advancing the freshly reset window
                self.lo = 0
                self.window_epoch += 1
                return k
        raise RuntimeError("admit() called with no free slot")

    def evict(self, rid: int) -> Tuple[SampleRequest, SampleResponse]:
        """Preempt a running request: free its slot, discard its lane.

        Frozen-lane masking means batch-mates are untouched — eviction only
        forfeits the evicted request's own (partial) refinement work.
        """
        for k, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[k] = None
                self.active[k] = False
                uninitialized = k in self.newly
                if uninitialized:
                    self.newly.remove(k)
                return s.req, SampleResponse(
                    sample=None, iterations=s.iters,
                    final_delta=s.history[-1] if s.history else float("inf"),
                    delta_history=np.asarray(s.history, np.float32),
                    # a lane evicted before its coarse init ran did no work
                    model_evals=0 if uninitialized
                    else self._slot_evals(s),
                    status="preempted")
        raise KeyError(f"request {rid} is not running in this batch")

    # ----------------------------------------------------------------- step

    def _lane_evals(self, iters: int) -> int:
        """Per-lane eval charge for ``iters`` refinements, in the engine's
        mode: truncated frontier schedule when the step programs truncate,
        the flat untruncated rate otherwise — billing always matches what
        an ideally-packed engine of this configuration would execute."""
        return truncated_evals(self.cost, iters) if self.engine.truncate \
            else predicted_evals(self.cost, iters)

    def _slot_evals(self, s: _Slot) -> int:
        """A finished/preempted lane's eval charge.  Residual-window lanes
        bill their *realized* accumulated window schedule (tracked in
        ``_Slot.evals``); exact policies keep the per-lane ideal schedule
        of ``_lane_evals``."""
        if self.policy.needs_block_residuals:
            return s.evals
        return self._lane_evals(s.iters)

    def _refine_evals_at(self, frontier: int) -> int:
        return self.cost.refine_evals_at(frontier) if self.engine.truncate \
            else self.cost.refine_evals

    def _static_frontier(self) -> int:
        """Un-quantized provable group frontier: the min bitwise-frozen
        prefix over active lanes (each lane's frontier is its own
        completed-refinement count, lagged per ``prefix_frontier``)."""
        fr = [prefix_frontier(s.iters) for k, s in enumerate(self.slots)
              if s is not None and self.active[k]]
        return min(fr) if fr else 0

    def _frontier(self) -> int:
        """Quantized group frontier, snapped *down* to the truncation
        quantum so at most ~B/quantum step programs compile.  Snapping
        down is always sound — less truncation than provable."""
        minf = (self._static_frontier() // self.trunc_q) * self.trunc_q
        return min(minf, self.B - 1)

    def _window_frontier(self) -> Tuple[int, int]:
        """Residual-window frontiers: ``(lo, minf)`` where ``lo`` is the
        effective window lower bound (the policy's dynamic bound, floored
        at the provable group frontier and capped at B-1 — the final
        block never retires) and ``minf`` is ``lo`` snapped down to the
        quantum: the compiled suffix starts at ``minf``, blocks
        ``[minf, lo)`` are frozen by masking inside the program."""
        lo = min(max(self.lo, self._static_frontier()), self.B - 1)
        minf = min((lo // self.trunc_q) * self.trunc_q, self.B - 1)
        return lo, minf

    def step_evals(self) -> int:
        """Physical model evals of this batch's next refinement step at
        its current frontier — the unit ``predict_completion`` charges a
        waiting request per round-robin round of cross-group contention."""
        if self.policy.needs_block_residuals:
            _, minf = self._window_frontier()
        else:
            minf = self._frontier() if self.engine.truncate else 0
        return self.engine.batch_size * self._refine_evals_at(minf)

    @hot_loop
    def dispatch(self) -> _InFlight:
        """Enqueue one lockstep refinement (newly-admitted lane inits
        included) with NO device->host sync: the returned
        :class:`_InFlight` token carries the un-fetched residual and the
        post-step final-block snapshot as device values.  The async
        serving loop dispatches the *next* refinement before resolving
        this one, so the blocking fetch in :meth:`resolve` overlaps
        device compute; the synchronous path (``step()``) fuses the two
        back to back.

        A lane that — unbeknownst to the host — converged on the still
        unresolved *previous* refinement gets one speculative extra
        refinement here.  That work is physically wasted but never
        observable: the lane's completed sample is cut from the previous
        step's snapshot at resolve, so responses stay bit-identical to
        the synchronous engine's.
        """
        K = self.engine.batch_size
        init_eff = phys = 0
        if self.newly:
            # coarse-init the fixed batch inside one donated program (the
            # new-lane write-back included, so the trajectory-sized
            # x_tail/prev_coarse buffers are reused in place off-CPU;
            # occupied lanes keep their refined trajectories)
            m = np.zeros((K,), bool)
            m[self.newly] = True
            self.x_tail, self.prev_coarse = self.init_fn(
                self.x_init, self.x_tail, self.prev_coarse, jnp.asarray(m))
            if self.astate is not None:
                # a recycled slot's mixing history belongs to its previous
                # tenant: zero it so old transients never mix into the
                # freshly admitted request
                self.astate = self.engine.accel.reset_lanes(
                    self.astate, jnp.asarray(m))
            init_eff = len(self.newly) * self.cost.init_evals
            phys += K * self.cost.init_evals
            for k in self.newly:
                self.slots[k].evals = self.cost.init_evals
            self.newly = []

        amask = jnp.asarray(self.active)
        if self.policy.needs_block_residuals:
            # residual-window step: the compiled suffix starts at the
            # quantized window floor, blocks [minf, lo) freeze by masking,
            # and the (B,) group block residual rides the one fetch
            lo, minf = self._window_frontier()
            if self.astate is not None:
                self.x_tail, self.prev_coarse, fetch, self.astate = \
                    self.step_for.windowed(minf)(
                        self.x_init, self.x_tail, self.prev_coarse, amask,
                        jnp.int32(lo), self.astate)
            else:
                self.x_tail, self.prev_coarse, fetch = \
                    self.step_for.windowed(minf)(
                        self.x_init, self.x_tail, self.prev_coarse, amask,
                        jnp.int32(lo))
            # effective = the window schedule every active lane actually
            # executes; physical = the compiled suffix width times K
            per_lane = self.cost.refine_evals_window(lo)
            lanes = [(k, s.rid, per_lane)
                     for k, s in enumerate(self.slots)
                     if s is not None and self.active[k]]
            phys += K * self.cost.refine_evals_window(minf)
            windowed = True
        else:
            minf = self._frontier() if self.engine.truncate else 0
            lo = minf
            if self.astate is not None:
                self.x_tail, self.prev_coarse, fetch, self.astate = \
                    self.step_for(minf)(
                        self.x_init, self.x_tail, self.prev_coarse, amask,
                        self.astate)
            else:
                self.x_tail, self.prev_coarse, fetch = self.step_for(minf)(
                    self.x_init, self.x_tail, self.prev_coarse, amask)
            # effective = per-lane ideal (each lane truncated at its OWN
            # frontier when the engine truncates); physical = what the
            # lockstep program actually ran (K lanes at the group frontier)
            lanes = [(k, s.rid,
                      self._refine_evals_at(prefix_frontier(s.iters)))
                     for k, s in enumerate(self.slots)
                     if s is not None and self.active[k]]
            phys += K * self._refine_evals_at(minf)
            windowed = False
        self.inflight += 1
        # the snapshot reads the REBOUND (post-step) x_tail: a device-side
        # slice enqueued before the next dispatch donates the buffer away
        return _InFlight(batch=self, fetch=fetch, snap=self.x_tail[-1],
                         lanes=lanes, windowed=windowed, lo=lo, phys=phys,
                         init_eff=init_eff, epoch=self.window_epoch)

    @hot_loop
    def resolve(self, tok: _InFlight):
        """Land a dispatched refinement: block on its residual fetch,
        update lane bookkeeping, finalize converged slots.  Returns
        ``(completions, effective_evals, physical_evals)`` where
        completions are ``(rid, req, response)``.

        Host traffic: exactly ONE device->host sync per refinement — the
        batched ``(K,)`` residual vector, with the ``(B,)`` per-block
        residual piggybacked onto the same fetch under a residual-window
        policy — plus one per completed request (that lane's row of the
        snapshot only, never the ``(B, K, *shape)`` trajectory).
        """
        K = self.engine.batch_size
        self.inflight -= 1
        fetched = _host_fetch(tok.fetch)     # the one per-iteration sync
        delta_np = fetched[:K]
        if tok.windowed:
            block_np = fetched[K:]
            if tok.epoch == self.window_epoch:
                # advance the shared window from the lane-max residuals;
                # never retreat below what a younger resolved step already
                # proved.  An admission since dispatch re-opened the
                # window — its reset wins (smaller window = sound).
                self.lo = max(self.lo, int(self.policy.advance(
                    tok.lo, block_np, self.B)))

        eff = tok.init_eff
        completed: List[Tuple[int, SampleRequest, SampleResponse]] = []
        for k, rid, lane_eff in tok.lanes:
            slot = self.slots[k]
            if slot is None or slot.rid != rid:
                # lane completed/was evicted between dispatch and resolve:
                # this refinement of it was speculative waste — physical,
                # never effective, and never observable
                continue
            eff += lane_eff
            if tok.windowed:
                slot.evals += lane_eff
            slot.iters += 1
            slot.history.append(float(delta_np[k]))
            # f32 compare, matching the engine's still_refining gate
            if (delta_np[k] < np.float32(slot.req.tol)
                    or slot.iters >= self.max_iters):
                completed.append((slot.rid, slot.req, SampleResponse(
                    # fetch ONLY the completed lane's final state — not the
                    # (B, K, *shape) trajectory, not even the (K, *shape)
                    # final row
                    sample=_host_fetch(tok.snap[k]),
                    iterations=slot.iters,
                    final_delta=slot.history[-1],
                    delta_history=np.asarray(slot.history, np.float32),
                    model_evals=self._slot_evals(slot))))
                self.slots[k] = None
                self.active[k] = False
        return completed, eff, tok.phys

    @hot_loop
    def step(self):
        """One synchronous refinement: dispatch + resolve back to back —
        the ``simulate()``/``drain()`` path, bit-identical to the
        pre-async fused step."""
        return self.resolve(self.dispatch())


class DiffusionSamplingEngine:
    """Micro-batching SRDS sampling service with per-slot convergence gating
    and a deterministic virtual clock for SLO-aware scheduling.

    Args:
      model_fn:     eps-predictor ``(x, t) -> eps`` (batched over leading x
                    axes).
      sample_shape: default per-sample tensor shape (no batch axis).
      solver:       default solver config (requests may override).
      schedule:     default schedule family name (``make_schedule`` key).
      num_steps:    default grid size for requests that don't pin one.
      batch_size:   K — slots per micro-batch (one compiled program per
                    compatibility group).
      num_blocks / max_iters / norm: SRDS knobs, as in ``SRDSConfig``.
      mesh / axis:  optional device mesh + *block* axis name: run each
                    refinement's fine solves block-parallel under
                    ``shard_map``.
      data_axis:    optional *data* axis name on ``mesh``: shard the K slot
                    batch itself (requires ``batch_size`` divisible by the
                    axis size).  Composes with ``axis`` on a 2D mesh.
      allow_inexact: accept stochastic (``ddpm``) solvers despite the
                    lane-exactness caveat (see module docstring).
      sec_per_eval: seconds charged per *physical* model eval on the
                    virtual clock, and the cost model's per-eval price
                    under **either** clock (calibrate it to measured
                    wall time per eval so ``predict_completion`` — and
                    through it CostAware admission — stays meaningful on
                    a wall clock).
      clock:        the engine's time source (:mod:`repro.serve.clock`).
                    ``None`` (default) -> a fresh deterministic
                    :class:`~repro.serve.clock.VirtualClock` — bit-exact
                    discrete-event time, what ``simulate()`` requires.
                    Pass a :class:`~repro.serve.clock.MonotonicClock`
                    for real-time serving under
                    :class:`repro.serve.async_loop.AsyncServeLoop`;
                    latency/SLO stamps then read real elapsed seconds
                    and wall deadlines (``deadline_wall``) apply.
      truncate:     converged-prefix truncation of the refinement step
                    (default on): each step program is compiled for the
                    group's quantized minimum frontier and statically skips
                    the provably bitwise-frozen block prefix — fewer
                    physical evals per step; bit-identical results for
                    elementwise-deterministic denoisers (matmul denoisers:
                    roundoff-level, see the guarantee block above).  Forced
                    off when ``axis`` is set (the block-parallel fine-solve
                    layout slices the full block dim).  Shorthand for
                    ``window=ExactPrefix()``.
      window:       explicit :class:`repro.core.window.FrontierPolicy`
                    (overrides ``truncate``): ``ResidualWindow(window_tol)``
                    opts into the approximate residual-driven group window
                    — fewer evals at a ``window_tol``-bounded quality cost
                    and a weakened per-request guarantee (see the module
                    docstring).  Truncating policies degrade to
                    ``FixedBudget`` when ``axis`` is set, like
                    ``truncate``.
      truncate_quantum: frontier quantization step (None -> B//4): bounds
                    the per-group compiled-step-program cache at
                    ~B/quantum variants.
      use_fused:    route the predictor-corrector + residual through the
                    fused Pallas kernel, whose per-tile L1 partials feed
                    the ``(K,)`` convergence residual directly.  ``None``
                    (default) = on where supported (TPU), off elsewhere.
      accel:        optional :class:`repro.core.accel.Accelerator` mixing
                    the refinement fixed point (fewer iterations to the
                    same tolerance, zero extra model evals per
                    iteration).  ``None`` (default) keeps the bit-exact
                    unaccelerated step programs byte-for-byte.  The
                    mixing state rides each micro-batch (reset per lane
                    on admission, so a recycled slot's history never
                    leaks into the next request) and the residual fetch
                    is untouched — still exactly one host sync per
                    refinement.  Iteration savings are priced honestly:
                    per-iteration ``IterationCost`` is unchanged (mixing
                    is eval-free) and :class:`IterationEMA` learns the
                    reduced per-tier iteration counts from completions,
                    which ``predict_completion`` then reflects.  Pairing
                    rule: a truncating frontier policy (the default
                    ``ExactPrefix``, or ``ResidualWindow``) requires a
                    ``prefix_exact`` accelerator (``TriangularAccel``);
                    ``AndersonAccel`` needs ``truncate=False`` /
                    ``window=FixedBudget()`` (see ``repro.core.accel``).
    """

    def __init__(self, model_fn: ModelFn, sample_shape: Tuple[int, ...],
                 solver: SolverConfig = SolverConfig("ddim"),
                 schedule: str = "ddpm_linear", num_steps: int = 64,
                 batch_size: int = 4, num_blocks: Optional[int] = None,
                 max_iters: Optional[int] = None, norm: str = "l1_mean",
                 mesh=None, axis: Optional[str] = None,
                 data_axis: Optional[str] = None,
                 allow_inexact: bool = False, sec_per_eval: float = 1e-6,
                 dtype=jnp.float32, truncate: bool = True,
                 truncate_quantum: Optional[int] = None,
                 use_fused: Optional[bool] = None, ema_alpha: float = 0.3,
                 window=None, clock: Optional[Clock] = None, accel=None):
        self.model_fn = model_fn
        # every model eval goes through the sharding-aware Denoiser seam;
        # plain callables adapt for free (replicated specs).  A
        # model-parallel denoiser is bound to the engine mesh so the coarse
        # sweep / corrector (outside any shard_map) self-wrap its shard_fn.
        den = as_denoiser(model_fn)
        if den.is_model_parallel:
            if mesh is not None:
                den.check_mesh(mesh)
                if den.mesh is None:
                    den = den.bind(mesh)
            elif den.mesh is None:
                raise ValueError(
                    "model-parallel denoiser needs a mesh: pass mesh= to "
                    "the engine or bind one with Denoiser.bind(mesh)")
        self.denoiser = den
        self.sample_shape = tuple(sample_shape)
        self.solver = solver
        self.schedule = schedule
        self.num_steps = num_steps
        self.batch_size = batch_size
        self.num_blocks = num_blocks
        self.max_iters = max_iters
        self.norm = norm
        self.mesh = mesh
        self.axis = axis
        self.data_axis = data_axis
        self.allow_inexact = allow_inexact
        self.sec_per_eval = sec_per_eval
        self.dtype = dtype
        # Frontier policy seam (repro.core.window): an explicit window
        # policy wins, else `truncate` maps to ExactPrefix/FixedBudget.
        # Block-parallel fine solves slice the full (B, K, ...) head stack
        # per device, so truncating policies degrade to FixedBudget there
        # (suffix truncation would unbalance the shards).
        pol = resolve_policy(window, truncate)
        if axis is not None and pol.truncates:
            pol = FixedBudget()
        self.window = pol
        self.truncate = pol.truncates
        self.truncate_quantum = truncate_quantum
        # fixed-point acceleration seam (repro.core.accel): with NoAccel
        # (the default) the step programs are byte-identical to the
        # pre-seam engine; an accelerating Accelerator's mixing state
        # rides each micro-batch and its step programs take/return it
        self.accel = resolve_accel(accel)
        if self.accel.accelerates and pol.truncates \
                and not self.accel.prefix_exact:
            # same pairing rule as run_parareal: truncation freezes blocks
            # on the provable serial-prefix schedule, which joint mixing
            # invalidates (see repro.core.accel)
            raise ValueError(
                f"{type(self.accel).__name__} does not preserve the "
                f"serial-prefix invariant that the engine's truncating "
                f"frontier policy ({type(pol).__name__}) relies on; use "
                f"TriangularAccel, or build the engine with truncate=False "
                f"/ window=FixedBudget().")
        self.use_fused = resolve_fused(use_fused)
        # buffer donation lets XLA reuse the trajectory-sized x_tail /
        # prev_coarse allocations across refinements; the CPU backend
        # ignores donation (with a warning), so only donate off-CPU
        self._donate = (1, 2) if jax.default_backend() != "cpu" else ()
        self.iters_ema = IterationEMA(alpha=ema_alpha)
        if data_axis is not None:
            if mesh is None:
                raise ValueError("data_axis requires a mesh")
            microbatch_spec(data_axis, mesh=mesh)   # clear unbound-axis error
            d = mesh.shape[data_axis]
            if batch_size % d != 0:
                raise ValueError(
                    f"batch_size={batch_size} not divisible by data axis "
                    f"size {d}")
        self._queue: List[Tuple[int, SampleRequest]] = []
        self._next_rid = 0
        self._programs: Dict[tuple, Tuple[Callable, Callable, int, int]] = {}
        self._batches: Dict[tuple, _MicroBatch] = {}
        self._rr = 0                      # round-robin cursor over batches
        self._first_arrival: Optional[float] = None
        # effective (per-active-slot) vs physical (per-lane) eval accounting
        self.effective_evals = 0
        self.physical_evals = 0
        self.requests_served = 0
        # the time seam: deterministic virtual time unless the caller
        # plugs in a wall clock (repro.serve.clock)
        self._clock = clock if clock is not None else VirtualClock()
        self.records: List[CompletionRecord] = []

    # ------------------------------------------------------------------ API

    @property
    def clock(self) -> float:
        """Current engine time (seconds): the deterministic accumulator
        of a :class:`~repro.serve.clock.VirtualClock`, or real elapsed
        seconds under a :class:`~repro.serve.clock.MonotonicClock`."""
        return self._clock.now()

    def request_deadline(self, req: SampleRequest) -> float:
        """``req``'s absolute deadline in THIS engine's clock regime:
        ``deadline_wall`` under a wall clock, the virtual ``deadline``
        otherwise, ``slo_ms``-relative on either.  Policies and latency
        stamping go through here so deadlines on the wrong clock are
        never compared against the running one."""
        return req.absolute_deadline(wall=self._clock.is_wall)

    def _resolve(self, req: SampleRequest):
        """(num_steps, schedule, shape, solver) with engine defaults filled."""
        n = req.num_steps if req.num_steps is not None else self.num_steps
        schedule = req.schedule if req.schedule is not None else self.schedule
        shape = tuple(req.shape) if req.shape is not None \
            else self.sample_shape
        solver = req.solver if req.solver is not None else self.solver
        return n, schedule, shape, solver

    def compat_key(self, req: SampleRequest) -> tuple:
        """The batching compatibility key: requests agreeing on
        (num_steps, schedule, shape, solver) share one micro-batch group
        and one compiled program.  Hashable (policies may group by it)."""
        n, schedule, shape, solver = self._resolve(req)
        return (n, schedule, shape, _solver_fp(solver))

    def submit(self, req: SampleRequest) -> int:
        """Enqueue a request; returns its id (key into ``drain()``'s dict).

        Invalid requests are rejected here, so they can never poison an
        already-queued batch: unservable grids (no block decomposition),
        unknown solvers/schedules, and — unless the engine was built with
        ``allow_inexact=True`` — the stochastic ``ddpm`` solver, whose
        batch-shaped noise breaks the per-request lane-exactness guarantee
        (ROADMAP caveat: same distribution, different realization than the
        single-request run).
        """
        n, schedule, shape, solver = self._resolve(req)
        resolve_blocks(n, self.num_blocks)   # raises on an unservable grid
        if solver.name not in solver_names():
            raise ValueError(f"unknown solver {solver.name!r}; "
                             f"have {solver_names()}")
        make_schedule(schedule, n)           # raises on an unknown family
        if solver.name == "ddpm" and not self.allow_inexact:
            raise ValueError(
                "stochastic 'ddpm' solver draws batch-shaped noise, so "
                "per-request lane-exactness vs the single-request run is "
                "NOT guaranteed under micro-batching; construct the engine "
                "with allow_inexact=True to accept distribution-level "
                "(not bitwise) results.")
        rid = self._next_rid
        self._next_rid += 1
        self._first_arrival = req.arrival_time \
            if self._first_arrival is None \
            else min(self._first_arrival, req.arrival_time)
        self._queue.append((rid, req))
        return rid

    def drain(self) -> Dict[int, SampleResponse]:
        """Run every queued request to convergence; returns rid -> response.

        FIFO admission over the scheduling primitives below: requests are
        admitted into free slots of their compatibility group's micro-batch
        as slots recycle; busy batches step round-robin.  Arrival times and
        deadlines are *recorded* (the virtual clock always runs) but not
        enforced — SLO-aware admission lives in
        :func:`repro.serve.scheduler.simulate`.
        """
        results: Dict[int, SampleResponse] = {}
        queue = self.pull_queue()
        while queue or self.busy():
            remaining: List[Tuple[int, SampleRequest]] = []
            for rid, req in queue:
                # not-yet-arrived requests wait: admitting one would warp
                # the clock past co-batched requests' actual service time
                if req.arrival_time <= self.clock and self.free_slots(req) > 0:
                    self.admit(rid, req)
                else:
                    remaining.append((rid, req))
            queue = remaining
            if self.busy():
                for rid, resp in self.step_once():
                    results[rid] = resp
            elif queue:
                # idle with only future-stamped work: jump to its arrival
                self.advance_clock(min(r.arrival_time for _, r in queue))
        return results

    def stats(self) -> Dict[str, float]:
        served = max(self.requests_served, 1)
        lats = [r.latency for r in self.records if r.status == "ok"]
        with_slo = [r for r in self.records if math.isfinite(r.deadline)]
        met = sum(1 for r in self.records if r.status == "ok" and r.slo_met)
        p50, p95, p99 = (np.percentile(lats, [50, 95, 99])
                         if lats else (0.0, 0.0, 0.0))
        # goodput over the served span (first *submitted* arrival -> now),
        # matching SimReport's makespan denominator — idle time before a
        # late-starting trace must not dilute it, and a rejected first
        # arrival (which leaves no completion record) still anchors it
        start = self._first_arrival if self._first_arrival is not None \
            else min((r.arrival_time for r in self.records), default=0.0)
        span = self.clock - start
        return {
            "requests_served": self.requests_served,
            "effective_evals": self.effective_evals,
            "physical_evals": self.physical_evals,
            "effective_evals_per_sample": self.effective_evals / served,
            "physical_evals_per_sample": self.physical_evals / served,
            # clock-time latency/SLO metrics (0.0 / 1.0 when idle) —
            # deterministic under the default VirtualClock, real elapsed
            # seconds under a MonotonicClock
            "latency_p50": float(p50),
            "latency_p95": float(p95),
            "latency_p99": float(p99),
            # fraction of deadline-carrying requests that finished in time
            "slo_attainment": (sum(1 for r in with_slo
                                   if r.status == "ok" and r.slo_met)
                               / len(with_slo)) if with_slo else 1.0,
            # SLO-met completions per clock second (deadline-free requests
            # always count as met)
            "goodput_rps": met / span if span > 0 else 0.0,
            # key name kept for artifact-schema stability; reads the
            # engine clock, virtual or wall
            "virtual_time": self.clock,
        }

    def reset_metrics(self) -> None:
        """Zero the clock, eval counters and latency ledger (compiled
        programs are kept — resets are for back-to-back deterministic
        simulation runs on one warm engine)."""
        if self.busy() or self._queue:
            raise RuntimeError("reset_metrics() with requests in flight")
        self._next_rid = 0
        self._rr = 0
        self._first_arrival = None
        # drop (empty) batch state: the set of instantiated groups feeds the
        # round-robin scan order, so a warm run must rebuild it exactly as a
        # fresh run would.  Compiled programs stay cached — no recompile.
        self._batches = {}
        self.effective_evals = 0
        self.physical_evals = 0
        self.requests_served = 0
        self._clock.reset()
        self.records = []
        # the learned per-tier iteration estimates are run state too: a
        # warm re-run must make the same admission decisions as a fresh one
        self.iters_ema.reset()

    # ------------------------------------------------- scheduling primitives

    def pull_queue(self) -> List[Tuple[int, SampleRequest]]:
        """Take ownership of the submitted-but-unadmitted queue (scheduler
        policies reorder/reject it; ``drain`` serves it FIFO)."""
        q, self._queue = self._queue, []
        return q

    def _batch_for(self, req: SampleRequest) -> _MicroBatch:
        key = self.compat_key(req)
        if key not in self._batches:
            n, schedule, shape, solver = self._resolve(req)
            self._batches[key] = _MicroBatch(self, n, schedule, shape, solver)
        return self._batches[key]

    def free_slots(self, req: SampleRequest) -> int:
        """Free slots in ``req``'s compatibility group's micro-batch.

        A read-only query: a group nobody was admitted to yet is all-free
        and is NOT instantiated (no device buffers, no compile) — batches
        materialize in ``admit``.
        """
        b = self._batches.get(self.compat_key(req))
        return self.batch_size if b is None else b.free_slots()

    def admit(self, rid: int, req: SampleRequest) -> None:
        """Place a validated request into its group's batch (a free slot
        must exist — check ``free_slots`` first).  Work on a request cannot
        start before it arrives, so the clock catches up to its
        ``arrival_time`` (keeps ``drain()`` latencies non-negative)."""
        self.advance_clock(req.arrival_time)
        self._batch_for(req).admit(rid, req)

    def busy(self) -> bool:
        return any(b.busy() for b in self._batches.values())

    @hot_loop
    def step_once(self) -> List[Tuple[int, SampleResponse]]:
        """One synchronous lockstep refinement on the next busy
        micro-batch (round-robin): dispatch + resolve fused back to
        back, advancing the clock by the step's physical eval cost.
        Returns completions finalized by this step.  Bit-identical to
        the pre-async engine — the asynchronous loop instead interleaves
        :meth:`step_dispatch` / :meth:`step_resolve` so device compute
        overlaps the host's blocking fetch."""
        tok = self.step_dispatch()
        if tok is None:
            return []
        return self.step_resolve(tok)

    def step_dispatch(self, max_inflight: int = 2) -> Optional[_InFlight]:
        """Dispatch one refinement on the next busy micro-batch
        (round-robin) that has fewer than ``max_inflight`` unresolved
        steps; returns an opaque token for :meth:`step_resolve`, or
        ``None`` when nothing is dispatchable.  Performs NO host sync —
        the device starts computing while the host goes on scheduling.
        Tokens must be resolved in dispatch order (oldest first)."""
        batches = list(self._batches.values())
        for off in range(len(batches)):
            b = batches[(self._rr + off) % len(batches)]
            if b.busy() and b.inflight < max_inflight:
                self._rr = (self._rr + off + 1) % len(batches)
                return b.dispatch()
        return None

    @hot_loop
    def step_resolve(self, tok: _InFlight) -> List[Tuple[int,
                                                         SampleResponse]]:
        """Land a dispatched refinement: block on its residual fetch
        (that refinement's ONE host sync), account effective/physical
        evals, charge the clock its physical cost, and finalize
        completions."""
        completed, eff, phys = tok.batch.resolve(tok)
        self.effective_evals += eff
        self.physical_evals += phys
        self._clock.charge(phys * self.sec_per_eval)
        return [(rid, self._finalize(rid, req, resp))
                for rid, req, resp in completed]

    def evict(self, rid: int) -> SampleResponse:
        """Preempt a running request (scheduler policy decision); its
        partial work is discarded and recorded as status="preempted"."""
        for b in self._batches.values():
            try:
                req, resp = b.evict(rid)
            except KeyError:
                continue
            return self._finalize(rid, req, resp)
        raise KeyError(f"request {rid} is not running")

    def advance_clock(self, until: float) -> None:
        """Idle the engine forward (no work to do before the next
        arrival): a virtual clock warps, a wall clock really sleeps."""
        self._clock.wait_until(until)

    def predict_iterations(self, req: SampleRequest) -> float:
        """Expected refinement count for ``req``: the *most optimistic* of
        the online per-tier EMA (:class:`IterationEMA`, fed by completed
        requests of the same ``(compat_key, tol)`` tier) and the caller's
        static ``iters_hint``; worst-case ``max_iters`` when neither
        exists.  Taking the minimum keeps CostAware's rejection on the
        optimistic side: the EMA is a *mean*, so alone it could exceed an
        easier-than-average request's true need and over-reject."""
        n, _, _, _ = self._resolve(req)
        B, _ = resolve_blocks(n, self.num_blocks)
        cap = self.max_iters if self.max_iters is not None else B
        cands = [self.iters_ema.predict((self.compat_key(req),
                                         float(req.tol)))]
        if req.iters_hint is not None:
            cands.append(float(req.iters_hint))
        cands = [c for c in cands if c is not None]
        est = min(cands) if cands else float(cap)
        return min(float(est), float(cap))

    def predict_completion(self, req: SampleRequest,
                           now: Optional[float] = None) -> float:
        """Cost-model completion estimate (virtual seconds) if ``req`` were
        admitted now: the frontier policy's own per-iteration eval pricing
        (:meth:`repro.core.window.FrontierPolicy.predict_evals` — for the
        default ``ExactPrefix``, the exact frontier schedule the step
        programs execute) times the physical K-lane width, for
        :meth:`predict_iterations` refinements — **plus cross-group device
        contention**: busy micro-batches step round-robin on the one
        device, so every *other currently-busy* group charges one step at
        its current frontier cost per refinement round this request needs.
        Within those terms the estimate stays optimistic — the frontier is
        assumed to advance every refinement, contending groups are priced
        at today's (only-shrinking) step cost and assumed not to grow, and
        the iteration estimate is the smallest available one — so
        rejection sheds requests hopeless under the *currently visible*
        load.  (Both the iteration estimate and the contention snapshot
        are estimates: a contending group can drain early, so 'never
        over-rejects' holds relative to them, not as an absolute.)"""
        now = self.clock if now is None else now
        n, _, _, solver = self._resolve(req)
        cost = iteration_cost(n, self.num_blocks, solver.evals_per_step)
        iters = self.predict_iterations(req)
        evals = self.batch_size * self.window.predict_evals(cost, iters)
        key = self.compat_key(req)
        rounds = int(math.ceil(iters))
        contention = rounds * sum(
            b.step_evals() for bkey, b in self._batches.items()
            if bkey != key and b.busy())
        return now + (evals + contention) * self.sec_per_eval

    def _finalize(self, rid: int, req: SampleRequest,
                  resp: SampleResponse) -> SampleResponse:
        """Stamp virtual-clock latency/SLO fields and ledger the outcome."""
        resp.arrival_time = req.arrival_time
        resp.finish_time = self.clock
        resp.latency = resp.finish_time - req.arrival_time
        resp.deadline = self.request_deadline(req)
        resp.slo_met = resp.status == "ok" \
            and resp.finish_time <= resp.deadline
        if resp.status == "ok":
            self.requests_served += 1
            # feed the online per-tier iterations predictor
            self.iters_ema.observe((self.compat_key(req), float(req.tol)),
                                   resp.iterations)
        self.records.append(CompletionRecord(
            rid=rid, arrival_time=resp.arrival_time,
            finish_time=resp.finish_time, deadline=resp.deadline,
            latency=resp.latency, slo_met=resp.slo_met, status=resp.status))
        return resp

    # ------------------------------------------------------- compiled cells

    def _build_program(self, n: int, schedule: str, shape: Tuple[int, ...],
                       solver: SolverConfig):
        """(init_fn, step_for, B, S) for one compatibility group (cached).

        ``step_for(minf)`` returns the jitted one-refinement program whose
        fine solves and corrector sweep are statically truncated to the
        block suffix ``[minf, B)`` (one compiled variant per quantized
        frontier value, cached).  ``x_tail``/``prev_coarse`` are donated so
        XLA reuses the trajectory-sized buffers across refinements.
        """
        key = (n, schedule, shape, _solver_fp(solver))
        if key in self._programs:
            return self._programs[key]
        B, S = resolve_blocks(n, self.num_blocks)
        sched = make_schedule(schedule, n)
        # run the schedule in the engine's working dtype so results match a
        # standalone srds_sample on the same-dtype schedule bit for bit
        sched = DiffusionSchedule(ab=sched.ab.astype(self.dtype),
                                  t_model=sched.t_model.astype(self.dtype),
                                  kind=sched.kind)
        starts = jnp.arange(B, dtype=jnp.int32) * S
        den, norm = self.denoiser, self.norm
        use_fused = self.use_fused
        accel = self.accel

        def G(x, i0):
            # coarse sweep + corrector run outside any shard_map: the
            # seam's standalone call (a model-parallel denoiser self-wraps
            # its shard_fn over the bound mesh; a plain one is just fn)
            return solve(den, sched, solver, x, i0, 1, S)

        def fine_F(eval_fn):
            # fine-solve factory: _make_fine picks the seam composition
            # (standalone den for the vmap path, den.shard_eval() inside
            # the shard_map whose specs come from denoiser_spec)
            def F(x, i0):
                return solve(eval_fn, sched, solver, x, i0, S, 1)
            return F

        fine = self._make_fine(fine_F, starts, B)

        def init_body(x_init, x_tail, prev_coarse, new_mask):
            # coarse initialization sweep for the whole slot batch, with
            # the new-lane write-back fused in so x_tail/prev_coarse are
            # donated (occupied lanes keep their refined trajectories —
            # the old value flows through the jnp.where)
            tail0 = coarse_init_sweep(G, x_init, starts)
            m = new_mask.reshape((1,) + new_mask.shape + (1,) * len(shape))
            return (jnp.where(m, tail0, x_tail),
                    jnp.where(m, tail0, prev_coarse))

        init_fn = jax.jit(init_body, donate_argnums=self._donate)

        step_cache: Dict[int, Callable] = {}
        step_win_cache: Dict[int, Callable] = {}

        def make_step(minf: int):
            if accel.accelerates:
                def step_accel(x_init, x_tail, prev_coarse, active, astate):
                    """Accelerated refinement: the unaccelerated step's
                    math, then one :meth:`Accelerator.apply` on the joint
                    state (per-lane, live-masked to the compiled suffix).
                    The residual is recomputed post-mix — the gate must
                    see what was actually committed — and still rides the
                    program's one output fetch."""
                    heads = jnp.concatenate([x_init[None], x_tail[:-1]],
                                            axis=0)
                    if minf:
                        heads = heads[minf:]
                    y = fine(heads)
                    new_tail, cur_all, _ = suffix_refinement(
                        G, y, x_init, x_tail, prev_coarse, starts, minf,
                        use_fused=use_fused, norm=norm, batched=True)
                    m = active.reshape((1,) + active.shape
                                       + (1,) * (x_tail.ndim - 2))
                    new_tail = jnp.where(m, new_tail, x_tail)
                    cur_all = jnp.where(m, cur_all, prev_coarse)
                    live = (jnp.arange(B, dtype=jnp.int32) >= minf) \
                        if minf else None
                    z_mix, astate = accel.apply(
                        astate, jnp.stack([x_tail, prev_coarse]),
                        jnp.stack([new_tail, cur_all]), live=live,
                        batched=True)
                    # inactive lanes are fixed points of the mix (f = 0);
                    # the re-mask makes that bitwise, not just numeric
                    new_tail = jnp.where(m, z_mix[0], x_tail)
                    cur_all = jnp.where(m, z_mix[1], prev_coarse)
                    delta = convergence_norm(new_tail[-1] - x_tail[-1],
                                             norm, batched=True)
                    delta = jnp.where(active, delta, jnp.inf)
                    return new_tail, cur_all, delta, astate

                donate = self._donate + (4,) if self._donate else ()
                return jax.jit(step_accel, donate_argnums=donate)

            def step_fn(x_init, x_tail, prev_coarse, active):
                """One Parareal refinement over all K slots, truncated to
                the suffix [minf, B) via the engine's shared
                :func:`suffix_refinement`; inactive slots (free, or
                holding a finished sample) are frozen no-ops."""
                heads = jnp.concatenate([x_init[None], x_tail[:-1]], axis=0)
                if minf:
                    heads = heads[minf:]
                y = fine(heads)
                new_tail, cur_all, delta = suffix_refinement(
                    G, y, x_init, x_tail, prev_coarse, starts, minf,
                    use_fused=use_fused, norm=norm, batched=True)
                m = active.reshape((1,) + active.shape
                                   + (1,) * (x_tail.ndim - 2))
                new_tail = jnp.where(m, new_tail, x_tail)
                cur_all = jnp.where(m, cur_all, prev_coarse)
                # inactive lanes' pre-mask residual entries are discarded
                delta = jnp.where(active, delta, jnp.inf)
                return new_tail, cur_all, delta

            return jax.jit(step_fn, donate_argnums=self._donate)

        def make_step_windowed(minf: int):
            if accel.accelerates:
                def step_accel(x_init, x_tail, prev_coarse, active, lo,
                               astate):
                    """Accelerated residual-window refinement: mixing is
                    live-masked to the dynamic window ``[lo, B)`` —
                    window-frozen blocks stay bitwise untouched — and the
                    per-block residuals are recomputed post-mix before
                    the lane-max reduction, so the window only advances
                    past blocks whose *committed* values converged."""
                    heads = jnp.concatenate([x_init[None], x_tail[:-1]],
                                            axis=0)
                    if minf:
                        heads = heads[minf:]
                    y = fine(heads)
                    new_tail, cur_all, _, _ = suffix_refinement(
                        G, y, x_init, x_tail, prev_coarse, starts, minf,
                        use_fused=use_fused, norm=norm, batched=True,
                        window_lo=lo)
                    m = active.reshape((1,) + active.shape
                                       + (1,) * (x_tail.ndim - 2))
                    new_tail = jnp.where(m, new_tail, x_tail)
                    cur_all = jnp.where(m, cur_all, prev_coarse)
                    live = jnp.arange(B, dtype=jnp.int32) >= lo
                    z_mix, astate = accel.apply(
                        astate, jnp.stack([x_tail, prev_coarse]),
                        jnp.stack([new_tail, cur_all]), live=live,
                        batched=True)
                    new_tail = jnp.where(m, z_mix[0], x_tail)
                    cur_all = jnp.where(m, z_mix[1], prev_coarse)
                    # full-width post-mix block residuals: frozen blocks
                    # are bitwise unchanged, so their rows are exactly 0
                    br = blockwise_norm(new_tail - x_tail, norm,
                                        batched=True)
                    delta = jnp.where(active, br[-1], jnp.inf)
                    br_g = jnp.max(jnp.where(active[None, :], br, 0.0),
                                   axis=1)
                    return (new_tail, cur_all,
                            jnp.concatenate([delta, br_g]), astate)

                donate = self._donate + (5,) if self._donate else ()
                return jax.jit(step_accel, donate_argnums=donate)

            def step_fn(x_init, x_tail, prev_coarse, active, lo):
                """One residual-window refinement over all K slots: the
                compiled suffix is [minf, B), blocks [minf, lo) freeze by
                masking inside the engine's shared
                :func:`suffix_refinement`, and the (B,) lane-max per-block
                residual piggybacks on the (K,) residual so the host still
                syncs exactly once."""
                heads = jnp.concatenate([x_init[None], x_tail[:-1]], axis=0)
                if minf:
                    heads = heads[minf:]
                y = fine(heads)
                new_tail, cur_all, delta, br = suffix_refinement(
                    G, y, x_init, x_tail, prev_coarse, starts, minf,
                    use_fused=use_fused, norm=norm, batched=True,
                    window_lo=lo)
                m = active.reshape((1,) + active.shape
                                   + (1,) * (x_tail.ndim - 2))
                new_tail = jnp.where(m, new_tail, x_tail)
                cur_all = jnp.where(m, cur_all, prev_coarse)
                # inactive lanes' pre-mask residual entries are discarded
                delta = jnp.where(active, delta, jnp.inf)
                # group per-block residual: max over active lanes — the
                # shared window only advances past blocks EVERY active
                # lane passed (inactive lanes don't refine, so they never
                # hold the window back)
                br_g = jnp.max(jnp.where(active[None, :], br, 0.0), axis=1)
                if minf:
                    br_g = jnp.concatenate(
                        [jnp.zeros((minf,), br_g.dtype), br_g])
                return new_tail, cur_all, jnp.concatenate([delta, br_g])

            return jax.jit(step_fn, donate_argnums=self._donate)

        def step_for(minf: int) -> Callable:
            if minf not in step_cache:
                step_cache[minf] = make_step(minf)
            return step_cache[minf]

        def step_windowed(minf: int) -> Callable:
            if minf not in step_win_cache:
                step_win_cache[minf] = make_step_windowed(minf)
            return step_win_cache[minf]

        step_for.cache = step_cache     # introspectable: compiled variants
        step_for.windowed = step_windowed
        step_windowed.cache = step_win_cache

        self._programs[key] = (init_fn, step_for, B, S)
        return self._programs[key]

    def _make_fine(self, fine_F, starts, B: int):
        """The fine-solve hook: vmapped in one program, or shard_mapped over
        the block axis (``axis``), the slot batch (``data_axis``), the
        denoiser's own model axes, or any combination.

        Block parallelism slices the local blocks by ``axis_index`` and
        re-joins them with one tiled ``all_gather`` per iteration (the
        :func:`repro.core.pipelined.srds_sharded_local` layout); slot-batch
        parallelism needs no collectives at all — lanes are independent, so
        ``shard_map`` just splits the K axis.  A model-parallel
        :class:`~repro.core.denoiser.Denoiser` contributes its ``in_spec``
        sample axes to the same specs via
        :func:`repro.parallel.sharding.denoiser_spec`, and the body
        evaluates its ``shard_eval()`` directly — no per-eval collectives
        beyond the backbone's own.  That is the (time, data, model)
        composition: one shard_map, zero driver-specific model code.
        """
        den = self.denoiser
        if self.mesh is None or (self.axis is None and self.data_axis is None
                                 and not den.is_model_parallel):
            F = fine_F(den)   # standalone seam: self-wraps if model-parallel

            def fine(x_heads):
                # truncated step programs pass the active suffix; recover
                # the static offset from the stack length
                f = B - x_heads.shape[0]
                return jax.vmap(F)(x_heads, starts[f:] if f else starts)
            return fine

        heads_spec = denoiser_spec(self.data_axis, den, mesh=self.mesh)
        F = fine_F(den.shard_eval())   # specs already shard per in_spec

        if self.axis is not None:
            axis = self.axis
            d_axis = self.mesh.shape[axis]
            if B % d_axis != 0:
                raise ValueError(
                    f"num_blocks={B} not divisible by axis size {d_axis}")

            def fine_local(x_heads):
                d = compat.axis_size(axis)
                me = jax.lax.axis_index(axis)
                b_local = B // d
                my = jax.lax.dynamic_slice_in_dim(x_heads, me * b_local,
                                                  b_local)
                my_starts = jax.lax.dynamic_slice_in_dim(starts, me * b_local,
                                                         b_local)
                y_local = jax.vmap(F)(my, my_starts)
                return jax.lax.all_gather(y_local, axis, tiled=True)
        else:
            def fine_local(x_heads):
                f = B - x_heads.shape[0]
                return jax.vmap(F)(x_heads, starts[f:] if f else starts)

        return compat.shard_map(fine_local, mesh=self.mesh,
                                in_specs=heads_spec, out_specs=heads_spec,
                                check_vma=False)
