"""Batched diffusion sampling service over the batch-aware SRDS engine.

:class:`DiffusionSamplingEngine` mirrors :class:`repro.serve.engine.
ServingEngine` for diffusion workloads: callers ``submit`` sampling
requests carrying their own ``(tol, num_steps, seed)``, the engine packs
*compatible* requests (same trajectory grid — the micro-batch shares one
block decomposition and one compiled program) into fixed-size micro-batches
of ``batch_size`` slots, and drives the Parareal refinement loop one
iteration at a time across the whole batch.

Slot recycling is the throughput story: convergence is gated **per slot**
(the engine's per-sample semantics — every slot's refinement is
bit-identical to an independent :func:`repro.core.parareal.srds_sample`
call with that request's tolerance), so the moment a sample converges its
slot is freed and the next queued request is admitted into it, instead of
the whole batch idling until the slowest sample finishes.  Under lockstep
whole-batch gating a micro-batch pays ``K * max_k(iters_k)`` refinements;
with recycling it pays ``sum_k(iters_k)`` (plus a drain tail), which is
where the "effective model evals per sample" win in
``benchmarks/table9_batched.py`` comes from.

What the engine does / does not guarantee:

* per-request exactness: each returned sample equals the single-request
  SRDS result for that ``(tol, num_steps, seed)`` — admission order and
  batch-mates do not perturb it (converged/empty lanes are frozen with
  ``jnp.where``, never fed back);
* eval accounting is *effective* (per-active-slot): lockstep SPMD still
  computes masked lanes, so physical compute equals effective compute only
  while the queue keeps every slot busy — exactly the heavy-traffic regime
  the service targets.  ``stats()`` reports both so the gap is visible;
* no preemption and no cross-``num_steps`` batching: requests on different
  grids run in separate micro-batch groups (one compiled program each);
* deterministic solvers only for the exactness guarantee — the frozen-noise
  ``ddpm`` solver draws noise shaped like the *batch*, so its lanes differ
  from single-request runs (same distribution, different realization).

The refinement step can optionally run block-parallel under ``shard_map``
(``mesh``/``axis``): fine solves execute locally per device slice of the
block axis and are re-joined with one ``all_gather`` per iteration — the
same layout as :func:`repro.core.pipelined.srds_sharded_local`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.engine import (coarse_init_sweep, convergence_norm,
                               corrector_sweep, resolve_blocks)
from repro.core.schedules import DiffusionSchedule, make_schedule
from repro.core.solvers import ModelFn, SolverConfig, solve

__all__ = ["SampleRequest", "SampleResponse", "DiffusionSamplingEngine"]


@dataclasses.dataclass
class SampleRequest:
    """One sampling job: draw x_init ~ N(0, I) from ``seed`` and run SRDS
    to the requester's tolerance on a ``num_steps`` grid."""
    seed: int
    tol: float = 1e-3
    num_steps: Optional[int] = None      # None -> engine default grid


@dataclasses.dataclass
class SampleResponse:
    sample: np.ndarray
    iterations: int
    final_delta: float
    delta_history: np.ndarray            # (iterations,) — converged prefix
    model_evals: int                     # effective evals charged to this job


class _Slot:
    __slots__ = ("rid", "req", "iters", "history")

    def __init__(self, rid: int, req: SampleRequest):
        self.rid = rid
        self.req = req
        self.iters = 0
        self.history: List[float] = []


class DiffusionSamplingEngine:
    """Micro-batching SRDS sampling service with per-slot convergence gating.

    Args:
      model_fn:     eps-predictor ``(x, t) -> eps`` (batched over leading x
                    axes).
      sample_shape: per-sample tensor shape (no batch axis).
      solver:       shared solver config for all requests.
      schedule:     schedule family name (``make_schedule`` key).
      num_steps:    default grid size for requests that don't pin one.
      batch_size:   K — slots per micro-batch (one compiled program).
      num_blocks / max_iters / norm: SRDS knobs, as in ``SRDSConfig``.
      mesh / axis:  optional device mesh: run each refinement's fine solves
                    block-parallel under ``shard_map`` along ``axis``.
    """

    def __init__(self, model_fn: ModelFn, sample_shape: Tuple[int, ...],
                 solver: SolverConfig = SolverConfig("ddim"),
                 schedule: str = "ddpm_linear", num_steps: int = 64,
                 batch_size: int = 4, num_blocks: Optional[int] = None,
                 max_iters: Optional[int] = None, norm: str = "l1_mean",
                 mesh=None, axis: Optional[str] = None,
                 dtype=jnp.float32):
        self.model_fn = model_fn
        self.sample_shape = tuple(sample_shape)
        self.solver = solver
        self.schedule = schedule
        self.num_steps = num_steps
        self.batch_size = batch_size
        self.num_blocks = num_blocks
        self.max_iters = max_iters
        self.norm = norm
        self.mesh = mesh
        self.axis = axis
        self.dtype = dtype
        self._queue: List[Tuple[int, SampleRequest]] = []
        self._next_rid = 0
        self._programs: Dict[int, Tuple[Callable, Callable, int, int]] = {}
        # effective (per-active-slot) vs physical (per-lane) eval accounting
        self.effective_evals = 0
        self.physical_evals = 0
        self.requests_served = 0

    # ------------------------------------------------------------------ API

    def submit(self, req: SampleRequest) -> int:
        """Enqueue a request; returns its id (key into ``drain()``'s dict).

        Invalid requests (e.g. a grid with no block decomposition) are
        rejected here, so they can never poison an already-queued batch.
        """
        n = req.num_steps if req.num_steps is not None else self.num_steps
        resolve_blocks(n, self.num_blocks)   # raises on an unservable grid
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, req))
        return rid

    def drain(self) -> Dict[int, SampleResponse]:
        """Run every queued request to convergence; returns rid -> response.

        Requests are grouped by grid size (the compatibility key) and each
        group is served by one fixed-size micro-batch with slot recycling.
        """
        results: Dict[int, SampleResponse] = {}
        by_grid: Dict[int, List[Tuple[int, SampleRequest]]] = {}
        for rid, req in self._queue:
            n = req.num_steps if req.num_steps is not None else self.num_steps
            by_grid.setdefault(n, []).append((rid, req))
        self._queue.clear()
        for n, group in sorted(by_grid.items()):
            results.update(self._drain_group(n, group))
        return results

    def stats(self) -> Dict[str, float]:
        served = max(self.requests_served, 1)
        return {
            "requests_served": self.requests_served,
            "effective_evals": self.effective_evals,
            "physical_evals": self.physical_evals,
            "effective_evals_per_sample": self.effective_evals / served,
            "physical_evals_per_sample": self.physical_evals / served,
        }

    # ------------------------------------------------------- compiled cells

    def _program(self, n: int):
        """(init_fn, step_fn, B, S) for grid size ``n`` (cached per grid)."""
        if n in self._programs:
            return self._programs[n]
        B, S = resolve_blocks(n, self.num_blocks)
        sched = make_schedule(self.schedule, n)
        # run the schedule in the engine's working dtype so results match a
        # standalone srds_sample on the same-dtype schedule bit for bit
        sched = DiffusionSchedule(ab=sched.ab.astype(self.dtype),
                                  t_model=sched.t_model.astype(self.dtype),
                                  kind=sched.kind)
        starts = jnp.arange(B, dtype=jnp.int32) * S
        model_fn, solver, norm = self.model_fn, self.solver, self.norm

        def G(x, i0):
            return solve(model_fn, sched, solver, x, i0, 1, S)

        def F(x, i0):
            return solve(model_fn, sched, solver, x, i0, S, 1)

        if self.mesh is not None:
            axis = self.axis
            d_axis = self.mesh.shape[axis]
            if B % d_axis != 0:
                raise ValueError(
                    f"num_blocks={B} not divisible by axis size {d_axis}")

            def fine_local(x_heads):
                d = compat.axis_size(axis)
                me = jax.lax.axis_index(axis)
                b_local = B // d
                my = jax.lax.dynamic_slice_in_dim(x_heads, me * b_local,
                                                  b_local)
                my_starts = jax.lax.dynamic_slice_in_dim(starts, me * b_local,
                                                         b_local)
                y_local = jax.vmap(F)(my, my_starts)
                return jax.lax.all_gather(y_local, axis, tiled=True)

            fine = compat.shard_map(fine_local, mesh=self.mesh, in_specs=P(),
                                    out_specs=P(), check_vma=False)
        else:
            def fine(x_heads):
                return jax.vmap(F)(x_heads, starts)

        @jax.jit
        def init_fn(x_init):
            # coarse initialization sweep for the whole slot batch
            return coarse_init_sweep(G, x_init, starts)

        @jax.jit
        def step_fn(x_init, x_tail, prev_coarse, active):
            """One Parareal refinement over all K slots; inactive slots
            (free, or holding a finished sample) are frozen no-ops."""
            x_heads = jnp.concatenate([x_init[None], x_tail[:-1]], axis=0)
            y = fine(x_heads)
            new_tail, cur_all = corrector_sweep(G, x_init, y, prev_coarse,
                                                starts)
            m = active.reshape((1,) + active.shape
                               + (1,) * (x_tail.ndim - 2))
            new_tail = jnp.where(m, new_tail, x_tail)
            cur_all = jnp.where(m, cur_all, prev_coarse)
            delta = convergence_norm(new_tail[-1] - x_tail[-1], norm,
                                     batched=True)
            delta = jnp.where(active, delta, jnp.inf)
            return new_tail, cur_all, delta

        self._programs[n] = (init_fn, step_fn, B, S)
        return self._programs[n]

    # ------------------------------------------------------ the batch loop

    def _drain_group(self, n: int, group: List[Tuple[int, SampleRequest]]):
        init_fn, step_fn, B, S = self._program(n)
        max_iters = self.max_iters if self.max_iters is not None else B
        e = self.solver.evals_per_step
        K = self.batch_size
        shape = (K,) + self.sample_shape

        x_init = jnp.zeros(shape, self.dtype)
        x_tail = jnp.zeros((B,) + shape, self.dtype)
        prev_coarse = jnp.zeros((B,) + shape, self.dtype)
        active = np.zeros((K,), bool)
        slots: List[Optional[_Slot]] = [None] * K
        pending = list(group)
        results: Dict[int, SampleResponse] = {}

        def finalize(k: int, slot: _Slot, tail_np):
            results[slot.rid] = SampleResponse(
                sample=np.asarray(tail_np[k]),
                iterations=slot.iters,
                final_delta=slot.history[-1] if slot.history else float("inf"),
                delta_history=np.asarray(slot.history, np.float32),
                model_evals=(B + slot.iters * (B * S + B)) * e)
            self.requests_served += 1
            slots[k] = None
            active[k] = False

        while pending or any(s is not None for s in slots):
            # ---- admit queued requests into free slots ----
            newly = []
            for k in range(K):
                if slots[k] is None and pending:
                    rid, req = pending.pop(0)
                    x0 = jax.random.normal(jax.random.PRNGKey(req.seed),
                                           self.sample_shape, self.dtype)
                    x_init = x_init.at[k].set(x0)
                    slots[k] = _Slot(rid, req)
                    active[k] = True
                    newly.append(k)
            if newly:
                # coarse-init the fixed batch; write back only the new lanes
                # (occupied lanes must keep their refined trajectories)
                tail0 = init_fn(x_init)
                m = jnp.zeros((K,), bool).at[jnp.asarray(newly)].set(True)
                m = m.reshape((1, K) + (1,) * len(self.sample_shape))
                x_tail = jnp.where(m, tail0, x_tail)
                prev_coarse = jnp.where(m, tail0, prev_coarse)
                self.effective_evals += len(newly) * B * e
                self.physical_evals += K * B * e

            # ---- one lockstep refinement across all occupied slots ----
            amask = jnp.asarray(active)
            x_tail, prev_coarse, delta = step_fn(x_init, x_tail, prev_coarse,
                                                 amask)
            n_active = int(active.sum())
            self.effective_evals += n_active * (B * S + B) * e
            self.physical_evals += K * (B * S + B) * e

            delta_np = np.asarray(delta)
            tail_np = None
            for k in range(K):
                slot = slots[k]
                if slot is None or not active[k]:
                    continue
                slot.iters += 1
                slot.history.append(float(delta_np[k]))
                # f32 compare, matching the engine's still_refining gate
                if (delta_np[k] < np.float32(slot.req.tol)
                        or slot.iters >= max_iters):
                    if tail_np is None:
                        tail_np = np.asarray(x_tail[-1])
                    finalize(k, slot, tail_np)
        return results
