from .checkpointer import Checkpointer
