"""Sharded, elastic, async checkpointing (no external deps).

Layout:  <dir>/step_<N>/
             manifest.json     — treedef, shapes, dtypes, step, metadata
             host<k>.npz       — this host's gathered leaf arrays
         <dir>/LATEST          — atomic pointer (written last = commit)

Properties:
  * atomic commit: data goes to ``step_N.tmp`` then a single rename + the
    LATEST pointer update, so a preemption mid-save never corrupts the
    previous checkpoint (restore ignores .tmp dirs);
  * elastic restore: leaves are saved *unsharded* (fully gathered); restore
    applies whatever shardings the new mesh prescribes — scale-up/down and
    re-toplogy are tested in tests/test_checkpoint.py;
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a worker thread; ``wait()`` joins before the next save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


def _to_storable(a: np.ndarray) -> np.ndarray:
    """npz cannot round-trip ml_dtypes (bf16/fp8): store a bit-view."""
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16)
    if a.dtype in (ml_dtypes.float8_e4m3fn, ml_dtypes.float8_e5m2):
        return a.view(np.uint8)
    return a


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        return a.view(ml_dtypes.bfloat16)
    if dtype_str.startswith("float8"):
        return a.view(getattr(ml_dtypes, dtype_str))
    return a


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None

    # ---- save ------------------------------------------------------------

    def _write(self, step: int, flat_np, treedef_str, meta):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": treedef_str,
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in flat_np],
            "meta": meta or {},
            "hosts": jax.process_count(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        np.savez(os.path.join(tmp, f"host{jax.process_index()}.npz"),
                 **{_leaf_key(i): _to_storable(a)
                    for i, a in enumerate(flat_np)})
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _snapshot(self, tree):
        flat, treedef = jax.tree.flatten(tree)
        # gather to host (unsharded view) — elastic restore needs full arrays
        flat_np = [np.asarray(jax.device_get(x)) for x in flat]
        return flat_np, str(treedef)

    def save(self, step: int, tree, meta: Optional[dict] = None):
        self.wait()
        flat_np, td = self._snapshot(tree)
        self._write(step, flat_np, td, meta)

    def save_async(self, step: int, tree, meta: Optional[dict] = None):
        self.wait()
        flat_np, td = self._snapshot(tree)          # sync host snapshot
        self._pending = self._pool.submit(self._write, step, flat_np, td, meta)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---- restore -----------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, template, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedSharding
        for elastic placement (None -> default device)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, f"host{jax.process_index()}.npz"))
        flat_t, treedef = jax.tree.flatten(template)
        assert len(flat_t) == len(manifest["leaves"]), \
            f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs template {len(flat_t)}"
        flat_s = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat_t))
        out = []
        for i, (t, s) in enumerate(zip(flat_t, flat_s)):
            a = _from_storable(data[_leaf_key(i)],
                               manifest["leaves"][i]["dtype"])
            if tuple(a.shape) != tuple(t.shape):
                raise ValueError(f"shape mismatch leaf {i}: {a.shape} vs {t.shape}")
            a = a.astype(t.dtype)
            out.append(jax.device_put(a, s) if s is not None else jnp.asarray(a))
        return jax.tree.unflatten(treedef, out), manifest["step"], manifest["meta"]
