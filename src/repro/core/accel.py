"""Fixed-point acceleration for Parareal refinement — the ONE home of
Anderson/triangular mixing math.

SRDS's refinement loop is a fixed-point iteration ``z_{p+1} = T(z_p)``
over the joint state ``z = (x_tail, prev_coarse)``: one refinement maps
the current trajectory tail and coarse results to the next, and the
whole cost model is the number of applications of ``T`` until the
convergence residual passes tolerance.  Anderson acceleration (AA)
extrapolates over the iterate history the loop already carries —
mixing the last ``m`` iterate/residual differences through a tiny
least-squares solve — and typically reaches the same tolerance in
noticeably fewer applications of ``T``, i.e. fewer full fine sweeps.
ParaTAA ("Accelerating Parallel Sampling of Diffusion Models",
PAPERS.md) specializes AA to exactly this triangular Parareal fixed
point.

An :class:`Accelerator` is the seam every driver consumes (sibling of
:class:`repro.core.window.FrontierPolicy`): the engine's shared
refinement bodies call :meth:`Accelerator.apply` on the post-sweep
joint state, and the serving engine's per-frontier step programs do the
same — the mixing math lives here exactly once (reprolint rule RL009).
Three implementations ship:

``NoAccel``
    The default: no mixing, no extra loop carry (``RefineState.accel``
    stays ``None`` — an empty pytree — so compiled carries are
    byte-identical to the pre-seam engine).  **Bit-exact**: the repo's
    exactness guarantee is untouched when acceleration is off.

``AndersonAccel(depth=m)``
    Classical type-II Anderson mixing over a sliding window of the last
    ``m`` iterate/residual differences, solved per sample via a
    regularized ``m×m`` normal-equations system.  **Approximate,
    opt-in**: mixed iterates are no longer the serial solve's iterates,
    so intermediate trajectories differ from the unaccelerated engine —
    but the *fixed point is the same* (at the fixed point the residual
    ``f = T(z) - z`` is 0 and mixing is the identity), so converged
    samples agree with the serial solve up to the convergence tolerance.
    ``benchmarks/table13_accel.py`` measures the max-vs-serial error per
    config and CI asserts the bound.  Mixing is a handful of reductions
    and an ``m×m`` solve — **zero extra model evals** — so every mixed
    iteration costs exactly what a plain one does, and any iteration cut
    is a pure win.

``TriangularAccel``
    Prefix-exact variant exploiting the triangular structure of the
    Parareal trajectory map (block ``i``'s fine solve depends only on
    blocks ``< i``): mixing is restricted to the not-yet-exact
    ``x_tail`` block suffix — the serial-exact leading blocks commit
    the raw iterate and are excluded from the secant system, and the
    coarse component is never mixed.  By induction the protected prefix
    stays exactly the serial solve's (a capped run returns the bitwise
    serial result), which is what lets it compose with ``ExactPrefix``
    truncation without freezing mixed values — the conservative choice;
    :class:`AndersonAccel` is the stronger accelerator (see the
    interaction table in docs/acceleration.md).

Driver notes
------------

* The **engine** (:func:`repro.core.engine.run_parareal`) applies the
  accelerator inside the one shared refinement body, *after* the
  corrector sweep and convergence-gate masking, with the live-block
  mask of the active window — so mixing composes with per-sample gating
  and, for ``prefix_exact`` accelerators, with ``ExactPrefix``
  truncation and ``ResidualWindow``, all with no new host syncs.
  Truncating policies freeze blocks on the provable serial-prefix
  schedule — a theorem about the plain iteration — so the engine
  refuses to pair them with joint mixing (``AndersonAccel``), which
  breaks that invariant; use ``TriangularAccel`` there, or run
  ``AndersonAccel`` untruncated (``FixedBudget``).  The convergence
  residual is recomputed post-mix (mixing moves the final block, and
  the gate must see what was actually committed).
* The **sharded** driver inherits the engine loop unchanged: mixing is
  deterministic elementwise math over replicated carries, so every
  device computes the same mixed state.  Straggler reuse
  (``carry_fine_results``) is incompatible — stale fine results are not
  iterates of the mixed sequence — and raises.
* The **wavefront** distributes one block per device with no central
  iterate history, so accelerating accelerators raise there (an
  explicit error beats a silent no-op).
* The **serving engine** applies the same seam in its per-quantized-
  frontier step programs; the accelerator state rides the micro-batch
  (reset per lane on admission via :meth:`Accelerator.reset_lanes`) and
  the residual fetch is unchanged — still exactly one host sync per
  refinement.

Frozen-content invariant: wherever a driver freezes content — the
truncated prefix, window-masked blocks, gate-masked converged lanes —
``z_new == z_prev`` bitwise, hence ``f = 0``, and ``apply`` masks its
history columns by the same live mask, so the mixed value is exactly
``z_prev``: frozen content stays bitwise untouched through mixing.

Cost-model note: mixing adds **zero model evals**, so
:class:`repro.core.engine.IterationCost` is unchanged per iteration —
the speedup is entirely fewer iterations, which the serving layer's
:class:`IterationEMA` learns from completions and
``predict_completion`` then reflects (the EMA prior before any
completion is ``max_iters``, an upper bound — the same conservative
semantics as ``ResidualWindow.predict_evals``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AccelState", "Accelerator", "NoAccel", "AndersonAccel",
           "TriangularAccel", "resolve_accel"]


class AccelState(NamedTuple):
    """Loop carry of an accelerating :class:`Accelerator` (``None`` under
    :class:`NoAccel` — an empty pytree, so unaccelerated compiled carries
    are unchanged).

    ``z`` is the joint iterate ``stack([x_tail, prev_coarse])`` of shape
    ``(2, B, ...)`` — or ``(2, B, K, ...)`` per sample — and the rings
    hold its last ``m`` differences (newest last, zero-filled until the
    history warms up; ``count`` gates which columns are valid).
    """
    dz: jnp.ndarray      # (m, 2, B, [K,] ...) iterate-difference ring
    df: jnp.ndarray      # (m, 2, B, [K,] ...) residual-difference ring
    z_last: jnp.ndarray  # (2, B, [K,] ...) previous apply's input iterate
    f_last: jnp.ndarray  # (2, B, [K,] ...) previous apply's residual
    count: jnp.ndarray   # int32 () or (K,) — mixing steps applied so far


def _live_mask(live: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a live-block mask against a joint iterate ``(2, B, ...)``.

    ``live`` is bool over the block axis — ``(B,)``, or ``(B, K)`` when
    the window bound is per-sample — aligned to ``z``'s axis 1.
    """
    return live.reshape((1,) + live.shape + (1,) * (z.ndim - 1 - live.ndim))


@dataclasses.dataclass(frozen=True)
class Accelerator:
    """The fixed-point-acceleration seam every SRDS driver consumes.

    Subclasses override :meth:`_mix`; the class-level flags tell drivers
    what the accelerator needs and what it guarantees:

    ``accelerates``
        whether :meth:`apply` mixes at all (drivers skip state plumbing
        entirely when False, keeping compiled carries unchanged).
    ``exact``
        whether results are guaranteed identical to the unaccelerated
        engine (only :class:`NoAccel`; accelerated modes are
        tolerance-equivalent, with a measured error bound).
    ``prefix_exact``
        whether mixing preserves the serial-prefix invariant ("block
        ``i`` is exactly the serial solve after ``i + 1`` refinements")
        that truncating :class:`~repro.core.window.FrontierPolicy`
        schedules are built on.  Joint mixing (:class:`AndersonAccel`)
        does not — a truncating policy would freeze not-yet-converged
        mixed values as if they were exact, and the run diverges — so
        the engine refuses that pairing; :class:`TriangularAccel`
        restores the invariant by construction.
    """

    name = "accel"
    accelerates = False
    exact = True
    prefix_exact = True

    # ---------------------------------------------------------- lifecycle

    def history_depth(self, max_iters: int) -> int:
        """Ring length ``m`` for a run capped at ``max_iters``."""
        return 0

    def init_state(self, z: jnp.ndarray, max_iters: int,
                   batched: bool = False) -> Optional[AccelState]:
        """Fresh carry for a joint iterate shaped like ``z`` (``(2, B,
        ...)``, or ``(2, B, K, ...)`` with ``batched``).  ``None`` when
        not accelerating."""
        if not self.accelerates:
            return None
        m = self.history_depth(max_iters)
        ring = jnp.zeros((m,) + z.shape, z.dtype)
        count = jnp.zeros((z.shape[2],), jnp.int32) if batched \
            else jnp.int32(0)
        return AccelState(ring, ring, jnp.zeros_like(z), jnp.zeros_like(z),
                          count)

    def reset_lanes(self, state: Optional[AccelState],
                    new_mask) -> Optional[AccelState]:
        """Zero the history of newly-(re)admitted lanes (serving engine:
        a recycled slot's old transients must not mix into the next
        request).  ``new_mask`` is bool ``(K,)`` over the sample axis;
        ``count = 0`` gates the zeroed rings out until they re-warm."""
        if state is None:
            return None
        nm = jnp.asarray(new_mask)
        ring_m = nm.reshape((1, 1, 1) + nm.shape
                            + (1,) * (state.dz.ndim - 4))
        z_m = nm.reshape((1, 1) + nm.shape + (1,) * (state.z_last.ndim - 3))
        return AccelState(
            jnp.where(ring_m, jnp.zeros_like(state.dz), state.dz),
            jnp.where(ring_m, jnp.zeros_like(state.df), state.df),
            jnp.where(z_m, jnp.zeros_like(state.z_last), state.z_last),
            jnp.where(z_m, jnp.zeros_like(state.f_last), state.f_last),
            jnp.where(nm, jnp.zeros_like(state.count), state.count))

    # -------------------------------------------------------------- apply

    def apply(self, state: Optional[AccelState], z_prev: jnp.ndarray,
              z_new: jnp.ndarray, *, live=None, batched: bool = False):
        """One mixing step: given the pre-refinement joint iterate
        ``z_prev`` and the refinement's raw output ``z_new = T(z_prev)``,
        return ``(z_mixed, new_state)`` — the iterate the driver should
        commit.  ``live`` (optional bool over the block axis, ``(B,)`` or
        ``(B, K)``) masks mixing to the active window: frozen blocks'
        residuals and history columns are zeroed so their content stays
        bitwise ``z_prev``.  With ``batched`` the iterate carries a
        sample axis at position 2 and mixing runs independently per
        sample (vmapped — converged/frozen lanes see ``f = 0`` and are
        fixed points of the mix)."""
        if not self.accelerates:
            return z_new, state
        if not batched:
            return self._apply_single(state, z_prev, z_new, live)
        live_ax = None if live is None or live.ndim == 1 else 1
        return jax.vmap(
            self._apply_single,
            in_axes=(AccelState(dz=3, df=3, z_last=2, f_last=2, count=0),
                     2, 2, live_ax),
            out_axes=(2, AccelState(dz=3, df=3, z_last=2, f_last=2,
                                    count=0)),
        )(state, z_prev, z_new, live)

    # ------------------------------------------------------------ internals

    def _apply_single(self, s: AccelState, z_prev: jnp.ndarray,
                      z_new: jnp.ndarray, live):
        f = z_new - z_prev
        if live is not None:
            lm = _live_mask(live, z_prev)
            f = jnp.where(lm, f, jnp.zeros_like(f))
        m = s.dz.shape[0]
        # ring push is gated on count >= 1: the first apply has no prior
        # (z_last, f_last) pair, so the zero-initialized rings stay zero
        # and the valid-column mask below keeps them out of the solve
        push = s.count >= 1
        dz_col = z_prev - s.z_last
        df_col = f - s.f_last
        dz = jnp.where(push, jnp.concatenate([s.dz[1:], dz_col[None]]), s.dz)
        df = jnp.where(push, jnp.concatenate([s.df[1:], df_col[None]]), s.df)
        # columns valid so far (newest last); live-mask them at use time so
        # blocks frozen *since* a column was recorded cannot be perturbed
        valid = (jnp.arange(m) >= m - jnp.minimum(s.count, m)).astype(
            f.dtype).reshape((m,) + (1,) * f.ndim)
        dz_u = dz * valid
        df_u = df * valid
        if live is not None:
            dz_u = jnp.where(lm[None], dz_u, jnp.zeros_like(dz_u))
            df_u = jnp.where(lm[None], df_u, jnp.zeros_like(df_u))
        # protection (triangular variant): blocks outside the mask commit
        # the raw iterate AND are excluded from the secant system — the
        # least-squares solve must only see blocks whose committed sequence
        # is the mixed sequence, or the recorded history violates the
        # secant relations AA assumes and the mix diverges
        pm = self._protect_mask(s, z_prev)
        f_mix = f
        if pm is not None:
            f_mix = jnp.where(pm, f, jnp.zeros_like(f))
            dz_u = jnp.where(pm[None], dz_u, jnp.zeros_like(dz_u))
            df_u = jnp.where(pm[None], df_u, jnp.zeros_like(df_u))
        z_mixed = self._mix(s, z_prev, z_new, f_mix, dz_u, df_u)
        if pm is not None:
            z_mixed = jnp.where(pm, z_mixed, z_new)
        if live is not None:
            # bitwise guarantee for frozen blocks (not just f == 0):
            # their committed value is exactly z_prev
            z_mixed = jnp.where(lm, z_mixed, z_prev)
        return z_mixed, AccelState(dz, df, z_prev, f, s.count + 1)

    def _mix(self, s: AccelState, z_prev, z_new, f, dz_u, df_u):
        raise NotImplementedError

    def _protect_mask(self, s: AccelState, z_prev: jnp.ndarray):
        """Optional bool mask over the joint iterate (broadcastable to its
        shape): True where mixing may apply; masked-out entries commit the
        raw ``z_new`` and are excluded from the secant system.  ``None``
        (the default) mixes everywhere."""
        return None

    def _solve_gamma(self, f: jnp.ndarray, df_u: jnp.ndarray,
                     reg: float) -> jnp.ndarray:
        """Type-II AA coefficients: the regularized ``m×m`` normal
        equations ``(Gm + lam·I) gamma = <df_i, f>`` with ``Gm[i, j] =
        <df_i, df_j>``.  Zero/invalid columns give exactly ``gamma = 0``
        (zero rhs rows through a finite solve), so the formula is uniform
        across warm-up with no ``lax.cond``."""
        m = df_u.shape[0]
        cols = df_u.reshape(m, -1).astype(jnp.float32)
        # column normalization: residual differences shrink by orders of
        # magnitude per Parareal iteration, so the raw normal equations are
        # hopelessly ill-conditioned in f32 — scale each column to unit
        # norm (zero/invalid columns stay exactly zero) and unscale gamma
        nrm = jnp.sqrt(jnp.sum(cols * cols, axis=1, keepdims=True))
        scale = jnp.where(nrm > 0, nrm, jnp.ones_like(nrm))
        colsn = cols / scale
        gm = colsn @ colsn.T
        rhs = colsn @ f.reshape(-1).astype(jnp.float32)
        lam = reg * (jnp.trace(gm) / m) + jnp.float32(1e-30)
        gamma = jnp.linalg.solve(gm + lam * jnp.eye(m, dtype=jnp.float32),
                                 rhs)
        return gamma / scale[:, 0]


@dataclasses.dataclass(frozen=True)
class NoAccel(Accelerator):
    """No mixing: ``apply`` returns the refinement's raw output and the
    loop carries no accelerator state — byte-identical to the pre-seam
    engine (the default everywhere)."""

    name = "no_accel"
    accelerates = False
    exact = True


@dataclasses.dataclass(frozen=True)
class AndersonAccel(Accelerator):
    """Sliding-window type-II Anderson mixing of the refinement fixed
    point.

    ``depth`` is the history window ``m`` — how many past
    iterate/residual differences the least-squares extrapolation sees.
    Small depths (2-3) are the sweet spot for Parareal: the map is
    strongly contracting in its leading blocks, deep histories mostly
    add stale transients (and ``m×m`` solve conditioning issues) without
    better search directions.  ``warmup`` delays the first *mixed*
    commit (history still records): Parareal's first refinements are
    strongly nonlinear — residuals drop orders of magnitude per
    iteration — and extrapolating over that transient hurts more than it
    helps; mixing starts once the map is in its slowly-contracting
    near-linear tail, which is exactly where AA shines.  ``reg`` scales
    the Tikhonov term of the normal-equations solve relative to
    ``trace(G)/m``; ``damping`` is the AA beta (``1.0`` = undamped, the
    standard choice — lower it only if mixed iterates visibly
    overshoot).
    """

    depth: int = 2
    warmup: int = 3
    reg: float = 1e-8
    damping: float = 1.0

    name = "anderson"
    accelerates = True
    exact = False
    prefix_exact = False

    def history_depth(self, max_iters: int) -> int:
        return max(1, min(int(self.depth), int(max_iters)))

    def _mix(self, s, z_prev, z_new, f, dz_u, df_u):
        gamma = self._solve_gamma(f, df_u, self.reg)
        beta = jnp.asarray(self.damping, jnp.float32)
        corr = beta * f.astype(jnp.float32) - jnp.tensordot(
            gamma, (dz_u + beta * df_u).astype(jnp.float32), axes=1)
        mixed = z_prev + corr.astype(z_prev.dtype)
        # warm-up: commit the raw iterate while the transient is still
        # nonlinear (the rings keep recording, so the first mixed step
        # already sees a full history)
        return jnp.where(s.count < self.warmup, z_new, mixed)


@dataclasses.dataclass(frozen=True)
class TriangularAccel(AndersonAccel):
    """Prefix-exact triangular Anderson mixing (ParaTAA-inspired): the
    same sliding-window extrapolation as :class:`AndersonAccel`,
    restricted by a *triangular protection* mask that exploits the
    block-triangular structure of the Parareal trajectory map (block
    ``i``'s fine solve depends only on blocks ``< i``): ``x_tail``
    blocks ``<= count + 1`` commit the raw iterate and are excluded from
    the secant system, and the ``prev_coarse`` component is never mixed
    (it is recomputed raw every sweep, so it stays the coarse solve of
    the committed x-chain).  By induction the protected prefix is
    exactly the serial solve's, marching one block per refinement — so a
    run that reaches the iteration cap returns the **bitwise-identical**
    serial result (Parareal's finite convergence), and composition with
    ``ExactPrefix`` truncation freezes serial values, never mixed ones.

    This is the *conservative* variant: mixing only the not-yet-exact
    block suffix is provably safe but measurably weaker than
    :class:`AndersonAccel`'s joint-state mixing — on strongly
    contracting problems the protection marches exactness across blocks
    at the serial rate and the suffix mix adds little (the bench's
    iteration-cut gate targets :class:`AndersonAccel`; see
    docs/acceleration.md for when to pick which).  The aggressive
    alternative — mixing everything and only *committing* raw on the
    protected prefix — is tempting but wrong: the joint map is not
    strictly triangular (the corrector for block ``i`` reads
    ``prev_coarse[i]``), so mixed coarse values corrupt the protected
    prefix one call later and the iteration diverges."""

    name = "triangular"
    accelerates = True
    exact = False
    prefix_exact = True

    def _protect_mask(self, s, z_prev):
        # mix only x_tail blocks beyond the serial prefix; never mix
        # prev_coarse.  The joint map is NOT strictly triangular — the
        # corrector for block i reads prev_coarse[i] (same index) — so a
        # mixed prev_coarse corrupts the "already exact" premise one call
        # later and the protected prefix pins wrong values (empirically:
        # divergence).  Keeping prev_coarse raw makes it G(committed
        # x-chain), and protecting x blocks <= count+1 closes the
        # serial-prefix induction one block ahead of the commit.
        b = z_prev.shape[1]
        idx = jnp.arange(b, dtype=jnp.int32).reshape(
            (1, b) + (1,) * (z_prev.ndim - 2))
        comp = jnp.arange(2, dtype=jnp.int32).reshape(
            (2,) + (1,) * (z_prev.ndim - 1))
        return (comp == 0) & (idx > s.count + 1)


def resolve_accel(accel) -> Accelerator:
    """The one place ``accel=None`` maps onto the seam: ``None`` means
    :class:`NoAccel` (bit-exact, no extra carry); anything else must be
    an :class:`Accelerator`."""
    if accel is None:
        return NoAccel()
    if not isinstance(accel, Accelerator):
        raise TypeError(f"accel must be an Accelerator, got "
                        f"{type(accel).__name__}")
    return accel
