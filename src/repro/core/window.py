"""Frontier/window control for Parareal refinement — the ONE home of
sliding-window policy.

Before this module, three drivers each re-derived "which blocks does
refinement ``p`` still have to compute": the engine's unrolled loop
hard-coded :func:`repro.core.engine.prefix_frontier`, the wavefront
pipeline hard-coded its per-device retirement rule, and the serving
engine hard-coded the quantized group frontier.  Adding any new frontier
rule meant touching all three, inconsistently.  Now every driver consumes
a :class:`FrontierPolicy` and the rule lives here exactly once.

A policy decides the *active refinement window* ``[lo, hi)`` over the
``B`` parareal blocks: blocks below ``lo`` are frozen (their fine solves
and corrector updates are skipped or masked to no-ops), blocks in the
window refine normally.  ``hi`` is always ``B`` — the final block carries
the convergence residual and never retires — so a policy is fully
described by how ``lo`` advances.

Three implementations ship:

``ExactPrefix``
    Today's provable rule, ``lo = prefix_frontier(p) = max(p - 1, 0)``:
    the bitwise-frozen prefix of classical Parareal exactness, lagged one
    refinement for bitwise stability (see
    :func:`repro.core.engine.prefix_frontier`).  **Bit-exact**: results
    are identical to the untruncated engine; this is the policy
    ``SRDSConfig(truncate=True)`` resolves to.

``ResidualWindow``
    ParaDiGMS-style residual-driven window (Shih et al., "Parallel
    Sampling of Diffusion Models"; Tang et al., "Accelerating Parallel
    Sampling of Diffusion Models"): ``lo`` advances past every leading
    block whose last per-block residual norm is ``<= window_tol``, not
    just the provably-exact prefix.  **Approximate, opt-in**: frozen
    blocks stop refining while still mathematically inexact, so the
    sample can drift from the serial solution by an amount controlled by
    the ``window_tol`` knob (measured per config in
    ``benchmarks/table12_window.py``; the error is the accumulated
    correction the frozen blocks would still have applied, empirically
    the same order as ``window_tol`` for contractive denoisers).  The
    window never retreats and is floored at the provable
    ``ExactPrefix`` frontier, so ``window_tol = 0`` degrades gracefully
    to (a masked equivalent of) the exact policy.

``FixedBudget``
    No truncation: every refinement computes all ``B`` blocks.  The
    policy behind ``truncate=False`` engines and ``fixed_iters``
    fixed-budget sampling, made explicit so cost models can price it
    through the same seam.

Driver notes
------------

* The **engine** (:func:`repro.core.engine.run_parareal`) unrolls the
  refinement loop so each iteration's *compiled* suffix shape is the
  static floor :meth:`FrontierPolicy.static_frontier`; a residual-driven
  policy additionally freezes blocks ``[static, lo)`` *dynamically* with
  masking (``lo`` rides the loop carry, advanced by
  :meth:`FrontierPolicy.advance` from the per-block residuals the sweep
  already produces).  In one compiled program the masked blocks still
  occupy FLOPs — the accounting (and the host-stepped serving engine,
  which physically skips them) realizes the savings.
* The **wavefront** consults :meth:`FrontierPolicy.retire_at` for its
  per-device retirement superstep.  Per-block residuals live only on the
  tail device there, so ``ResidualWindow`` falls back to the provable
  (exact) retirement rule on the wavefront — sound, just not approximate.
* The **serving engine** is host-stepped, so the dynamic window is
  physically real: each refinement's step program is compiled for the
  quantized window floor and the per-block residual vector rides the
  existing one-sync-per-refinement fetch.

Cost-model note: :meth:`FrontierPolicy.predict_evals` prices an
``iterations``-refinement run for admission control and billing
estimates.  For ``ResidualWindow`` the realized window depends on data
the predictor cannot see, so it charges the ``ExactPrefix`` schedule —
an upper bound on the windowed cost (the window is always at least the
provable prefix), i.e. admission under-truncates rather than
over-promises.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FrontierPolicy", "ExactPrefix", "ResidualWindow", "FixedBudget",
           "resolve_policy"]


def _xp(a):
    """numpy for host-side (serving-loop) arrays, jnp for traced ones, so
    one ``advance`` implementation serves both drivers without dragging
    host policy math onto the device."""
    return jnp if isinstance(a, jax.Array) else np


@dataclasses.dataclass(frozen=True)
class FrontierPolicy:
    """The window-control seam every SRDS driver consumes.

    Subclasses override the three decision methods; the class-level flags
    tell drivers what the policy needs and what it guarantees:

    ``truncates``
        whether refinements run on a shrinking window at all (drivers
        pick the unrolled/static-suffix loop vs the plain while_loop).
    ``exact``
        whether results are guaranteed identical to the untruncated
        engine (bit-identical for elementwise-deterministic models).
    ``needs_block_residuals``
        whether drivers must materialize per-block residual norms and
        feed them to :meth:`advance` (costs one extra small reduction on
        the non-fused path; free with the fused kernel's per-tile
        partials).
    """

    name = "policy"
    truncates = False
    exact = True
    needs_block_residuals = False

    # ---------------------------------------------------------- decisions

    def static_frontier(self, p: int, num_blocks: int) -> int:
        """Compile-time floor of the window lower bound at refinement
        ``p`` (0-indexed): the suffix ``[static_frontier(p), B)`` is the
        largest block range refinement ``p`` can ever need, so unrolled /
        per-frontier-compiled programs size their suffix with it.  Must
        be sound for *any* data (a static frontier is never given the
        residuals)."""
        return 0

    def advance(self, lo, block_resid, num_blocks: int):
        """Next window lower bound, given the current ``lo`` and the
        per-block residual norms of the refinement that just ran.

        ``block_resid`` has a leading block axis ``(B, ...)`` — trailing
        axes (e.g. a per-sample ``K``) are carried through, so ``lo`` may
        be a scalar or a per-sample vector.  Works on host ``numpy``
        arrays (the serving loop) and traced ``jnp`` values (the engine
        carry) alike.  Must be monotone (``advance(lo, ..) >= lo``) and
        capped at ``B - 1``: the final block carries the convergence
        residual and never retires."""
        return lo

    def retire_at(self, block_idx, num_blocks: int, max_iters: int):
        """Wavefront rule: the number of *completed refinements* after
        which the device owning ``block_idx`` stops evaluating.  The tail
        device never retires early (its residuals gate convergence).
        ``block_idx`` may be a traced ``axis_index``."""
        return max_iters

    def predict_evals(self, cost, iterations):
        """Per-lane model evals for an ``iterations``-refinement run
        under this policy's *predicted* window schedule — the pricing
        seam shared by billing, ``predict_completion`` and the CostAware
        scheduler.  ``cost`` is a :class:`repro.core.engine.IterationCost`."""
        from .engine import predicted_evals
        return predicted_evals(cost, iterations)


@dataclasses.dataclass(frozen=True)
class ExactPrefix(FrontierPolicy):
    """The provable bitwise-frozen prefix (PR 4's ``truncate=True``),
    ``lo = max(p - 1, 0)``: bit-exact truncation, one block per
    refinement, one refinement behind the exactness bound (see
    :func:`repro.core.engine.prefix_frontier` for why the lag)."""

    name = "exact_prefix"
    truncates = True
    exact = True
    needs_block_residuals = False

    def static_frontier(self, p: int, num_blocks: int) -> int:
        from .engine import prefix_frontier
        return min(prefix_frontier(p), num_blocks - 1)

    def advance(self, lo, block_resid, num_blocks: int):
        return lo                      # the static schedule is the window

    def retire_at(self, block_idx, num_blocks: int, max_iters: int):
        # Block i+1 is provably exact after i+1 refinements; on the
        # wavefront both coarse terms of every update come from the same
        # compiled call site, so the frontier needs NO one-refinement lag
        # there (the engine-side lag exists only because init sweep and
        # corrector sweep are two separately compiled scans).  The tail
        # device keeps computing: its residuals feed delta/history.
        return jnp.where(block_idx == num_blocks - 1, max_iters,
                         jnp.minimum(block_idx + 1, max_iters))

    def predict_evals(self, cost, iterations):
        from .engine import truncated_evals
        return truncated_evals(cost, iterations)


@dataclasses.dataclass(frozen=True)
class ResidualWindow(FrontierPolicy):
    """Residual-driven sliding window (ParaDiGMS-style) — the opt-in
    *approximate* mode: ``lo`` advances past every leading block whose
    last residual norm (same ``norm`` as the convergence gate) is
    ``<= window_tol``, freezing it even before exactness is provable.

    ``window_tol`` is the error knob: frozen blocks stop applying
    corrections, so the sample drifts from the serial solution by the
    corrections foregone — empirically the same order as ``window_tol``
    for contractive denoisers (``benchmarks/table12_window.py`` measures
    the max trajectory error per config; pick ``window_tol`` at or below
    the convergence ``tol`` to keep the drift inside the tolerance you
    already accepted).  The window is floored at the provable
    :class:`ExactPrefix` frontier and never retreats."""

    window_tol: float = 1e-3

    name = "residual_window"
    truncates = True
    exact = False
    needs_block_residuals = True

    def static_frontier(self, p: int, num_blocks: int) -> int:
        # the provable prefix is free (bit-exact) truncation: compile the
        # suffix against it and handle the residual-driven extra freezing
        # dynamically via masking / the serve quantum
        from .engine import prefix_frontier
        return min(prefix_frontier(p), num_blocks - 1)

    def advance(self, lo, block_resid, num_blocks: int):
        """``lo + (length of the contiguous run of blocks at >= lo whose
        residual passed window_tol)``, capped at ``B - 1``.  Blocks below
        the current ``lo`` count as passed (the window never retreats);
        the contiguity requirement is ParaDiGMS's: a still-moving block
        keeps every later block's inputs moving, so freezing past it
        would compound unchecked error."""
        xp = _xp(block_resid)
        b = num_blocks
        idx = xp.arange(b).reshape((b,) + (1,) * (block_resid.ndim - 1))
        under = xp.logical_or(idx < lo, block_resid <= self.window_tol)
        run = xp.cumprod(under.astype(xp.int32), axis=0)
        new_lo = xp.sum(run, axis=0, dtype=xp.int32)
        return xp.minimum(new_lo, b - 1).astype(xp.int32)

    def retire_at(self, block_idx, num_blocks: int, max_iters: int):
        # per-block residuals live on no single wavefront device, so the
        # approximate window is not available there: fall back to the
        # provable (exact) retirement rule — sound, never worse than PR 4
        return ExactPrefix().retire_at(block_idx, num_blocks, max_iters)

    def predict_evals(self, cost, iterations):
        # the realized window is data-dependent; charge the provable
        # ExactPrefix schedule — an upper bound on the windowed cost
        # (window >= provable prefix), so estimates never under-bill
        from .engine import truncated_evals
        return truncated_evals(cost, iterations)


@dataclasses.dataclass(frozen=True)
class FixedBudget(FrontierPolicy):
    """No truncation: every refinement computes all ``B`` blocks (the
    ``truncate=False`` / ``fixed_iters`` engines, and the pricing unit of
    the pre-PR-4 flat cost model)."""

    name = "fixed_budget"
    truncates = False
    exact = True
    needs_block_residuals = False

    def retire_at(self, block_idx, num_blocks: int, max_iters: int):
        return max_iters               # no early retirement anywhere


def resolve_policy(window, truncate: bool) -> FrontierPolicy:
    """The one place the legacy ``truncate`` bool maps onto the policy
    seam: an explicit ``window`` policy wins; otherwise ``truncate=True``
    means :class:`ExactPrefix` and ``False`` means :class:`FixedBudget`."""
    if window is not None:
        if not isinstance(window, FrontierPolicy):
            raise TypeError(f"window must be a FrontierPolicy, got "
                            f"{type(window).__name__}")
        return window
    return ExactPrefix() if truncate else FixedBudget()
