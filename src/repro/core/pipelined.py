"""Distributed SRDS: shard_map block-parallel and wavefront-pipelined samplers.

Two TPU-native implementations of the paper's parallelism:

``srds_sharded_local``
    Algorithmically identical to :func:`repro.core.parareal.srds_sample` —
    both drive the *same* refinement loop in :mod:`repro.core.engine` — but
    the parareal blocks live on a mesh axis: each device(-group) runs the
    fine solves for its own blocks; boundary values are exchanged with one
    ``all_gather`` per refinement and the (cheap) coarse sweep is computed
    redundantly on every device.  Supports >1 block per device and the
    SRDS-native straggler-mitigation mask (stale fine results are accepted
    for straggling blocks; correctness is preserved because convergence is
    still gated on the final-sample residual and exactness re-enters as soon
    as the block computes again).

``srds_pipelined_local``
    The paper's wavefront pipeline (Fig. 4) at *model-eval granularity*:
    one block per device; at superstep ``s`` device ``i`` performs fine
    sub-step ``j=(s-i) mod S`` of refinement ``p=(s-i)//S + 1``; the coarse
    eval is **batched into the same model call** as the fine eval (paper
    §3.4: "the coarse solver is simply a DDIM-step with a larger time-step,
    so it can be batched with fine solves").  Boundary values ride a ring
    ``lax.ppermute`` — this replaces the paper's torch.multiprocessing
    coordinator (their footnote 4) with the ICI-native pattern.  Effective
    serial evals ≈ k·S + B - 1, reproducing the paper's Table 3 pipelining
    gain (e.g. N=25: 9 supersteps for k=1).

Both functions are written against a *local* (per-shard) view and must be
called inside ``shard_map``; ``make_*_sampler`` wrappers build the jitted
SPMD program for a given mesh via :func:`repro.compat.shard_map` (the
version-adaptive surface — JAX moved ``shard_map`` between 0.4.x and 0.5,
so no call site here names a ``jax.*`` spelling directly).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from .engine import (SRDSConfig, assemble_result, convergence_norm,
                     has_converged, parareal_update, resolve_blocks,
                     run_parareal)
from .schedules import DiffusionSchedule
from .solvers import ModelFn, SolverConfig, solve, solver_step


# --------------------------------------------------------------------------
# Block-parallel (non-wavefront) distributed SRDS
# --------------------------------------------------------------------------

def srds_sharded_local(model_fn: ModelFn, sched: DiffusionSchedule,
                       solver: SolverConfig, x_init: jnp.ndarray,
                       axis: str, cfg: SRDSConfig,
                       straggler_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                       tol=None):
    """Per-shard body. x_init is replicated; returns replicated outputs.

    ``straggler_fn(p) -> (B,) bool`` marks blocks whose fine solve is treated
    as dropped at refinement ``p`` (stale result substituted).
    ``tol`` overrides ``cfg.tol`` and may be a traced scalar or — with
    ``cfg.per_sample`` — a per-sample ``(K,)`` vector over the leading batch
    axis of ``x_init`` (mixed-tolerance micro-batches).
    """
    n = sched.num_steps
    d = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    b_total, s_steps = resolve_blocks(n, cfg.num_blocks)
    if b_total % d != 0:
        raise ValueError(f"num_blocks={b_total} not divisible by axis size {d}")
    b_local = b_total // d
    max_iters = cfg.max_iters if cfg.max_iters is not None else b_total

    my_starts = (me * b_local + jnp.arange(b_local, dtype=jnp.int32)) * s_steps
    all_starts = jnp.arange(b_total, dtype=jnp.int32) * s_steps

    def G(x, i0):
        return solve(model_fn, sched, solver, x, i0, 1, s_steps)

    def F(x, i0):
        return solve(model_fn, sched, solver, x, i0, s_steps, 1)

    def fine_fn(x_heads, p, y_prev):
        # ---- local fine solves (the parallel part) ----
        my_heads = jax.lax.dynamic_slice_in_dim(x_heads, me * b_local, b_local)
        y_local = jax.vmap(F)(my_heads, my_starts)                 # (B_local, ...)
        y = jax.lax.all_gather(y_local, axis, tiled=True)          # (B, ...)
        if straggler_fn is not None:
            mask = straggler_fn(p).reshape((-1,) + (1,) * (y.ndim - 1))
            y = jnp.where(jnp.logical_and(mask, p > 0), y_prev, y)
        return y

    # The coarse sweep / predictor-corrector / convergence gating all come
    # from the shared engine; the coarse sweep is computed redundantly on
    # every device (cheap: B coarse evals).
    out = run_parareal(G, fine_fn, x_init, all_starts,
                       tol=cfg.tol if tol is None else tol,
                       max_iters=max_iters, norm=cfg.norm,
                       use_fused_update=cfg.use_fused_update,
                       fixed_iters=cfg.fixed_iters,
                       scan_unroll=cfg.scan_unroll,
                       carry_fine_results=straggler_fn is not None,
                       batched=cfg.per_sample)
    return out.x_tail[-1], out.iters, out.delta, out.history


def make_sharded_sampler(mesh, axis: str, model_fn: ModelFn,
                         sched: DiffusionSchedule, solver: SolverConfig,
                         cfg: SRDSConfig, straggler_fn=None):
    """jit-compiled SPMD sampler: x_init (replicated) -> SRDSResult.

    The returned callable takes an optional runtime ``tol`` (scalar, or a
    per-sample ``(K,)`` vector with ``cfg.per_sample``) so a serving layer
    can pack requests with different tolerances into one micro-batch without
    recompiling; ``tol=None`` uses ``cfg.tol``.
    """
    def local(x_init, tol):
        s, it, d, h = srds_sharded_local(model_fn, sched, solver, x_init, axis,
                                         cfg, straggler_fn, tol=tol)
        return s, it, d, h

    fn = compat.shard_map(local, mesh=mesh,
                          in_specs=(P(), P()), out_specs=(P(), P(), P(), P()),
                          check_vma=False)

    @jax.jit
    def _sample(x_init, tol):
        s, it, d, h = fn(x_init, tol)
        return assemble_result(s, it, d, h)

    def sample(x_init, tol=None):
        tolv = jnp.asarray(cfg.tol if tol is None else tol, jnp.float32)
        return _sample(x_init, tolv)

    return sample


# --------------------------------------------------------------------------
# Wavefront-pipelined SRDS (paper Fig. 4, eval-granular)
# --------------------------------------------------------------------------

class _WaveCarry(NamedTuple):
    s: jnp.ndarray             # superstep counter
    z: jnp.ndarray             # running fine-solve state
    x_new: jnp.ndarray         # latest left-boundary value x_i^p
    prev_coarse: jnp.ndarray   # G(x_i^{p-1})
    out_last: jnp.ndarray      # device's last completed block output
    delta: jnp.ndarray         # last residual, f32 () or (K,) per sample —
                               # live on device B-1, psum-broadcast on exit
    history: jnp.ndarray       # per-refinement residuals, (max_iters,[ K]) —
                               # live on device B-1, psum-broadcast on exit
    p_done: jnp.ndarray        # completed refinements (device-local),
                               # int32 () or per-sample (K,)
    conv: jnp.ndarray          # per-sample converged mask on device B-1,
                               # bool () or (K,) (always False elsewhere)
    done: jnp.ndarray          # all-samples-converged flag (replicated)


def srds_pipelined_local(model_fn: ModelFn, sched: DiffusionSchedule,
                         solver: SolverConfig, x_init: jnp.ndarray,
                         axis: str, cfg: SRDSConfig):
    """Per-shard wavefront body; one parareal block per device along ``axis``.

    Every superstep performs exactly ONE model call on a 2-sample batch
    (fine slot + coarse slot) per device — the paper's unit of "effective
    serial evals".  The coarse slot is live only on block-boundary and init
    supersteps; it is evaluated unconditionally to keep SPMD lockstep (cost:
    a 2x smaller micro-batch would not be faster on the MXU anyway).

    The wavefront restructures *scheduling*, not math: the corrector update
    and convergence gate below are :func:`repro.core.engine.parareal_update`
    and :func:`repro.core.engine.convergence_norm` — the same code the
    sequential and block-sharded samplers run.

    With ``cfg.per_sample`` the leading axis of ``x_init`` is a batch of K
    samples gated independently: the tail device carries a per-sample
    residual/convergence mask, freezes converged samples' outputs, and the
    psum'd done-flag fires only once *every* sample has converged.
    """
    n = sched.num_steps
    d = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    if n % d != 0:
        raise ValueError(f"N={n} must be divisible by device count {d}")
    s_steps = n // d                       # fine steps per block
    max_iters = cfg.max_iters if cfg.max_iters is not None else d
    max_supersteps = max_iters * s_steps + d + 2
    right = [(i, (i + 1) % d) for i in range(d)]
    per = cfg.per_sample

    def lane_mask(mask, t):
        # broadcast a per-sample mask against a (K, ...) state tensor
        return mask.reshape(mask.shape + (1,) * (t.ndim - mask.ndim)) \
            if per else mask

    block_i0 = me * s_steps                # my block's first grid index

    def batched_eval(z, j, x_coarse):
        """One lockstep model call advancing fine slot and coarse slot."""
        fine_i0 = block_i0 + j
        # Stack the two slots on a fresh leading axis; solver_step below
        # will broadcast its per-slot grid indices.
        stacked = jnp.stack([z, x_coarse], axis=0)
        i0 = jnp.stack([fine_i0, block_i0])
        i1 = jnp.stack([fine_i0 + 1, block_i0 + s_steps])

        def one(slot, a, b):
            return solver_step(model_fn, sched, solver, slot, a, b)

        out = jax.vmap(one)(stacked, i0, i1)
        return out[0], out[1]              # fine-advanced z, coarse result

    def body(c: _WaveCarry) -> _WaveCarry:
        rel = c.s - me
        active = rel >= 0
        j = jnp.where(active, rel % s_steps, 0)
        p = jnp.where(active, rel // s_steps + 1, 0)
        is_first = jnp.logical_and(active, j == 0)
        is_last = jnp.logical_and(active, j == s_steps - 1)
        is_init = jnp.logical_and(is_first, p == 1)

        # fine input: at j==0 restart from the boundary value x_i^{p-1}
        z_in = jnp.where(is_first, c.x_new, c.z)
        z_out, coarse_out = batched_eval(z_in, j, c.x_new)

        # --- init superstep: coarse_out = G(x_i^0): seed prev_coarse, send
        # --- last superstep:  coarse_out = G(x_i^p): predictor-corrector
        prev_eff = jnp.where(is_init, coarse_out, c.prev_coarse)
        out_block = parareal_update(z_out, coarse_out, prev_eff,
                                    cfg.use_fused_update)
        send_val = jnp.where(is_last, out_block,
                             jnp.where(is_init, coarse_out, c.out_last))
        send_flag = jnp.logical_or(is_init, is_last)

        new_prev_coarse = jnp.where(jnp.logical_or(is_init, is_last),
                                    coarse_out, c.prev_coarse)
        # out_last tracks x_{i+1}^p (x_{i+1}^0 after the init eval), so the
        # tail device's p=1 residual compares against x_B^0 per Alg. 1.
        # Samples already converged on the tail device stay frozen — their
        # reported output is the value at their convergence refinement, the
        # same contract as the engine's per-sample gating (c.conv is always
        # False off the tail device, so this is a no-op elsewhere).  The
        # superstep budget has a few supersteps of ramp slack past
        # refinement max_iters (for s_steps <= 3 a block can complete an
        # extra refinement inside it) — `over` freezes those too, so
        # iterations/delta/history never report past the budget.
        over = p > max_iters
        frozen = lane_mask(jnp.logical_or(c.conv, over), out_block)
        new_out_last = jnp.where(is_last,
                                 jnp.where(frozen, c.out_last, out_block),
                                 jnp.where(is_init, coarse_out, c.out_last))
        new_p_done = jnp.where(
            jnp.logical_and(is_last,
                            jnp.logical_not(jnp.logical_or(c.conv, over))),
            p, c.p_done)

        # convergence residual on the final block (per sample when gated)
        is_tail = me == d - 1
        resid = convergence_norm(out_block - c.out_last, cfg.norm, batched=per)
        upd = jnp.logical_and(is_tail,
                              jnp.logical_and(is_last, jnp.logical_not(over)))
        live = jnp.logical_and(upd, jnp.logical_not(c.conv))
        delta = jnp.where(live, resid, c.delta)
        # record the refinement's residual for still-refining samples (the
        # +inf tail past a sample's convergence matches the engine contract)
        idx = jnp.clip(p - 1, 0, max_iters - 1)
        history = c.history.at[idx].set(
            jnp.where(live, resid, c.history[idx]))
        conv = jnp.where(upd, has_converged(delta, cfg.tol), c.conv)
        local_conv = jnp.where(
            upd, jnp.all(conv).astype(jnp.float32), 0.0)
        done = jax.lax.psum(local_conv, axis) > 0.0

        # ring exchange of boundary values (one sample per neighbor pair)
        recv_val = jax.lax.ppermute(send_val, axis, right)
        recv_flag = jax.lax.ppermute(send_flag.astype(jnp.float32), axis, right)
        take = jnp.logical_and(recv_flag > 0, me > 0)
        x_new = jnp.where(take, recv_val, c.x_new)
        x_new = jnp.where(me == 0, x_init, x_new)   # x_0 is the fixed IC

        return _WaveCarry(c.s + 1, jnp.where(active, z_out, c.z), x_new,
                          jnp.where(active, new_prev_coarse, c.prev_coarse),
                          jnp.where(active, new_out_last, c.out_last),
                          delta, history,
                          jnp.where(active, new_p_done, c.p_done), conv, done)

    def cond(c: _WaveCarry):
        return jnp.logical_and(c.s < max_supersteps, jnp.logical_not(c.done))

    if per:
        k = x_init.shape[0]
        delta0 = jnp.full((k,), jnp.inf, jnp.float32)
        hist0 = jnp.full((max_iters, k), jnp.inf, jnp.float32)
        p_done0 = jnp.zeros((k,), jnp.int32)
        conv0 = jnp.zeros((k,), bool)
    else:
        delta0 = jnp.float32(jnp.inf)
        hist0 = jnp.full((max_iters,), jnp.inf, jnp.float32)
        p_done0 = jnp.int32(0)
        conv0 = jnp.asarray(False)
    init = _WaveCarry(s=jnp.int32(0), z=x_init, x_new=x_init,
                      prev_coarse=jnp.zeros_like(x_init),
                      out_last=jnp.zeros_like(x_init),
                      delta=delta0, history=hist0, p_done=p_done0,
                      conv=conv0, done=jnp.asarray(False))
    c = jax.lax.while_loop(cond, body, init)

    # broadcast the tail device's answers to every shard
    def from_tail(v):
        return jax.lax.psum(jnp.where(me == d - 1, v, jnp.zeros_like(v)),
                            axis)

    sample = from_tail(c.out_last)
    iters = from_tail(c.p_done)
    delta = from_tail(c.delta)
    history = from_tail(c.history)
    supersteps = c.s
    return sample, iters, delta, history, supersteps


def make_pipelined_sampler(mesh, axis: str, model_fn: ModelFn,
                           sched: DiffusionSchedule, solver: SolverConfig,
                           cfg: SRDSConfig):
    def local(x_init):
        return srds_pipelined_local(model_fn, sched, solver, x_init, axis, cfg)

    fn = compat.shard_map(local, mesh=mesh, in_specs=P(),
                          out_specs=(P(), P(), P(), P(), P()),
                          check_vma=False)

    @jax.jit
    def sample(x_init):
        s, p, dlt, hist, steps = fn(x_init)
        return assemble_result(s, p, dlt, hist), steps

    return sample
