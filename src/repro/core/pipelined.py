"""Distributed SRDS: shard_map block-parallel and wavefront-pipelined samplers.

Two TPU-native implementations of the paper's parallelism:

``srds_sharded_local``
    Algorithmically identical to :func:`repro.core.parareal.srds_sample` —
    both drive the *same* refinement loop in :mod:`repro.core.engine` — but
    the parareal blocks live on a mesh axis: each device(-group) runs the
    fine solves for its own blocks; boundary values are exchanged with one
    ``all_gather`` per refinement and the (cheap) coarse sweep is computed
    redundantly on every device.  Supports >1 block per device and the
    SRDS-native straggler-mitigation mask (stale fine results are accepted
    for straggling blocks; correctness is preserved because convergence is
    still gated on the final-sample residual and exactness re-enters as soon
    as the block computes again).

``srds_pipelined_local``
    The paper's wavefront pipeline (Fig. 4) at *model-eval granularity*:
    one block per device; at superstep ``s`` device ``i`` performs fine
    sub-step ``j=(s-i) mod S`` of refinement ``p=(s-i)//S + 1``; the coarse
    eval is **batched into the same model call** as the fine eval (paper
    §3.4: "the coarse solver is simply a DDIM-step with a larger time-step,
    so it can be batched with fine solves").  Boundary values ride a ring
    ``lax.ppermute`` — this replaces the paper's torch.multiprocessing
    coordinator (their footnote 4) with the ICI-native pattern.  Effective
    serial evals ≈ k·S + B - 1, reproducing the paper's Table 3 pipelining
    gain (e.g. N=25: 9 supersteps for k=1).

Both functions are written against a *local* (per-shard) view and must be
called inside ``shard_map``; ``make_*_sampler`` wrappers build the jitted
SPMD program for a given mesh via :func:`repro.compat.shard_map` (the
version-adaptive surface — JAX moved ``shard_map`` between 0.4.x and 0.5,
so no call site here names a ``jax.*`` spelling directly).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from .accel import resolve_accel
from .denoiser import as_denoiser
from .engine import (SRDSConfig, assemble_result, convergence_norm,
                     has_converged, parareal_update, resolve_blocks,
                     run_parareal)
from .schedules import DiffusionSchedule
from .solvers import ModelFn, SolverConfig, solve, solver_step
from .window import ExactPrefix, resolve_policy


# --------------------------------------------------------------------------
# Block-parallel (non-wavefront) distributed SRDS
# --------------------------------------------------------------------------

def srds_sharded_local(model_fn: ModelFn, sched: DiffusionSchedule,
                       solver: SolverConfig, x_init: jnp.ndarray,
                       axis: str, cfg: SRDSConfig,
                       straggler_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                       tol=None):
    """Per-shard body. x_init is replicated; returns replicated outputs.

    ``straggler_fn(p) -> (B,) bool`` marks blocks whose fine solve is treated
    as dropped at refinement ``p`` (stale result substituted).
    ``tol`` overrides ``cfg.tol`` and may be a traced scalar or — with
    ``cfg.per_sample`` — a per-sample ``(K,)`` vector over the leading batch
    axis of ``x_init`` (mixed-tolerance micro-batches).

    ``model_fn`` may be a :class:`repro.core.denoiser.Denoiser`: the specs
    of the enclosing shard_map replicate over the denoiser's mesh axes, so
    its ``inner_eval`` glue (slice per ``in_spec`` -> shard body ->
    all_gather per ``out_spec``) runs the backbone model-parallel on the
    same mesh — the block ``axis`` and the model axes compose without any
    driver-specific code.
    """
    n = sched.num_steps
    d = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    eval_fn = as_denoiser(model_fn).inner_eval()
    b_total, s_steps = resolve_blocks(n, cfg.num_blocks)
    if b_total % d != 0:
        raise ValueError(f"num_blocks={b_total} not divisible by axis size {d}")
    if resolve_policy(cfg.window, cfg.truncate).truncates \
            and straggler_fn is not None:
        raise ValueError("truncate is incompatible with straggler_fn (stale "
                         "fine results are indexed on the full block axis)")
    b_local = b_total // d
    max_iters = cfg.max_iters if cfg.max_iters is not None else b_total

    my_starts = (me * b_local + jnp.arange(b_local, dtype=jnp.int32)) * s_steps
    all_starts = jnp.arange(b_total, dtype=jnp.int32) * s_steps

    def G(x, i0):
        return solve(eval_fn, sched, solver, x, i0, 1, s_steps)

    def F(x, i0):
        return solve(eval_fn, sched, solver, x, i0, s_steps, 1)

    def fine_fn(x_heads, p, y_prev):
        live = x_heads.shape[0]
        if live == b_total:
            # ---- full-width local fine solves (the parallel part) ----
            my_heads = jax.lax.dynamic_slice_in_dim(x_heads, me * b_local,
                                                    b_local)
            y_local = jax.vmap(F)(my_heads, my_starts)             # (B_local, ...)
            y = jax.lax.all_gather(y_local, axis, tiled=True)      # (B, ...)
            if straggler_fn is not None:
                mask = straggler_fn(p).reshape((-1,) + (1,) * (y.ndim - 1))
                y = jnp.where(jnp.logical_and(mask, p > 0), y_prev, y)
            return y
        # ---- truncated suffix: redistribute the live blocks over the axis
        # so retired prefix blocks free whole devices.  Every device takes a
        # ceil(live/d) chunk of the suffix (padded with copies of the last
        # head so the lockstep shapes stay static); devices whose chunk
        # starts past the suffix skip their fine solves entirely — real
        # per-device retirement, not masking.  Block->device placement
        # shifts as the frontier advances, which is fine: results are
        # re-joined by one all_gather either way.
        f = b_total - live
        m = -(-live // d)
        pad = d * m - live
        heads = x_heads
        st = all_starts[f:]
        if pad:
            heads = jnp.concatenate(
                [heads, jnp.broadcast_to(heads[-1:],
                                         (pad,) + heads.shape[1:])], axis=0)
            st = jnp.concatenate([st, jnp.broadcast_to(st[-1:], (pad,))])
        start = me * m
        my_heads = jax.lax.dynamic_slice_in_dim(heads, start, m)
        my_st = jax.lax.dynamic_slice_in_dim(st, start, m)
        y_local = jax.lax.cond(
            start < live,
            lambda: jax.vmap(F)(my_heads, my_st),
            lambda: jnp.zeros((m,) + x_heads.shape[1:], x_heads.dtype))
        return jax.lax.all_gather(y_local, axis, tiled=True)[:live]

    # The coarse sweep / predictor-corrector / convergence gating all come
    # from the shared engine; the coarse sweep is computed redundantly on
    # every device (cheap: B coarse evals).
    out = run_parareal(G, fine_fn, x_init, all_starts,
                       tol=cfg.tol if tol is None else tol,
                       max_iters=max_iters, norm=cfg.norm,
                       use_fused_update=cfg.use_fused_update,
                       fixed_iters=cfg.fixed_iters,
                       scan_unroll=cfg.scan_unroll,
                       carry_fine_results=straggler_fn is not None,
                       batched=cfg.per_sample, truncate=cfg.truncate,
                       window=cfg.window, accel=cfg.accel)
    return out.x_tail[-1], out.iters, out.delta, out.history


def make_sharded_sampler(mesh, axis: str, model_fn: ModelFn,
                         sched: DiffusionSchedule, solver: SolverConfig,
                         cfg: SRDSConfig, straggler_fn=None,
                         data_axis: str = None):
    """jit-compiled SPMD sampler: x_init (replicated) -> SRDSResult.

    The returned callable takes an optional runtime ``tol`` (scalar, or a
    per-sample ``(K,)`` vector with ``cfg.per_sample``) so a serving layer
    can pack requests with different tolerances into one micro-batch without
    recompiling; ``tol=None`` uses ``cfg.tol``.

    ``data_axis`` (optional) shards the leading K sample batch of
    ``x_init`` — and the runtime ``tol`` vector with it — over a second
    mesh axis: lanes are independent, so the split needs no collectives and
    composes with the block ``axis`` on a 2D mesh.  Requires
    ``cfg.per_sample`` (joint-norm gating couples lanes: each data shard
    would gate on its local residual and iteration counts would diverge)
    and a ``K`` divisible by the axis size.

    ``model_fn`` may be a sharding-aware
    :class:`repro.core.denoiser.Denoiser` whose ``mesh_axes`` name further
    axes of the same ``mesh`` (e.g. ``model``) — a (time, data, model)
    mesh then runs time-, data- and model-parallel fine solves through the
    one seam.  The mesh is validated against the denoiser's requirement
    up front (clear ``ValueError`` instead of XLA's unbound-axis error).
    """
    as_denoiser(model_fn).check_mesh(mesh)
    if data_axis is not None and not cfg.per_sample:
        raise ValueError("data_axis shards the sample batch, which is only "
                         "exact under per-sample gating — set "
                         "SRDSConfig.per_sample=True")
    d_data = mesh.shape[data_axis] if data_axis is not None else 1

    def local(x_init, tol):
        s, it, d, h = srds_sharded_local(model_fn, sched, solver, x_init, axis,
                                         cfg, straggler_fn, tol=tol)
        return s, it, d, h

    if data_axis is not None:
        in_specs = (P(data_axis), P(data_axis))
        out_specs = (P(data_axis), P(data_axis), P(data_axis),
                     P(None, data_axis))
    else:
        in_specs = (P(), P())
        out_specs = (P(), P(), P(), P())
    fn = compat.shard_map(local, mesh=mesh,
                          in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)

    @jax.jit
    def _sample(x_init, tol):
        s, it, d, h = fn(x_init, tol)
        return assemble_result(s, it, d, h)

    def sample(x_init, tol=None):
        tolv = jnp.asarray(cfg.tol if tol is None else tol, jnp.float32)
        if data_axis is not None:
            k = x_init.shape[0]
            if k % d_data != 0:
                raise ValueError(f"sample batch K={k} not divisible by "
                                 f"data axis size {d_data}")
            if tolv.ndim == 0:
                tolv = jnp.broadcast_to(tolv, (k,))
        return _sample(x_init, tolv)

    return sample


# --------------------------------------------------------------------------
# Wavefront-pipelined SRDS (paper Fig. 4, eval-granular)
# --------------------------------------------------------------------------

class _WaveCarry(NamedTuple):
    s: jnp.ndarray             # superstep counter
    z: jnp.ndarray             # running fine-solve state
    x_new: jnp.ndarray         # latest left-boundary value x_i^p
    prev_coarse: jnp.ndarray   # G(x_i^{p-1})
    out_last: jnp.ndarray      # device's last completed block output
    delta: jnp.ndarray         # last residual, f32 () or (K,) per sample —
                               # live on device B-1, psum-broadcast on exit
    history: jnp.ndarray       # per-refinement residuals, (max_iters,[ K]) —
                               # live on device B-1, psum-broadcast on exit
    p_done: jnp.ndarray        # completed refinements (device-local),
                               # int32 () or per-sample (K,)
    conv: jnp.ndarray          # per-sample converged mask on device B-1,
                               # bool () or (K,) (always False elsewhere)
    done: jnp.ndarray          # all-samples-converged flag (replicated)
    my_evals: jnp.ndarray      # int32 model evals this device actually ran
                               # (retired/ramp supersteps skip the eval)


def srds_pipelined_local(model_fn: ModelFn, sched: DiffusionSchedule,
                         solver: SolverConfig, x_init: jnp.ndarray,
                         axis: str, cfg: SRDSConfig):
    """Per-shard wavefront body; one parareal block per device along ``axis``.

    Every *working* superstep performs exactly ONE model call on a 2-sample
    batch (fine slot + coarse slot) per device — the paper's unit of
    "effective serial evals".  The coarse slot is live only on
    block-boundary and init supersteps; it rides the same call (cost: a 2x
    smaller micro-batch would not be faster on the MXU anyway).  Devices
    whose block is past the converged-prefix frontier are *retired* — block
    ``i`` is provably exact after ``i`` refinements, so device ``i-1``
    skips its model call entirely from then on (``lax.cond``; the ring
    exchange still runs every superstep) — and devices ahead of the ramp
    skip theirs too.  The returned ``evals`` counts the model evals that
    actually ran.

    The wavefront restructures *scheduling*, not math: the corrector update
    and convergence gate below are :func:`repro.core.engine.parareal_update`
    and :func:`repro.core.engine.convergence_norm` — the same code the
    sequential and block-sharded samplers run.

    With ``cfg.per_sample`` the leading axis of ``x_init`` is a batch of K
    samples gated independently: the tail device carries a per-sample
    residual/convergence mask, freezes converged samples' outputs, and the
    psum'd done-flag fires only once *every* sample has converged.
    """
    n = sched.num_steps
    d = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    # model evals go through the sharding-aware seam: a model-parallel
    # Denoiser's inner_eval composes its mesh axes with the ring axis
    eval_fn = as_denoiser(model_fn).inner_eval()
    if n % d != 0:
        raise ValueError(f"N={n} must be divisible by device count {d}")
    if resolve_accel(cfg.accel).accelerates:
        # one block per device, no central iterate history: the joint-state
        # mixing the Accelerator seam defines has nowhere to live on the
        # ring — refuse loudly rather than silently not accelerating
        raise ValueError("the wavefront pipeline does not support "
                         "accelerating Accelerators (per-block state is "
                         "distributed with no central iterate history); "
                         "use srds_sample or the sharded driver, or pass "
                         "accel=None")
    s_steps = n // d                       # fine steps per block
    evals_per_step = solver.evals_per_step
    max_iters = cfg.max_iters if cfg.max_iters is not None else d
    # Frontier policy behind per-device retirement.  Retirement is exact
    # and free on the wavefront (see the retire_at note below), so the
    # default is ExactPrefix regardless of cfg.truncate; an explicit
    # cfg.window (e.g. FixedBudget to disable retirement for analysis)
    # overrides it.  ResidualWindow falls back to the provable rule here —
    # per-block residuals live on no single device of the ring.
    policy = cfg.window if cfg.window is not None else ExactPrefix()
    max_supersteps = max_iters * s_steps + d + 2
    right = [(i, (i + 1) % d) for i in range(d)]
    per = cfg.per_sample

    def lane_mask(mask, t):
        # broadcast a per-sample mask against a (K, ...) state tensor
        return mask.reshape(mask.shape + (1,) * (t.ndim - mask.ndim)) \
            if per else mask

    block_i0 = me * s_steps                # my block's first grid index

    def batched_eval(z, j, x_coarse):
        """One lockstep model call advancing fine slot and coarse slot."""
        fine_i0 = block_i0 + j
        # Stack the two slots on a fresh leading axis; solver_step below
        # will broadcast its per-slot grid indices.
        stacked = jnp.stack([z, x_coarse], axis=0)
        i0 = jnp.stack([fine_i0, block_i0])
        i1 = jnp.stack([fine_i0 + 1, block_i0 + s_steps])

        def one(slot, a, b):
            return solver_step(eval_fn, sched, solver, slot, a, b)

        out = jax.vmap(one)(stacked, i0, i1)
        return out[0], out[1]              # fine-advanced z, coarse result

    def body(c: _WaveCarry) -> _WaveCarry:
        rel = c.s - me
        active = rel >= 0
        j = jnp.where(active, rel % s_steps, 0)
        p = jnp.where(active, rel // s_steps + 1, 0)
        is_first = jnp.logical_and(active, j == 0)
        is_last = jnp.logical_and(active, j == s_steps - 1)
        is_init = jnp.logical_and(is_first, p == 1)

        # --- per-device retirement (the wavefront's converged-prefix
        # truncation): block me+1 is provably exact after me+1 refinements
        # (classical Parareal), so once this device has completed
        # min(me+1, max_iters) refinements every further eval would
        # reproduce its boundary bit for bit.  Note the frontier does NOT
        # need the engine's one-refinement lag (prefix_frontier): that lag
        # exists because the engine's init sweep and corrector sweep are
        # two separately compiled scans whose coarse values can differ in
        # the last bits — here BOTH coarse terms of every update come from
        # the same batched_eval call site in this one loop body, so equal
        # inputs give bitwise-equal terms already at the first
        # recomputation (inductively: block i's boundary is a bitwise
        # fixed point from refinement i).  Retired (and not-yet-ramped)
        # devices genuinely skip the model call via lax.cond — the
        # predicate is device-local and the branch holds no collectives, so
        # SPMD stays sound; the ring exchange below still runs every
        # superstep on every device.
        completed = jnp.where(active, rel // s_steps, 0)
        # the tail device keeps computing until `over` freezes it: its
        # residuals feed delta/history, and with max_iters > d a retired
        # tail would report a pinned 0.0 in place of a computed residual
        # (identical by the fixed-point argument, but never synthesize a
        # number that gates convergence) — the policy's retire_at encodes
        # both the per-block rule and the tail exemption
        retire_at = policy.retire_at(me, d, max_iters)
        retired = jnp.logical_and(active, completed >= retire_at)
        do_eval = jnp.logical_and(active, jnp.logical_not(retired))

        # fine input: at j==0 restart from the boundary value x_i^{p-1}
        z_in = jnp.where(is_first, c.x_new, c.z)
        z_out, coarse_out = jax.lax.cond(
            do_eval,
            lambda: batched_eval(z_in, j, c.x_new),
            lambda: (c.z, c.prev_coarse))
        my_evals = c.my_evals + jnp.where(do_eval, 2 * evals_per_step, 0)

        # --- init superstep: coarse_out = G(x_i^0): seed prev_coarse, send
        # --- last superstep:  coarse_out = G(x_i^p): predictor-corrector
        prev_eff = jnp.where(is_init, coarse_out, c.prev_coarse)
        out_block = parareal_update(z_out, coarse_out, prev_eff,
                                    cfg.use_fused_update)
        # a retired device's boundary is already final: pin out_block to it
        # so every downstream consumer (send, residual, out_last) sees the
        # stable value instead of the skipped eval's placeholders
        out_block = jnp.where(retired, c.out_last, out_block)
        send_val = jnp.where(is_last, out_block,
                             jnp.where(is_init, coarse_out, c.out_last))
        send_flag = jnp.logical_or(is_init, is_last)

        new_prev_coarse = jnp.where(jnp.logical_or(is_init, is_last),
                                    coarse_out, c.prev_coarse)
        # out_last tracks x_{i+1}^p (x_{i+1}^0 after the init eval), so the
        # tail device's p=1 residual compares against x_B^0 per Alg. 1.
        # Samples already converged on the tail device stay frozen — their
        # reported output is the value at their convergence refinement, the
        # same contract as the engine's per-sample gating (c.conv is always
        # False off the tail device, so this is a no-op elsewhere).  The
        # superstep budget has a few supersteps of ramp slack past
        # refinement max_iters (for s_steps <= 3 a block can complete an
        # extra refinement inside it) — `over` freezes those too, so
        # iterations/delta/history never report past the budget.
        over = p > max_iters
        frozen = lane_mask(jnp.logical_or(c.conv, over), out_block)
        new_out_last = jnp.where(is_last,
                                 jnp.where(frozen, c.out_last, out_block),
                                 jnp.where(is_init, coarse_out, c.out_last))
        new_p_done = jnp.where(
            jnp.logical_and(
                jnp.logical_and(is_last, jnp.logical_not(retired)),
                jnp.logical_not(jnp.logical_or(c.conv, over))),
            p, c.p_done)

        # convergence residual on the final block (per sample when gated)
        is_tail = me == d - 1
        resid = convergence_norm(out_block - c.out_last, cfg.norm, batched=per)
        upd = jnp.logical_and(is_tail,
                              jnp.logical_and(is_last, jnp.logical_not(over)))
        live = jnp.logical_and(upd, jnp.logical_not(c.conv))
        delta = jnp.where(live, resid, c.delta)
        # record the refinement's residual for still-refining samples (the
        # +inf tail past a sample's convergence matches the engine contract)
        idx = jnp.clip(p - 1, 0, max_iters - 1)
        history = c.history.at[idx].set(
            jnp.where(live, resid, c.history[idx]))
        conv = jnp.where(upd, has_converged(delta, cfg.tol), c.conv)
        local_conv = jnp.where(
            upd, jnp.all(conv).astype(jnp.float32), 0.0)
        done = jax.lax.psum(local_conv, axis) > 0.0

        # ring exchange of boundary values (one sample per neighbor pair)
        recv_val = jax.lax.ppermute(send_val, axis, right)
        recv_flag = jax.lax.ppermute(send_flag.astype(jnp.float32), axis, right)
        take = jnp.logical_and(recv_flag > 0, me > 0)
        x_new = jnp.where(take, recv_val, c.x_new)
        x_new = jnp.where(me == 0, x_init, x_new)   # x_0 is the fixed IC

        return _WaveCarry(c.s + 1, jnp.where(active, z_out, c.z), x_new,
                          jnp.where(active, new_prev_coarse, c.prev_coarse),
                          jnp.where(active, new_out_last, c.out_last),
                          delta, history,
                          jnp.where(active, new_p_done, c.p_done), conv, done,
                          my_evals)

    def cond(c: _WaveCarry):
        return jnp.logical_and(c.s < max_supersteps, jnp.logical_not(c.done))

    if per:
        k = x_init.shape[0]
        delta0 = jnp.full((k,), jnp.inf, jnp.float32)
        hist0 = jnp.full((max_iters, k), jnp.inf, jnp.float32)
        p_done0 = jnp.zeros((k,), jnp.int32)
        conv0 = jnp.zeros((k,), bool)
    else:
        delta0 = jnp.float32(jnp.inf)
        hist0 = jnp.full((max_iters,), jnp.inf, jnp.float32)
        p_done0 = jnp.int32(0)
        conv0 = jnp.asarray(False)
    init = _WaveCarry(s=jnp.int32(0), z=x_init, x_new=x_init,
                      prev_coarse=jnp.zeros_like(x_init),
                      out_last=jnp.zeros_like(x_init),
                      delta=delta0, history=hist0, p_done=p_done0,
                      conv=conv0, done=jnp.asarray(False),
                      my_evals=jnp.int32(0))
    c = jax.lax.while_loop(cond, body, init)

    # broadcast the tail device's answers to every shard
    def from_tail(v):
        return jax.lax.psum(jnp.where(me == d - 1, v, jnp.zeros_like(v)),
                            axis)

    sample = from_tail(c.out_last)
    iters = from_tail(c.p_done)
    delta = from_tail(c.delta)
    history = from_tail(c.history)
    supersteps = c.s
    # physical model evals actually run across the ring (retired and
    # not-yet-ramped devices skipped theirs)
    evals = jax.lax.psum(c.my_evals, axis)
    return sample, iters, delta, history, supersteps, evals


def make_pipelined_sampler(mesh, axis: str, model_fn: ModelFn,
                           sched: DiffusionSchedule, solver: SolverConfig,
                           cfg: SRDSConfig):
    as_denoiser(model_fn).check_mesh(mesh)

    def local(x_init):
        return srds_pipelined_local(model_fn, sched, solver, x_init, axis, cfg)

    fn = compat.shard_map(local, mesh=mesh, in_specs=P(),
                          out_specs=(P(), P(), P(), P(), P(), P()),
                          check_vma=False)

    @jax.jit
    def sample(x_init):
        s, p, dlt, hist, steps, evals = fn(x_init)
        return assemble_result(s, p, dlt, hist), steps, evals

    return sample
