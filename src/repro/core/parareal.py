"""SRDS: Parareal-based self-refining diffusion sampler (paper Algorithm 1).

Single-program version: fine solves across blocks are batched with ``vmap``
(the paper's §3.4 "batched inference" benefit — on TPU the vmapped block dim
fuses into the model's batch and feeds the MXU); all Parareal math — the
coarse sweep, predictor-corrector update, convergence gating, and result
assembly — lives in :mod:`repro.core.engine`, shared verbatim with the
distributed samplers in :mod:`repro.core.pipelined`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .denoiser import as_denoiser
from .engine import (SRDSConfig, SRDSResult, iteration_cost, resolve_blocks,
                     result_from_state, run_parareal, vmap_fine_fn)
from .schedules import DiffusionSchedule
from .sequential import SampleStats
from .solvers import ModelFn, SolverConfig, solve

__all__ = ["SRDSConfig", "SRDSResult", "resolve_blocks", "srds_sample",
           "srds_stats"]


def srds_sample(model_fn: ModelFn, sched: DiffusionSchedule, solver: SolverConfig,
                x_init: jnp.ndarray, cfg: SRDSConfig = SRDSConfig(),
                return_trajectory: bool = False, tol=None) -> SRDSResult:
    """Algorithm 1.  ``x_init ~ N(0, I)`` with shape (batch?, ...).

    With ``cfg.per_sample`` the leading axis of ``x_init`` is a batch of K
    independent samples: convergence is gated per sample (converged samples
    freeze; results are bit-identical to K independent calls) and
    ``iterations``/``final_delta``/``delta_history`` gain a K axis.
    ``tol`` overrides ``cfg.tol`` and may be traced — per-sample mode accepts
    a ``(K,)`` tolerance vector (mixed-tolerance micro-batches).
    With ``cfg.truncate`` refinement ``p`` only fine-solves the non-frozen
    block suffix ``[prefix_frontier(p), B)`` — the frontier lags exactness
    by one refinement for bitwise stability (see
    :func:`repro.core.engine.prefix_frontier`) — bit-identical, strictly
    less work per iteration from the third refinement on.
    ``cfg.window`` generalizes this to any
    :class:`repro.core.window.FrontierPolicy`; with ``ResidualWindow`` the
    result's ``window_history`` records the window lower bound each
    refinement actually ran with (feed it to
    :func:`repro.core.engine.windowed_evals` for the realized eval cost).
    ``cfg.accel`` (a :class:`repro.core.accel.Accelerator`) mixes the
    refinement fixed point — fewer iterations to the same tolerance at
    zero extra model evals per iteration; ``None`` keeps the bit-exact
    unaccelerated loop.
    """
    n = sched.num_steps
    B, S = resolve_blocks(n, cfg.num_blocks)
    max_iters = cfg.max_iters if cfg.max_iters is not None else B
    starts = jnp.arange(B, dtype=jnp.int32) * S
    # every model eval goes through the sharding-aware seam: a
    # model-parallel Denoiser self-wraps its shard_fn over its bound mesh
    # (composing with the vmapped block dim), a plain fn adapts for free
    den = as_denoiser(model_fn)

    def G(x, i0):  # coarse: one solver step across a whole block
        return solve(den, sched, solver, x, i0, 1, S)

    def F(x, i0):  # fine: S solver steps of stride 1
        return solve(den, sched, solver, x, i0, S, 1)

    def _cb(t):
        if cfg.block_sharding is not None:
            return jax.lax.with_sharding_constraint(t, cfg.block_sharding)
        return t

    fine_fn = vmap_fine_fn(F, starts,
                           constrain=_cb if cfg.block_sharding is not None
                           else None)

    out = run_parareal(G, fine_fn, x_init, starts,
                       tol=cfg.tol if tol is None else tol,
                       max_iters=max_iters, norm=cfg.norm,
                       use_fused_update=cfg.use_fused_update,
                       fixed_iters=cfg.fixed_iters,
                       scan_unroll=cfg.scan_unroll,
                       constrain=_cb if cfg.block_sharding is not None
                       else None,
                       batched=cfg.per_sample, truncate=cfg.truncate,
                       window=cfg.window, accel=cfg.accel)

    traj = None
    if return_trajectory:
        traj = jnp.concatenate([x_init[None], out.x_tail], axis=0)
    return result_from_state(out, trajectory=traj)


def srds_stats(sched: DiffusionSchedule, solver: SolverConfig, cfg: SRDSConfig,
               iterations: int, pipelined: bool = False) -> SampleStats:
    """Paper-style eval accounting (Tables 1-3).

    Vanilla:     init B (sequential coarse) + per-iter [S fine (parallel
                 across blocks → S serial) + B coarse (sequential sweep)].
    Pipelined:   wavefront hides the sweep behind fine evals; one superstep
                 = one batched eval → eff ≈ B + k*(S+1)  (paper Table 3).
    Truncated (``cfg.truncate`` / a truncating ``cfg.window`` policy):
                 refinement p fine-solves and sweeps only the window
                 [policy.static_frontier(p), B), so total evals follow
                 the policy's pricing (``predict_evals`` — the ExactPrefix
                 schedule of :func:`repro.core.engine.truncated_evals`;
                 residual-window runs may realize strictly less, see
                 :func:`repro.core.engine.windowed_evals`) and the serial
                 sweep shortens with the frontier.
    """
    from .window import resolve_policy
    B, S = resolve_blocks(sched.num_steps, cfg.num_blocks)
    e = solver.evals_per_step
    k = int(iterations)
    cost = iteration_cost(sched.num_steps, cfg.num_blocks, e)
    pol = resolve_policy(cfg.window, cfg.truncate)
    total = pol.predict_evals(cost, k)
    if pipelined:
        serial = e * (B + k * (S + 1))
    elif pol.truncates:
        serial = e * (B + sum(S + B - pol.static_frontier(p, B)
                              for p in range(k)))
    else:
        serial = e * (B + k * (S + B))
    return SampleStats(serial_evals=serial, total_evals=total, iterations=k)
