"""SRDS: Parareal-based self-refining diffusion sampler (paper Algorithm 1).

Single-program version: fine solves across blocks are batched with ``vmap``
(the paper's §3.4 "batched inference" benefit — on TPU the vmapped block dim
fuses into the model's batch and feeds the MXU); the coarse predictor-
corrector sweep is a ``lax.scan``; refinement iterations run under
``lax.while_loop`` with the paper's final-sample ℓ1 convergence criterion.

The distributed (shard_map / wavefront-pipelined) version lives in
:mod:`repro.core.pipelined` and is algorithmically identical.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .schedules import DiffusionSchedule
from .sequential import SampleStats
from .solvers import ModelFn, SolverConfig, solve


@dataclasses.dataclass(frozen=True)
class SRDSConfig:
    """Knobs for the SRDS sampler.

    num_blocks:   B — the coarse discretization (None -> ceil(sqrt(N)),
                  Prop 4's optimum).
    tol:          τ — convergence threshold on the mean-abs change of the
                  *final* sample between consecutive refinements.
    max_iters:    refinement-iteration cap (None -> B; Prop 1 guarantees
                  exact convergence by then).
    norm:         'l1_mean' (paper) or 'l2_mean' or 'linf'.
    use_fused_update: route the predictor-corrector update + residual
                  accumulation through the Pallas kernel.
    """

    num_blocks: Optional[int] = None
    tol: float = 1e-3
    max_iters: Optional[int] = None
    norm: str = "l1_mean"
    use_fused_update: bool = False
    # Distribution hook: NamedSharding whose first axis is the parareal
    # block dim — constrains the trajectory/fine-solve tensors so GSPMD
    # maps blocks onto a mesh axis (time-parallelism on `data`).
    block_sharding: Optional[object] = None
    # Run exactly max_iters refinements under lax.scan instead of the
    # early-exit while_loop (analysis mode: cost_analysis counts while-loop
    # bodies once; also useful for fixed-budget sampling).
    fixed_iters: bool = False
    scan_unroll: bool = False


class SRDSResult(NamedTuple):
    sample: jnp.ndarray
    iterations: jnp.ndarray        # scalar int32 — refinements actually run
    final_delta: jnp.ndarray       # scalar f32 — last convergence residual
    delta_history: jnp.ndarray     # (max_iters,) f32, +inf beyond `iterations`
    trajectory: Optional[jnp.ndarray] = None  # (B+1, ...) final running traj


def _norm(diff: jnp.ndarray, kind: str) -> jnp.ndarray:
    diff = diff.astype(jnp.float32)
    if kind == "l1_mean":
        return jnp.mean(jnp.abs(diff))
    if kind == "l2_mean":
        return jnp.sqrt(jnp.mean(diff * diff))
    if kind == "linf":
        return jnp.max(jnp.abs(diff))
    raise ValueError(f"unknown norm {kind!r}")


def resolve_blocks(n_steps: int, num_blocks: Optional[int]) -> Tuple[int, int]:
    """Pick (B, S): B blocks of S fine steps, B*S == N.

    Prefers B = ceil(sqrt(N)) rounded to a divisor of N (the paper handles
    ragged last blocks; we keep blocks uniform — required for lockstep SPMD —
    by snapping to the nearest divisor, which preserves Prop 4's optimum for
    the perfect-square Ns used in all paper experiments).
    """
    if num_blocks is None:
        num_blocks = max(1, int(round(math.sqrt(n_steps))))
    # snap to nearest divisor of n_steps
    divs = [d for d in range(1, n_steps + 1) if n_steps % d == 0]
    num_blocks = min(divs, key=lambda d: abs(d - num_blocks))
    return num_blocks, n_steps // num_blocks


def _parareal_update(y, cur, prev, use_fused):
    if use_fused:
        from repro.kernels import ops as kops
        out, _ = kops.parareal_update(y, cur, prev)
        return out
    return y + cur - prev


def srds_sample(model_fn: ModelFn, sched: DiffusionSchedule, solver: SolverConfig,
                x_init: jnp.ndarray, cfg: SRDSConfig = SRDSConfig(),
                return_trajectory: bool = False) -> SRDSResult:
    """Algorithm 1.  ``x_init ~ N(0, I)`` with shape (batch?, ...)."""
    n = sched.num_steps
    B, S = resolve_blocks(n, cfg.num_blocks)
    max_iters = cfg.max_iters if cfg.max_iters is not None else B
    starts = jnp.arange(B, dtype=jnp.int32) * S

    def G(x, i0):  # coarse: one solver step across a whole block
        return solve(model_fn, sched, solver, x, i0, 1, S)

    def F(x, i0):  # fine: S solver steps of stride 1
        return solve(model_fn, sched, solver, x, i0, S, 1)

    # ---- coarse init (Alg 1, lines 1-4): x^0 via sequential G sweep -------
    def init_body(x, i0):
        g = G(x, i0)
        return g, g

    _, x_tail = jax.lax.scan(init_body, x_init, starts,
                             unroll=cfg.scan_unroll)           # (B, ...)
    # prev_coarse_i == G(x_i^0) == x_{i+1}^0 at init.
    prev_coarse = x_tail

    class Carry(NamedTuple):
        p: jnp.ndarray
        x_tail: jnp.ndarray        # (B, ...) running trajectory x_1..x_B
        prev_coarse: jnp.ndarray   # (B, ...) G(x_i^{p-1}) for each block
        delta: jnp.ndarray
        history: jnp.ndarray

    def cond(c: Carry):
        return jnp.logical_and(c.p < max_iters, c.delta >= cfg.tol)

    def _cb(t):
        if cfg.block_sharding is not None:
            return jax.lax.with_sharding_constraint(t, cfg.block_sharding)
        return t

    def body(c: Carry) -> Carry:
        x_heads = jnp.concatenate([x_init[None], c.x_tail[:-1]], axis=0)  # x_0..x_{B-1}
        # ---- parallel fine solves (Alg 1, lines 7-8) ----
        y = _cb(jax.vmap(lambda xi, i0: F(xi, i0))(_cb(x_heads), starts))  # (B, ...)

        # ---- sequential coarse sweep + predictor-corrector (lines 9-12) --
        def sweep(x_cur, inp):
            y_i, prev_i, i0 = inp
            cur = G(x_cur, i0)
            x_next = _parareal_update(y_i, cur, prev_i, cfg.use_fused_update)
            return x_next, (x_next, cur)

        _, (new_tail, cur_all) = jax.lax.scan(sweep, x_init,
                                              (y, c.prev_coarse, starts),
                                              unroll=cfg.scan_unroll)
        new_tail = _cb(new_tail)
        cur_all = _cb(cur_all)

        delta = _norm(new_tail[-1] - c.x_tail[-1], cfg.norm)
        history = c.history.at[c.p].set(delta)
        return Carry(c.p + 1, new_tail, cur_all, delta, history)

    init = Carry(jnp.int32(0), x_tail, prev_coarse,
                 jnp.float32(jnp.inf), jnp.full((max_iters,), jnp.inf, jnp.float32))
    if cfg.fixed_iters:
        out, _ = jax.lax.scan(lambda c, _: (body(c), None), init, None,
                              length=max_iters, unroll=cfg.scan_unroll)
    else:
        out = jax.lax.while_loop(cond, body, init)

    traj = None
    if return_trajectory:
        traj = jnp.concatenate([x_init[None], out.x_tail], axis=0)
    return SRDSResult(sample=out.x_tail[-1], iterations=out.p,
                      final_delta=out.delta, delta_history=out.history,
                      trajectory=traj)


def srds_stats(sched: DiffusionSchedule, solver: SolverConfig, cfg: SRDSConfig,
               iterations: int, pipelined: bool = False) -> SampleStats:
    """Paper-style eval accounting (Tables 1-3).

    Vanilla:     init B (sequential coarse) + per-iter [S fine (parallel
                 across blocks → S serial) + B coarse (sequential sweep)].
    Pipelined:   wavefront hides the sweep behind fine evals; one superstep
                 = one batched eval → eff ≈ B + k*(S+1)  (paper Table 3).
    """
    B, S = resolve_blocks(sched.num_steps, cfg.num_blocks)
    e = solver.evals_per_step
    k = int(iterations)
    total = e * (B + k * (B * S + B))
    if pipelined:
        serial = e * (B + k * (S + 1))
    else:
        serial = e * (B + k * (S + B))
    return SampleStats(serial_evals=serial, total_evals=total, iterations=k)
