"""Noise schedules on the *reversed* grid used throughout the paper.

Grid convention (matches SRDS paper §2): index ``i = 0`` is pure Gaussian
noise, ``i = N`` is the clean sample.  A schedule materializes, for every
grid point, the cumulative signal level ``alpha_bar`` (ᾱ) and the model
conditioning time ``t_model`` (what gets fed to the denoiser's time
embedding — by convention the *traditional* diffusion timestep, so that
pretrained-style denoisers condition identically).

All solvers in :mod:`repro.core.solvers` are defined between arbitrary grid
indices, so the same schedule serves the fine solver (stride 1), the coarse
solver (stride ``N/B``) and the sequential reference.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

_SCHEDULES = {}


def register_schedule(name):
    def deco(fn):
        _SCHEDULES[name] = fn
        return fn

    return deco


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    """Discretized schedule on the reversed grid.

    Attributes:
      ab:       (N+1,) float32 — ᾱ at each grid point; ab[0] ≈ 0 (noise),
                ab[N] ≈ 1 (data).
      t_model:  (N+1,) float32 — conditioning time per grid point
                (monotonically decreasing: t_model[0] is the noisiest).
      kind:     schedule family name (for checkpoint metadata).
    """

    ab: jnp.ndarray
    t_model: jnp.ndarray
    kind: str = "ddpm_linear"

    @property
    def num_steps(self) -> int:
        return int(self.ab.shape[0]) - 1

    def sigma(self, i) -> jnp.ndarray:
        """VE-space sigma at grid index i: sqrt((1-ab)/ab)."""
        a = jnp.take(self.ab, i)
        return jnp.sqrt((1.0 - a) / a)

    def gather(self, i) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(alpha_bar, t_model) at (possibly traced) grid index ``i``."""
        return jnp.take(self.ab, i), jnp.take(self.t_model, i)


def _ddpm_alpha_bar(t_train: int, beta_start: float, beta_end: float) -> np.ndarray:
    betas = np.linspace(beta_start, beta_end, t_train, dtype=np.float64)
    return np.cumprod(1.0 - betas)


def _cosine_alpha_bar(t_train: int, s: float = 0.008) -> np.ndarray:
    ts = np.arange(t_train + 1, dtype=np.float64) / t_train
    f = np.cos((ts + s) / (1 + s) * np.pi / 2) ** 2
    ab = f[1:] / f[0]
    return np.clip(ab, 1e-5, 0.999999)


@register_schedule("ddpm_linear")
def ddpm_linear(num_steps: int, t_train: int = 1000, beta_start: float = 1e-4,
                beta_end: float = 0.02) -> DiffusionSchedule:
    """DDPM linear-β schedule subsampled to ``num_steps`` grid intervals."""
    ab_full = _ddpm_alpha_bar(t_train, beta_start, beta_end)
    # Traditional timesteps, highest-noise first; grid index i maps to
    # traditional step t_trad[i].  i=0 -> t_train-1 (max noise), i=N -> 0.
    t_trad = np.round(np.linspace(t_train - 1, 0, num_steps + 1)).astype(np.int64)
    ab = ab_full[t_trad]
    return DiffusionSchedule(
        ab=jnp.asarray(ab, dtype=jnp.float32),
        t_model=jnp.asarray(t_trad, dtype=jnp.float32),
        kind="ddpm_linear",
    )


@register_schedule("cosine")
def cosine(num_steps: int, t_train: int = 1000) -> DiffusionSchedule:
    ab_full = _cosine_alpha_bar(t_train)
    t_trad = np.round(np.linspace(t_train - 1, 0, num_steps + 1)).astype(np.int64)
    ab = ab_full[t_trad]
    return DiffusionSchedule(
        ab=jnp.asarray(ab, dtype=jnp.float32),
        t_model=jnp.asarray(t_trad, dtype=jnp.float32),
        kind="cosine",
    )


@register_schedule("karras")
def karras(num_steps: int, sigma_min: float = 0.002, sigma_max: float = 80.0,
           rho: float = 7.0) -> DiffusionSchedule:
    """Karras et al. (2022) σ-grid, expressed as ᾱ via VP<->VE: ab = 1/(1+σ²)."""
    steps = np.arange(num_steps + 1, dtype=np.float64) / num_steps
    sig = (sigma_max ** (1 / rho) + steps * (sigma_min ** (1 / rho) - sigma_max ** (1 / rho))) ** rho
    sig[-1] = sigma_min  # keep strictly positive so VE transform stays finite
    ab = 1.0 / (1.0 + sig ** 2)
    return DiffusionSchedule(
        ab=jnp.asarray(ab, dtype=jnp.float32),
        t_model=jnp.asarray(sig, dtype=jnp.float32),
        kind="karras",
    )


def make_schedule(kind: str, num_steps: int, **kw) -> DiffusionSchedule:
    if kind not in _SCHEDULES:
        raise ValueError(f"unknown schedule {kind!r}; have {sorted(_SCHEDULES)}")
    return _SCHEDULES[kind](num_steps, **kw)
