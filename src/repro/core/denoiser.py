"""The sharding-aware model-eval seam (`Denoiser`).

Every driver (``srds_sample``, the sharded and wavefront samplers) and the
serving engine evaluates the diffusion backbone through this one seam
instead of calling a bare ``model_fn(x, t)``.  A :class:`Denoiser` is a
callable that *also* carries its parallelism contract:

* ``in_spec`` / ``out_spec`` — :class:`~jax.sharding.PartitionSpec`s over
  the **sample** layout ``(K, *sample_shape)`` naming which dims the
  backbone shards over its own mesh axes (e.g. DiT patch-sharding rows
  over a ``model`` axis: ``P(None, "model")``);
* ``mesh_axes`` — the axes the backbone requires of whatever mesh it runs
  under, as ``{axis_name: min_size}``;
* ``fn`` — the single-device global math, the bit-exactness reference;
* ``shard_fn`` — the per-shard body: takes/returns the ``in_spec`` /
  ``out_spec`` shard and may use collectives over ``mesh_axes`` names.

Plain ``model_fn(x, t)`` callables adapt losslessly via
:func:`as_denoiser` (replicated specs, no mesh requirement), so every
existing call path is unchanged.  A model-parallel denoiser composes with
the drivers' time/data parallelism in three ways, all driver-agnostic:

1. **standalone** (``den(x, t)``): self-wraps ``shard_fn`` in a
   ``shard_map`` over the denoiser's bound ``mesh`` — what ``srds_sample``
   hits (vmap-of-shard_map over blocks);
2. **inner** (``den.inner_eval()``): for call sites already inside a
   driver ``shard_map`` whose in/out specs *replicate* over the model
   axes (the sharded/wavefront drivers).  The mesh axes are still bound
   inside the enclosing body, so the glue slices the replicated operand
   per ``in_spec``, runs ``shard_fn``, and all-gathers per ``out_spec``;
3. **shard** (``den.shard_eval()``): for bodies whose specs already
   shard the operand per ``in_spec`` (the serve engine's fine program via
   ``parallel.sharding.denoiser_spec``) — ``shard_fn`` applies directly,
   no per-eval collectives beyond the backbone's own.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

__all__ = ["Denoiser", "as_denoiser"]


def _spec_axes(spec):
    """(dim, axis_name) pairs for every sharded dim of a PartitionSpec."""
    out = []
    for dim, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        if not isinstance(entry, str):
            raise ValueError(
                f"Denoiser specs shard each dim over at most one axis; got "
                f"{entry!r} at dim {dim}")
        out.append((dim, entry))
    return out


def _slice_spec(x, spec):
    """The local ``spec``-shard of a replicated ``x`` (inside shard_map)."""
    for dim, name in _spec_axes(spec):
        n = compat.axis_size(name)
        if x.shape[dim] % n:
            raise ValueError(
                f"dim {dim} of shape {x.shape} not divisible by axis "
                f"{name!r} (size {n})")
        chunk = x.shape[dim] // n
        x = jax.lax.dynamic_slice_in_dim(
            x, jax.lax.axis_index(name) * chunk, chunk, axis=dim)
    return x


def _gather_spec(y, spec):
    """Reassemble the global array from ``spec``-shards (inside shard_map)."""
    for dim, name in _spec_axes(spec):
        y = jax.lax.all_gather(y, name, axis=dim, tiled=True)
    return y


@dataclasses.dataclass(frozen=True)
class Denoiser:
    """A model-eval callable carrying its sharding contract (see module
    docstring).  ``Denoiser(fn=f)`` with the defaults is exactly ``f`` —
    replicated specs, no mesh requirement, zero overhead."""

    fn: Callable                        # global (x, t) -> eps, the reference
    shard_fn: Optional[Callable] = None  # per-shard body (None = fn)
    in_spec: P = P()
    out_spec: P = P()
    mesh_axes: Mapping[str, int] = dataclasses.field(default_factory=dict)
    mesh: Optional[Mesh] = None          # bound mesh for standalone calls

    def __post_init__(self):
        if self.mesh_axes and self.shard_fn is None:
            raise ValueError("a Denoiser with mesh_axes needs a shard_fn")
        if self.mesh is not None:
            self.check_mesh(self.mesh)

    @property
    def is_model_parallel(self) -> bool:
        return bool(self.mesh_axes)

    def check_mesh(self, mesh: Mesh) -> None:
        """Raise a clear ValueError unless ``mesh`` binds every required
        axis at its minimum size (instead of XLA's unbound-axis error)."""
        shape = dict(mesh.shape)
        for name, min_size in self.mesh_axes.items():
            if name not in shape:
                raise ValueError(
                    f"denoiser requires mesh axis {name!r} but mesh has "
                    f"axes {tuple(shape)}")
            if shape[name] < min_size:
                raise ValueError(
                    f"denoiser requires mesh axis {name!r} of size >= "
                    f"{min_size}, got {shape[name]}")

    def bind(self, mesh: Mesh) -> "Denoiser":
        """A copy bound to ``mesh`` (validated) for standalone calls."""
        return dataclasses.replace(self, mesh=mesh)

    def __call__(self, x, t):
        """Global eval.  Model-parallel denoisers self-wrap ``shard_fn``
        in a shard_map over their bound mesh; plain ones are just ``fn``."""
        if not self.is_model_parallel:
            return self.fn(x, t)
        if self.mesh is None:
            raise ValueError(
                "model-parallel Denoiser called standalone without a bound "
                "mesh; use .bind(mesh) or eval inside a driver shard_map "
                "via .inner_eval()/.shard_eval()")
        wrapped = compat.shard_map(
            self.shard_fn, mesh=self.mesh,
            in_specs=(self.in_spec, P()), out_specs=self.out_spec,
            check_vma=False)
        return wrapped(x, t)

    def inner_eval(self) -> Callable:
        """Eval callable for *inside* an enclosing shard_map whose specs
        replicate over this denoiser's mesh axes (slice -> shard_fn ->
        all_gather; identity glue for plain denoisers)."""
        if not self.is_model_parallel:
            return self.fn
        shard_fn, in_spec, out_spec = self.shard_fn, self.in_spec, self.out_spec

        def eval_fn(x, t):
            return _gather_spec(shard_fn(_slice_spec(x, in_spec), t), out_spec)

        return eval_fn

    def shard_eval(self) -> Callable:
        """Eval callable for inside a shard_map whose specs already shard
        the operand per ``in_spec`` (see ``parallel.sharding.denoiser_spec``)."""
        return self.shard_fn if self.is_model_parallel else self.fn


def as_denoiser(fn) -> Denoiser:
    """Adapt a plain ``model_fn(x, t)`` callable into the seam (identity
    for values that are already :class:`Denoiser`)."""
    if isinstance(fn, Denoiser):
        return fn
    return Denoiser(fn=fn)
