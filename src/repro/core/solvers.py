"""ODE/SDE solvers defined between arbitrary grid indices.

A solver *step* propagates ``x`` from grid index ``i0`` to ``i1`` (``i1 >
i0``; indices may be traced).  A *solve* chains ``n_steps`` steps of a fixed
``stride``.  The crucial structural property for SRDS/Parareal:

    solve(stride=1, n_steps=S) applied block-by-block composes to EXACTLY the
    sequential N-step solve, while solve(stride=S, n_steps=1) is the coarse
    solver G on the same schedule.

Solver signatures take ``model_fn(x, t) -> eps`` where ``t`` is a scalar
conditioning time (broadcast by the model wrapper as needed).

Evals-per-step (for the paper's eval accounting): ddim/euler/ddpm = 1,
heun/dpm2 = 2.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .schedules import DiffusionSchedule

ModelFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

_SOLVERS = {}


def register_solver(name: str, evals_per_step: int):
    def deco(fn):
        _SOLVERS[name] = (fn, evals_per_step)
        return fn

    return deco


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str = "ddim"
    eta: float = 0.0          # DDIM stochasticity (ddpm solver uses eta=1)
    noise_key: Optional[Any] = None  # PRNGKey for stochastic solvers (frozen noise)
    # Route the DDIM update through the Pallas op.  None = "on where
    # supported" (compiled kernels on TPU/GPU; CPU keeps the jnp path — see
    # repro.kernels.ops.fused_default); an explicit bool always wins.
    use_fused_kernel: Optional[bool] = None
    unroll: bool = False             # unroll multi-step solves (analysis mode)

    @property
    def evals_per_step(self) -> int:
        return _SOLVERS[self.name][1]


def _vp_to_sigma(a):
    return jnp.sqrt((1.0 - a) / a)


def _ddim_update(x, eps, a, b):
    """Deterministic DDIM map from signal level a -> b given eps prediction."""
    x0 = (x - jnp.sqrt(1.0 - a) * eps) / jnp.sqrt(a)
    return jnp.sqrt(b) * x0 + jnp.sqrt(1.0 - b) * eps


@register_solver("ddim", evals_per_step=1)
def ddim_step(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
              x: jnp.ndarray, i0, i1) -> jnp.ndarray:
    a, t0 = sched.gather(i0)
    b, _ = sched.gather(i1)
    eps = model_fn(x, t0)
    from .engine import resolve_fused
    if resolve_fused(cfg.use_fused_kernel):
        from repro.kernels import ops as kops
        return kops.ddim_fused(x, eps, a, b)
    return _ddim_update(x, eps, a, b)


# Euler on the probability-flow ODE in the VE-rescaled space coincides with
# DDIM (DPM-Solver-1 == DDIM); registered as an alias for API parity with the
# paper's solver table.
@register_solver("euler", evals_per_step=1)
def euler_step(model_fn, sched, cfg, x, i0, i1):
    return ddim_step(model_fn, sched, cfg, x, i0, i1)


@register_solver("heun", evals_per_step=2)
def heun_step(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
              x: jnp.ndarray, i0, i1) -> jnp.ndarray:
    """Heun (trapezoid) in VE sigma-space: 2nd-order, 2 evals."""
    a, t0 = sched.gather(i0)
    b, t1 = sched.gather(i1)
    s0 = _vp_to_sigma(a)
    s1 = _vp_to_sigma(b)
    xhat = x / jnp.sqrt(a)                       # VE coordinates
    eps0 = model_fn(x, t0)
    x1_pred_hat = xhat + (s1 - s0) * eps0        # Euler predictor
    x1_pred = jnp.sqrt(b) * x1_pred_hat
    eps1 = model_fn(x1_pred, t1)
    xhat1 = xhat + (s1 - s0) * 0.5 * (eps0 + eps1)
    return jnp.sqrt(b) * xhat1


@register_solver("dpm2", evals_per_step=2)
def dpm2_step(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
              x: jnp.ndarray, i0, i1) -> jnp.ndarray:
    """DPM-Solver-2 (midpoint in log-SNR λ-space)."""
    a, t0 = sched.gather(i0)
    b, t1 = sched.gather(i1)
    lam0 = 0.5 * (jnp.log(a) - jnp.log1p(-a))
    lam1 = 0.5 * (jnp.log(b) - jnp.log1p(-b))
    h = lam1 - lam0
    lam_mid = lam0 + 0.5 * h
    # invert λ -> ᾱ: ᾱ = sigmoid(2λ)
    a_mid = jax.nn.sigmoid(2.0 * lam_mid)
    t_mid = 0.5 * (t0 + t1)  # conditioning time at the midpoint (linear in grid)
    eps0 = model_fn(x, t0)
    # DPM-Solver-1 step to the midpoint
    x_mid = jnp.sqrt(a_mid / a) * x - jnp.sqrt(1.0 - a_mid) * jnp.expm1(0.5 * h) * eps0
    eps_mid = model_fn(x_mid, t_mid)
    return jnp.sqrt(b / a) * x - jnp.sqrt(1.0 - b) * jnp.expm1(h) * eps_mid


@register_solver("ddpm", evals_per_step=1)
def ddpm_step(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
              x: jnp.ndarray, i0, i1) -> jnp.ndarray:
    """η=1 stochastic DDIM (== DDPM ancestral) with *frozen* noise.

    The per-interval noise is a deterministic function of (key, i0, i1), so
    the solve is a well-posed IVP with known forcing: Parareal's exactness
    guarantee applies unchanged (the sequential and fine solvers see the same
    noise realization for each fine-grid interval; the coarse solver sees a
    consistent realization for its own intervals across iterations).
    """
    if cfg.noise_key is None:
        raise ValueError("ddpm solver requires SolverConfig.noise_key")
    a, t0 = sched.gather(i0)
    b, _ = sched.gather(i1)
    eps = model_fn(x, t0)
    eta = cfg.eta if cfg.eta > 0 else 1.0
    sigma = eta * jnp.sqrt(jnp.clip((1 - b) / (1 - a), 0, None)
                           * jnp.clip(1 - a / b, 0, None))
    x0 = (x - jnp.sqrt(1.0 - a) * eps) / jnp.sqrt(a)
    mean = jnp.sqrt(b) * x0 + jnp.sqrt(jnp.clip(1.0 - b - sigma ** 2, 0, None)) * eps
    # counter-based frozen noise: fold the interval id into the key
    k = jax.random.fold_in(cfg.noise_key, i0 * (sched.num_steps + 1) + i1)
    noise = jax.random.normal(k, x.shape, x.dtype)
    return mean + sigma * noise


def solver_step(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
                x: jnp.ndarray, i0, i1) -> jnp.ndarray:
    step_fn, _ = _SOLVERS[cfg.name]
    i0 = jnp.asarray(i0, jnp.int32)
    i1 = jnp.asarray(i1, jnp.int32)
    return step_fn(model_fn, sched, cfg, x, i0, i1)


def solve(model_fn: ModelFn, sched: DiffusionSchedule, cfg: SolverConfig,
          x: jnp.ndarray, i_start, n_steps: int, stride: int) -> jnp.ndarray:
    """``n_steps`` solver steps of ``stride`` grid intervals each.

    ``i_start`` may be traced (per-block starts under vmap); ``n_steps`` and
    ``stride`` are static.
    """
    if n_steps == 1:
        return solver_step(model_fn, sched, cfg, x, i_start,
                           jnp.asarray(i_start) + stride)

    def body(carry, k):
        i0 = jnp.asarray(i_start) + k * stride
        return solver_step(model_fn, sched, cfg, carry, i0, i0 + stride), None

    x, _ = jax.lax.scan(body, x, jnp.arange(n_steps, dtype=jnp.int32),
                        unroll=cfg.unroll)
    return x


def solver_names():
    return sorted(_SOLVERS)
