"""ParaDiGMS baseline (Shih et al. 2023): Picard iteration + sliding window.

The SRDS paper's main baseline (Tables 4 & 6).  Implemented faithfully in
its deterministic-ODE form:

  * keep the whole trajectory resident — the O(N) memory footprint the SRDS
    paper criticizes (Prop 3 discussion / Appendix D);
  * each Picard sweep evaluates every point in the active window in
    parallel, then reconciles with a *prefix sum* (the cumulative-sum
    cross-device sync the SRDS paper calls out as communication-expensive);
  * a per-step tolerance decides how far the converged prefix slides.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schedules import DiffusionSchedule
from .sequential import SampleStats
from .solvers import ModelFn, SolverConfig, solver_step


@dataclasses.dataclass(frozen=True)
class ParaDiGMSConfig:
    window: int = 64
    tol: float = 1e-3          # per-step mean-square tolerance (their τ)
    max_iters: int = 10_000


class ParaDiGMSResult(NamedTuple):
    sample: jnp.ndarray
    iterations: jnp.ndarray     # Picard sweeps == effective serial evals
    total_evals: jnp.ndarray


def paradigms_sample(model_fn: ModelFn, sched: DiffusionSchedule,
                     solver: SolverConfig, x_init: jnp.ndarray,
                     cfg: ParaDiGMSConfig = ParaDiGMSConfig()) -> ParaDiGMSResult:
    n = sched.num_steps
    w = min(cfg.window, n)

    # Picard init: the whole window starts at the current anchor value.
    xs = jnp.broadcast_to(x_init, (n + 1,) + x_init.shape).astype(x_init.dtype)

    def phi(x, i):  # one fine step from grid i -> i+1
        return solver_step(model_fn, sched, solver, x, i, i + 1)

    class Carry(NamedTuple):
        xs: jnp.ndarray
        lo: jnp.ndarray
        iters: jnp.ndarray
        total_evals: jnp.ndarray

    def cond(c: Carry):
        return jnp.logical_and(c.lo < n, c.iters < cfg.max_iters)

    def body(c: Carry) -> Carry:
        idx = c.lo + jnp.arange(w, dtype=jnp.int32)          # window grid points
        valid = idx < n
        idx_c = jnp.minimum(idx, n - 1)
        xw = c.xs[idx_c]                                      # (w, ...)
        # parallel Picard sweep: drift at every window point
        stepped = jax.vmap(phi)(xw, idx_c)                    # (w, ...)
        drift = stepped - xw
        drift = jnp.where(
            valid.reshape((-1,) + (1,) * (drift.ndim - 1)), drift, 0.0)
        # prefix-sum reconciliation: x_{t+1} = x_lo + sum_{s<=t} drift_s
        prefix = jnp.cumsum(drift, axis=0)
        new_vals = c.xs[c.lo][None] + prefix                  # candidates for idx+1
        old_vals = c.xs[jnp.minimum(idx + 1, n)]
        err = jnp.mean(
            jnp.square(new_vals - old_vals).reshape(w, -1), axis=-1)
        # converged prefix: longest run of leading window steps under tol
        under = jnp.logical_and(err < cfg.tol * cfg.tol, valid)
        stride = jnp.argmin(jnp.cumprod(under.astype(jnp.int32))).astype(jnp.int32)
        stride = jnp.where(jnp.all(under), jnp.sum(valid, dtype=jnp.int32), stride)
        stride = jnp.maximum(stride, 1)
        # scatter candidates back (out-of-range -> dropped)
        tgt = jnp.where(valid, idx + 1, n + 8)
        xs = c.xs.at[tgt].set(new_vals, mode="drop")
        n_evals = jnp.sum(valid, dtype=jnp.int32) * solver.evals_per_step
        return Carry(xs, (c.lo + stride).astype(jnp.int32), c.iters + 1,
                     (c.total_evals + n_evals).astype(jnp.int32))

    out = jax.lax.while_loop(
        cond, body,
        Carry(xs, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return ParaDiGMSResult(sample=out.xs[n], iterations=out.iters,
                           total_evals=out.total_evals)


def paradigms_stats(res: ParaDiGMSResult, solver: SolverConfig) -> SampleStats:
    return SampleStats(serial_evals=int(res.iterations) * solver.evals_per_step,
                       total_evals=int(res.total_evals),
                       iterations=int(res.iterations))
