"""SRDS core: schedules, solvers, sequential/parareal/pipelined samplers.

The Parareal math itself (coarse sweep, predictor-corrector, convergence
gating, result assembly) lives in :mod:`repro.core.engine`; the samplers in
``parareal`` / ``pipelined`` are thin drivers over it.
"""
from .schedules import DiffusionSchedule, make_schedule
from .solvers import SolverConfig, solve, solver_step, solver_names
from .denoiser import Denoiser, as_denoiser
from .sequential import SampleStats, sample_sequential, sequential_stats
from .engine import (IterationCost, SRDSConfig, SRDSResult, iteration_cost,
                     predicted_evals, resolve_blocks, truncated_evals,
                     windowed_evals)
from .window import (ExactPrefix, FixedBudget, FrontierPolicy,
                     ResidualWindow, resolve_policy)
from .accel import (Accelerator, AndersonAccel, NoAccel, TriangularAccel,
                    resolve_accel)
from .parareal import srds_sample, srds_stats
from .paradigms import ParaDiGMSConfig, ParaDiGMSResult, paradigms_sample, paradigms_stats

__all__ = [
    "DiffusionSchedule", "make_schedule",
    "SolverConfig", "solve", "solver_step", "solver_names",
    "Denoiser", "as_denoiser",
    "SampleStats", "sample_sequential", "sequential_stats",
    "SRDSConfig", "SRDSResult", "resolve_blocks", "srds_sample", "srds_stats",
    "IterationCost", "iteration_cost", "predicted_evals", "truncated_evals",
    "windowed_evals",
    "FrontierPolicy", "ExactPrefix", "ResidualWindow", "FixedBudget",
    "resolve_policy",
    "Accelerator", "NoAccel", "AndersonAccel", "TriangularAccel",
    "resolve_accel",
    "ParaDiGMSConfig", "ParaDiGMSResult", "paradigms_sample", "paradigms_stats",
]
