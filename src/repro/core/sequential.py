"""Sequential N-step reference sampler (the paper's baseline & ground truth).

SRDS is *approximation-free*: its output must equal this sampler's output
(Prop 1).  Every equivalence test in the suite compares against this module.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .schedules import DiffusionSchedule
from .solvers import ModelFn, SolverConfig, solve


@dataclasses.dataclass(frozen=True)
class SampleStats:
    """Eval accounting in the paper's units.

    ``serial_evals``: model evaluations on the critical path ("Eff. Serial
    Evals" in Tables 1-3 — simultaneous parallel evals count once).
    ``total_evals``: all model evaluations performed.
    """

    serial_evals: int
    total_evals: int
    iterations: int = 0


def sample_sequential(model_fn: ModelFn, sched: DiffusionSchedule,
                      cfg: SolverConfig, x_init: jnp.ndarray) -> jnp.ndarray:
    """The plain N-step solve: x_N = F(...F(F(x_0)))."""
    return solve(model_fn, sched, cfg, x_init, 0, sched.num_steps, 1)


def sequential_stats(sched: DiffusionSchedule, cfg: SolverConfig) -> SampleStats:
    n = sched.num_steps * cfg.evals_per_step
    return SampleStats(serial_evals=n, total_evals=n)
