"""Unified Parareal engine — the single home of SRDS's refinement math.

Every SRDS sampler in this repo (sequential single-program
:func:`repro.core.parareal.srds_sample`, block-sharded
:func:`repro.core.pipelined.srds_sharded_local`, wavefront-pipelined
:func:`repro.core.pipelined.srds_pipelined_local`) consumes this module for:

  * the coarse initialization sweep (Alg 1, lines 1-4),
  * the predictor-corrector update ``y + G_cur - G_prev`` (line 11),
  * the sequential corrector sweep (lines 9-12),
  * convergence gating on the final-sample residual,
  * ``SRDSResult`` assembly.

The three samplers differ only in *where the fine solves run* (vmapped in
one program, locally per shard with an all_gather, or wavefront-staggered)
— that part is injected into :func:`run_parareal` as ``fine_fn`` — so the
algorithm itself can no longer drift between implementations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SRDSConfig:
    """Knobs for the SRDS sampler.

    num_blocks:   B — the coarse discretization (None -> ceil(sqrt(N)),
                  Prop 4's optimum).
    tol:          τ — convergence threshold on the mean-abs change of the
                  *final* sample between consecutive refinements.
    max_iters:    refinement-iteration cap (None -> B; Prop 1 guarantees
                  exact convergence by then).
    norm:         'l1_mean' (paper) or 'l2_mean' or 'linf'.
    use_fused_update: route the predictor-corrector update + residual
                  accumulation through the Pallas kernel.
    per_sample:   gate convergence independently per sample over the leading
                  batch axis of ``x_init`` (shape ``(K, ...)``): the residual,
                  iteration counter and delta history become per-sample
                  ``(K,)``-shaped, converged samples freeze (their updates
                  are masked to no-ops) and the loop exits only when every
                  sample converged or ``max_iters`` hits.  Off (the default):
                  a single joint-norm residual gates the whole batch.
    """

    num_blocks: Optional[int] = None
    tol: float = 1e-3
    max_iters: Optional[int] = None
    norm: str = "l1_mean"
    use_fused_update: bool = False
    per_sample: bool = False
    # Distribution hook: NamedSharding whose first axis is the parareal
    # block dim — constrains the trajectory/fine-solve tensors so GSPMD
    # maps blocks onto a mesh axis (time-parallelism on `data`).
    block_sharding: Optional[object] = None
    # Run exactly max_iters refinements under lax.scan instead of the
    # early-exit while_loop (analysis mode: cost_analysis counts while-loop
    # bodies once; also useful for fixed-budget sampling).
    fixed_iters: bool = False
    scan_unroll: bool = False


class SRDSResult(NamedTuple):
    """Per-sample fields are scalar/(max_iters,)-shaped in joint-gating mode
    and gain a trailing batch axis of size K under per-sample gating."""
    sample: jnp.ndarray
    iterations: jnp.ndarray        # int32 () or (K,) — refinements actually run
    final_delta: jnp.ndarray       # f32 () or (K,) — last convergence residual
    delta_history: jnp.ndarray     # f32 (max_iters,) or (max_iters, K),
                                   # +inf beyond `iterations`
    trajectory: Optional[jnp.ndarray] = None  # (B+1, ...) final running traj


def convergence_norm(diff: jnp.ndarray, kind: str,
                     batched: bool = False) -> jnp.ndarray:
    """Residual norm used for the paper's convergence criterion.

    With ``batched=True`` the reduction skips the leading batch axis and
    returns one residual per sample: ``(K, ...) -> (K,)``.
    """
    diff = diff.astype(jnp.float32)
    axes = tuple(range(1, diff.ndim)) if batched else None
    if kind == "l1_mean":
        return jnp.mean(jnp.abs(diff), axis=axes)
    if kind == "l2_mean":
        return jnp.sqrt(jnp.mean(diff * diff, axis=axes))
    if kind == "linf":
        return jnp.max(jnp.abs(diff), axis=axes)
    raise ValueError(f"unknown norm {kind!r}")


def still_refining(delta: jnp.ndarray, tol) -> jnp.ndarray:
    """Convergence gate: keep iterating while the residual is >= τ.

    Elementwise — ``delta`` and ``tol`` may be scalars or per-sample ``(K,)``
    vectors (mixed-tolerance micro-batches pass a tol vector).
    """
    return delta >= tol


def has_converged(delta: jnp.ndarray, tol) -> jnp.ndarray:
    """The complementary gate (used by the wavefront's done-flag psum)."""
    return delta < tol


def resolve_blocks(n_steps: int, num_blocks: Optional[int]) -> Tuple[int, int]:
    """Pick (B, S): B blocks of S fine steps, B*S == N.

    Blocks are uniform — lockstep SPMD requires every block to run the same
    number of fine steps, so B must divide N exactly (the paper instead
    allows a ragged last block).  An explicit ``num_blocks`` that does not
    divide ``n_steps`` is an error.  With ``num_blocks=None``, B is
    ceil(sqrt(N)) snapped to the nearest *nontrivial* divisor of N (1 < B < N,
    preserving Prop 4's optimum for the perfect-square Ns of the paper's
    experiments); if none exists (prime N) this raises rather than silently
    degrading to the fully-serial B=1.
    """
    if num_blocks is not None:
        if not 1 <= num_blocks <= n_steps or n_steps % num_blocks != 0:
            raise ValueError(
                f"num_blocks={num_blocks} does not divide N={n_steps}: SRDS "
                f"blocks are uniform (B*S == N). Pick a divisor of N or pass "
                f"num_blocks=None to auto-select one.")
        return num_blocks, n_steps // num_blocks
    target = max(1, int(round(math.sqrt(n_steps))))
    divs = [d for d in range(2, n_steps) if n_steps % d == 0]
    if not divs:
        raise ValueError(
            f"N={n_steps} has no nontrivial divisor (prime): every block "
            f"split degenerates to the serial solve. Choose a composite "
            f"number of steps, or pass num_blocks={n_steps} or 1 explicitly "
            f"to accept a degenerate split.")
    num_blocks = min(divs, key=lambda d: abs(d - target))
    return num_blocks, n_steps // num_blocks


class IterationCost(NamedTuple):
    """Per-lane model-eval cost of one SRDS run, split by phase.

    ``init_evals`` is the sequential coarse sweep (B coarse steps);
    ``refine_evals`` is one Parareal refinement (B*S parallel fine steps +
    the B-step sequential corrector sweep).  All counts are in *model
    evals* — the paper's hardware-independent unit — already scaled by the
    solver's evals-per-step.
    """
    init_evals: int
    refine_evals: int


def iteration_cost(num_steps: int, num_blocks: Optional[int] = None,
                   evals_per_step: int = 1) -> IterationCost:
    """The engine's eval accounting, exported for cost-model consumers.

    Both the serving layer's per-request ``model_evals`` charge and the
    scheduler's completion-time predictor derive from this one function, so
    admission decisions and billing can never disagree with what the
    refinement loop actually executes.
    """
    B, S = resolve_blocks(num_steps, num_blocks)
    return IterationCost(init_evals=B * evals_per_step,
                         refine_evals=(B * S + B) * evals_per_step)


def predicted_evals(cost: IterationCost, iterations: int) -> int:
    """Total per-lane evals for a run that takes ``iterations`` refinements."""
    return cost.init_evals + iterations * cost.refine_evals


def parareal_update(y, g_cur, g_prev, use_fused: bool = False):
    """Predictor-corrector update (Alg 1, line 11): ``y + G_cur - G_prev``."""
    if use_fused:
        from repro.kernels import ops as kops
        out, _ = kops.parareal_update(y, g_cur, g_prev)
        return out
    return y + g_cur - g_prev


def coarse_init_sweep(G, x_init: jnp.ndarray, starts: jnp.ndarray,
                      unroll: bool = False) -> jnp.ndarray:
    """Sequential coarse sweep producing the initial trajectory tail x^0.

    Returns the (B, ...) stack ``[x_1^0, ..., x_B^0]`` where
    ``x_{i+1}^0 = G(x_i^0)`` — which doubles as ``prev_coarse`` at init.
    """
    def body(x, i0):
        g = G(x, i0)
        return g, g

    _, x_tail = jax.lax.scan(body, x_init, starts, unroll=unroll)
    return x_tail


def corrector_sweep(G, x_init: jnp.ndarray, y: jnp.ndarray,
                    prev_coarse: jnp.ndarray, starts: jnp.ndarray, *,
                    use_fused: bool = False, unroll: bool = False):
    """Sequential coarse sweep + predictor-corrector (Alg 1, lines 9-12).

    Returns ``(new_tail, cur_all)``: the refined trajectory tail and the
    coarse results ``G(x_i^p)`` that become next iteration's prev_coarse.
    """
    def sweep(x_cur, inp):
        y_i, prev_i, i0 = inp
        cur = G(x_cur, i0)
        x_next = parareal_update(y_i, cur, prev_i, use_fused)
        return x_next, (x_next, cur)

    _, (new_tail, cur_all) = jax.lax.scan(sweep, x_init,
                                          (y, prev_coarse, starts),
                                          unroll=unroll)
    return new_tail, cur_all


class RefineState(NamedTuple):
    """Carry of the refinement loop (shared by all non-wavefront samplers).

    Under per-sample gating (``batched=True``), ``delta``/``iters``/``active``
    are ``(K,)`` vectors over the leading batch axis and ``history`` is
    ``(max_iters, K)``; otherwise they are the scalar joint-gating carries.
    """
    p: jnp.ndarray             # refinement counter (scalar int32, lockstep)
    x_tail: jnp.ndarray        # (B, ...) running trajectory x_1..x_B
    prev_coarse: jnp.ndarray   # (B, ...) G(x_i^{p-1}) for each block
    y_prev: jnp.ndarray        # (B, ...) last fine results when
                               # carry_fine_results (straggler reuse),
                               # else a scalar placeholder
    delta: jnp.ndarray         # last convergence residual, f32 () or (K,)
    history: jnp.ndarray       # residual history, f32 (max_iters,[ K])
    iters: jnp.ndarray         # refinements applied, int32 () or (K,)
    active: jnp.ndarray        # frozen-when-converged mask, bool () or (K,)


FineFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _batch_mask(mask: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (K,) sample mask against a (B, K, ...) trajectory tensor."""
    return mask.reshape((1,) + mask.shape + (1,) * (t.ndim - 2))


def run_parareal(G, fine_fn: FineFn, x_init: jnp.ndarray,
                 starts: jnp.ndarray, *, tol, max_iters: int,
                 norm: str = "l1_mean", use_fused_update: bool = False,
                 fixed_iters: bool = False, scan_unroll: bool = False,
                 constrain=None, carry_fine_results: bool = False,
                 batched: bool = False) -> RefineState:
    """The complete Parareal refinement loop (Alg 1 minus the fine solves).

    ``fine_fn(x_heads, p, y_prev) -> y`` computes the (B, ...) fine-solve
    results for block heads ``x_heads = [x_0, ..., x_{B-1}]`` at refinement
    ``p`` — this is the only sampler-specific part (vmap in one program;
    local vmap + all_gather + straggler masking under shard_map).
    ``tol`` may be a python float, a traced scalar, or — with ``batched`` —
    a per-sample ``(K,)`` vector (mixed-tolerance micro-batches).
    ``constrain`` (optional) re-applies a block-dim sharding constraint to
    the trajectory tensors each iteration (GSPMD time-parallel path).
    ``carry_fine_results`` keeps the previous iteration's (B, ...) fine
    results in the loop carry, handed to ``fine_fn`` as ``y_prev`` (needed
    for straggler reuse); off by default so samplers that never read it
    don't pay an extra trajectory-sized buffer of loop state.
    ``batched`` treats the leading axis of ``x_init`` as a batch of K
    independent samples and gates convergence per sample: each sample's
    residual/iteration-count/history evolves on its own, converged samples
    freeze (their updates become no-ops via ``jnp.where``, so the result is
    bit-identical to K independent runs), and the loop exits when every
    sample converged or at ``max_iters``.  Under ``fixed_iters`` no freezing
    happens (all samples run the full budget, matching K independent
    fixed-budget runs) but the carries stay per-sample.
    """
    cb = constrain if constrain is not None else (lambda t: t)
    # Early-exit per-sample mode freezes converged samples; fixed-iters mode
    # never gates updates (scan runs the full budget for every sample).
    gate = batched and not fixed_iters

    x_tail = coarse_init_sweep(G, x_init, starts, unroll=scan_unroll)
    # prev_coarse_i == G(x_i^0) == x_{i+1}^0 at init; y_prev's init value is
    # never read (straggler substitution is gated on p > 0).
    y_prev0 = x_tail if carry_fine_results else jnp.zeros((), x_tail.dtype)
    if batched:
        k = x_init.shape[0]
        delta0 = jnp.full((k,), jnp.inf, jnp.float32)
        hist0 = jnp.full((max_iters, k), jnp.inf, jnp.float32)
        iters0 = jnp.zeros((k,), jnp.int32)
        active0 = jnp.ones((k,), bool)
    else:
        delta0 = jnp.float32(jnp.inf)
        hist0 = jnp.full((max_iters,), jnp.inf, jnp.float32)
        iters0 = jnp.int32(0)
        active0 = jnp.asarray(True)
    init = RefineState(jnp.int32(0), x_tail, x_tail, y_prev0,
                       delta0, hist0, iters0, active0)

    def cond(c: RefineState):
        return jnp.logical_and(c.p < max_iters, jnp.any(c.active))

    def body(c: RefineState) -> RefineState:
        x_heads = jnp.concatenate([x_init[None], c.x_tail[:-1]], axis=0)
        # ---- fine solves (Alg 1, lines 7-8) — sampler-specific ----
        y = fine_fn(x_heads, c.p, c.y_prev)
        # ---- sequential coarse sweep + predictor-corrector (lines 9-12) --
        new_tail, cur_all = corrector_sweep(G, x_init, y, c.prev_coarse,
                                            starts, use_fused=use_fused_update,
                                            unroll=scan_unroll)
        new_tail = cb(new_tail)
        cur_all = cb(cur_all)
        if gate:
            # converged samples' fine solves are no-ops: freeze their
            # trajectory and coarse state so they stay bit-identical to an
            # independent run that exited at their convergence iteration
            m = _batch_mask(c.active, new_tail)
            new_tail = jnp.where(m, new_tail, c.x_tail)
            cur_all = jnp.where(m, cur_all, c.prev_coarse)

        resid = convergence_norm(new_tail[-1] - c.x_tail[-1], norm,
                                 batched=batched)
        if gate:
            delta = jnp.where(c.active, resid, c.delta)
            history = c.history.at[c.p].set(
                jnp.where(c.active, resid, c.history[c.p]))
            iters = c.iters + c.active.astype(jnp.int32)
        else:
            delta = resid
            history = c.history.at[c.p].set(resid)
            iters = c.iters + 1
        active = jnp.logical_and(c.active, still_refining(delta, tol))
        if carry_fine_results:
            y_keep = jnp.where(_batch_mask(c.active, y), y, c.y_prev) \
                if gate else y
        else:
            y_keep = c.y_prev
        return RefineState(c.p + 1, new_tail, cur_all, y_keep, delta, history,
                           iters, active)

    if fixed_iters:
        out, _ = jax.lax.scan(lambda c, _: (body(c), None), init, None,
                              length=max_iters, unroll=scan_unroll)
        return out
    return jax.lax.while_loop(cond, body, init)


def assemble_result(sample: jnp.ndarray, iterations: jnp.ndarray,
                    final_delta: jnp.ndarray, delta_history: jnp.ndarray,
                    trajectory: Optional[jnp.ndarray] = None) -> SRDSResult:
    """The one place an ``SRDSResult`` is put together from loop outputs."""
    return SRDSResult(sample=sample, iterations=iterations,
                      final_delta=final_delta, delta_history=delta_history,
                      trajectory=trajectory)


def result_from_state(state: RefineState,
                      trajectory: Optional[jnp.ndarray] = None) -> SRDSResult:
    return assemble_result(state.x_tail[-1], state.iters, state.delta,
                           state.history, trajectory)
