"""Unified Parareal engine — the single home of SRDS's refinement math.

Every SRDS sampler in this repo (sequential single-program
:func:`repro.core.parareal.srds_sample`, block-sharded
:func:`repro.core.pipelined.srds_sharded_local`, wavefront-pipelined
:func:`repro.core.pipelined.srds_pipelined_local`) consumes this module for:

  * the coarse initialization sweep (Alg 1, lines 1-4),
  * the predictor-corrector update ``y + G_cur - G_prev`` (line 11),
  * the sequential corrector sweep (lines 9-12),
  * convergence gating on the final-sample residual,
  * ``SRDSResult`` assembly.

The three samplers differ only in *where the fine solves run* (vmapped in
one program, locally per shard with an all_gather, or wavefront-staggered)
— that part is injected into :func:`run_parareal` as ``fine_fn`` — so the
algorithm itself can no longer drift between implementations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SRDSConfig:
    """Knobs for the SRDS sampler.

    num_blocks:   B — the coarse discretization (None -> ceil(sqrt(N)),
                  Prop 4's optimum).
    tol:          τ — convergence threshold on the mean-abs change of the
                  *final* sample between consecutive refinements.
    max_iters:    refinement-iteration cap (None -> B; Prop 1 guarantees
                  exact convergence by then).
    norm:         'l1_mean' (paper) or 'l2_mean' or 'linf'.
    use_fused_update: route the predictor-corrector update + residual
                  accumulation through the Pallas kernel.  ``None`` (the
                  default) resolves at run time to "on where supported":
                  compiled kernels on TPU/GPU, plain jnp elsewhere
                  (interpreted Pallas would dominate CPU runtime) — see
                  :func:`repro.kernels.ops.fused_default`.
    truncate:     converged-prefix truncation: refinement ``p`` runs its
                  fine solves and corrector sweep only on the active block
                  suffix ``[frontier, B)`` where ``frontier =
                  prefix_frontier(p)`` is the provably *bitwise-frozen*
                  prefix (classical Parareal exactness, lagged one
                  refinement — see :func:`prefix_frontier`), advancing by
                  one block per refinement.  The refinement loop unrolls
                  over ``p`` so
                  each iteration's suffix shape is static — strictly less
                  work per iteration, all on device.  Results are
                  bit-identical to the untruncated loop (same sample,
                  iterations, delta_history) for elementwise-deterministic
                  models; matmul denoisers match to dtype roundoff because
                  the shrinking fine-solve batch hits shape-dependent gemm
                  kernels (the same caveat as ``per_sample``).
                  Incompatible with ``block_sharding`` and straggler reuse
                  (both keep the while_loop path).  Shorthand for
                  ``window=repro.core.window.ExactPrefix()``.
    window:       a :class:`repro.core.window.FrontierPolicy` controlling
                  the active refinement window explicitly — the seam all
                  frontier rules live behind.  ``None`` resolves from
                  ``truncate``: ``ExactPrefix()`` (bit-exact, the above)
                  when True, ``FixedBudget()`` (no truncation) when
                  False.  ``ResidualWindow(window_tol=...)`` enables the
                  opt-in *approximate* residual-driven window: blocks
                  whose per-block residual passed ``window_tol`` freeze
                  even before exactness is provable (error knob and
                  guarantees in :mod:`repro.core.window`).
    per_sample:   gate convergence independently per sample over the leading
                  batch axis of ``x_init`` (shape ``(K, ...)``): the residual,
                  iteration counter and delta history become per-sample
                  ``(K,)``-shaped, converged samples freeze (their updates
                  are masked to no-ops) and the loop exits only when every
                  sample converged or ``max_iters`` hits.  Off (the default):
                  a single joint-norm residual gates the whole batch.
    accel:        a :class:`repro.core.accel.Accelerator` mixing the
                  refinement fixed point (Anderson/triangular
                  acceleration — fewer iterations to the same tolerance,
                  zero extra model evals per iteration).  ``None`` (the
                  default) resolves to ``NoAccel``: no mixing, no extra
                  loop carry, bit-identical to the pre-seam engine.
                  Accelerated modes are *approximate* in the window
                  sense: converged samples match the serial solve to
                  tolerance, with the error measured and CI-asserted
                  (see :mod:`repro.core.accel`).
    """

    num_blocks: Optional[int] = None
    tol: float = 1e-3
    max_iters: Optional[int] = None
    norm: str = "l1_mean"
    use_fused_update: Optional[bool] = None
    per_sample: bool = False
    truncate: bool = False
    # Frontier policy (repro.core.window.FrontierPolicy); None resolves
    # from `truncate`.  ResidualWindow(...) opts into the approximate
    # residual-driven sliding window.
    window: Optional[object] = None
    # Distribution hook: NamedSharding whose first axis is the parareal
    # block dim — constrains the trajectory/fine-solve tensors so GSPMD
    # maps blocks onto a mesh axis (time-parallelism on `data`).
    block_sharding: Optional[object] = None
    # Run exactly max_iters refinements under lax.scan instead of the
    # early-exit while_loop (analysis mode: cost_analysis counts while-loop
    # bodies once; also useful for fixed-budget sampling).
    fixed_iters: bool = False
    scan_unroll: bool = False
    # Fixed-point accelerator (repro.core.accel.Accelerator); None resolves
    # to NoAccel.  AndersonAccel(depth=m) / TriangularAccel() opt into
    # approximate iteration-count acceleration.
    accel: Optional[object] = None


class SRDSResult(NamedTuple):
    """Per-sample fields are scalar/(max_iters,)-shaped in joint-gating mode
    and gain a trailing batch axis of size K under per-sample gating."""
    sample: jnp.ndarray
    iterations: jnp.ndarray        # int32 () or (K,) — refinements actually run
    final_delta: jnp.ndarray       # f32 () or (K,) — last convergence residual
    delta_history: jnp.ndarray     # f32 (max_iters,) or (max_iters, K),
                                   # +inf beyond `iterations`
    trajectory: Optional[jnp.ndarray] = None  # (B+1, ...) final running traj
    window_history: Optional[jnp.ndarray] = None  # int32 (max_iters,[ K]) —
                                   # window lower bound each refinement ran
                                   # with (-1 beyond `iterations`); only
                                   # populated by residual-window policies


def _leading_axes_norm(diff: jnp.ndarray, kind: str,
                       lead: int) -> jnp.ndarray:
    """The one norm-kind dispatch: reduce every axis past the first
    ``lead``, preserving those (the ``batch_dims`` idiom the fused
    kernels use) — ``lead=0`` is a full reduction."""
    diff = diff.astype(jnp.float32)
    axes = tuple(range(lead, diff.ndim)) if lead else None
    if kind == "l1_mean":
        return jnp.mean(jnp.abs(diff), axis=axes)
    if kind == "l2_mean":
        return jnp.sqrt(jnp.mean(diff * diff, axis=axes))
    if kind == "linf":
        return jnp.max(jnp.abs(diff), axis=axes)
    raise ValueError(f"unknown norm {kind!r}")


def convergence_norm(diff: jnp.ndarray, kind: str,
                     batched: bool = False) -> jnp.ndarray:
    """Residual norm used for the paper's convergence criterion.

    With ``batched=True`` the reduction skips the leading batch axis and
    returns one residual per sample: ``(K, ...) -> (K,)``.
    """
    return _leading_axes_norm(diff, kind, 1 if batched else 0)


def blockwise_norm(diff: jnp.ndarray, kind: str,
                   batched: bool = False) -> jnp.ndarray:
    """Per-block residual norms over a block-stacked difference tensor:
    ``(B, ...) -> (B,)``, or ``(B, K, ...) -> (B, K)`` with ``batched``
    (one norm per block per sample).  Same norm kinds as
    :func:`convergence_norm` — one shared dispatch, so the convergence
    gate and the window-advance residuals can never disagree on a norm;
    residual-window policies consume these to advance the frontier past
    blocks whose residual passed tolerance.
    """
    return _leading_axes_norm(diff, kind, 2 if batched else 1)


def still_refining(delta: jnp.ndarray, tol) -> jnp.ndarray:
    """Convergence gate: keep iterating while the residual is >= τ.

    Elementwise — ``delta`` and ``tol`` may be scalars or per-sample ``(K,)``
    vectors (mixed-tolerance micro-batches pass a tol vector).
    """
    return delta >= tol


def has_converged(delta: jnp.ndarray, tol) -> jnp.ndarray:
    """The complementary gate (used by the wavefront's done-flag psum)."""
    return delta < tol


def resolve_blocks(n_steps: int, num_blocks: Optional[int]) -> Tuple[int, int]:
    """Pick (B, S): B blocks of S fine steps, B*S == N.

    Blocks are uniform — lockstep SPMD requires every block to run the same
    number of fine steps, so B must divide N exactly (the paper instead
    allows a ragged last block).  An explicit ``num_blocks`` that does not
    divide ``n_steps`` is an error.  With ``num_blocks=None``, B is
    ceil(sqrt(N)) snapped to the nearest *nontrivial* divisor of N (1 < B < N,
    preserving Prop 4's optimum for the perfect-square Ns of the paper's
    experiments); if none exists (prime N) this raises rather than silently
    degrading to the fully-serial B=1.
    """
    if num_blocks is not None:
        if not 1 <= num_blocks <= n_steps or n_steps % num_blocks != 0:
            raise ValueError(
                f"num_blocks={num_blocks} does not divide N={n_steps}: SRDS "
                f"blocks are uniform (B*S == N). Pick a divisor of N or pass "
                f"num_blocks=None to auto-select one.")
        return num_blocks, n_steps // num_blocks
    target = max(1, int(round(math.sqrt(n_steps))))
    divs = [d for d in range(2, n_steps) if n_steps % d == 0]
    if not divs:
        raise ValueError(
            f"N={n_steps} has no nontrivial divisor (prime): every block "
            f"split degenerates to the serial solve. Choose a composite "
            f"number of steps, or pass num_blocks={n_steps} or 1 explicitly "
            f"to accept a degenerate split.")
    num_blocks = min(divs, key=lambda d: abs(d - target))
    return num_blocks, n_steps // num_blocks


class IterationCost(NamedTuple):
    """Per-lane model-eval cost of one SRDS run, split by phase.

    ``init_evals`` is the sequential coarse sweep (B coarse steps);
    ``refine_evals`` is one *untruncated* Parareal refinement (B*S parallel
    fine steps + the B-step sequential corrector sweep).  All counts are in
    *model evals* — the paper's hardware-independent unit — already scaled
    by the solver's evals-per-step.  ``num_blocks``/``fine_steps``/
    ``evals_per_step`` carry the decomposition so truncated refinements
    (:meth:`refine_evals_at`) are derivable from the same record.
    """
    init_evals: int
    refine_evals: int
    num_blocks: int = 0
    fine_steps: int = 0
    evals_per_step: int = 1

    def refine_evals_window(self, lo: int, hi: Optional[int] = None) -> int:
        """Evals of one refinement restricted to the block window
        ``[lo, hi)`` (fine solves + corrector sweep on the live blocks
        only).  ``hi=None`` means ``B`` — the common suffix case; the
        final in-window block never retires, so the window floors at one
        live block.  This is the unit every windowed consumer prices
        with: billing, ``predict_completion``, the CostAware scheduler
        and the benches all derive from it."""
        if not self.num_blocks:            # legacy record: no decomposition
            return self.refine_evals
        hi = self.num_blocks if hi is None else min(int(hi), self.num_blocks)
        live = hi - min(int(lo), hi - 1)
        return live * (self.fine_steps + 1) * self.evals_per_step

    def refine_evals_at(self, frontier: int) -> int:
        """Suffix shorthand: ``refine_evals_window(frontier, B)``."""
        return self.refine_evals_window(frontier)


def iteration_cost(num_steps: int, num_blocks: Optional[int] = None,
                   evals_per_step: int = 1) -> IterationCost:
    """The engine's eval accounting, exported for cost-model consumers.

    Both the serving layer's per-request ``model_evals`` charge and the
    scheduler's completion-time predictor derive from this one function, so
    admission decisions and billing can never disagree with what the
    refinement loop actually executes.
    """
    B, S = resolve_blocks(num_steps, num_blocks)
    return IterationCost(init_evals=B * evals_per_step,
                         refine_evals=(B * S + B) * evals_per_step,
                         num_blocks=B, fine_steps=S,
                         evals_per_step=evals_per_step)


def predicted_evals(cost: IterationCost, iterations: Union[int, float]):
    """Total per-lane evals for an *untruncated* run of ``iterations``
    refinements (the pre-truncation hot loop; kept for baselines and
    ``truncate=False`` engines).  Linear, so float iteration estimates
    (the EMA's) extend continuously."""
    return cost.init_evals + iterations * cost.refine_evals


def prefix_frontier(completed: int) -> int:
    """The provably *bitwise-frozen* prefix after ``completed`` refinements.

    Classical Parareal exactness makes block ``i`` mathematically exact
    after ``i`` refinements, but bitwise stability — what truncation must
    preserve — arrives one refinement later: a block's first value mixes a
    coarse term from the *init* sweep with one from the *corrector* sweep
    (two separately compiled scans whose last bits may differ), so only
    from its second recomputation onward are both coarse terms the same
    compiled computation on identical inputs, making the update a bitwise
    fixed point.  Hence the frontier advances by exactly one block per
    refinement, one refinement behind the exactness bound.
    """
    return max(int(completed) - 1, 0)


def truncated_evals(cost: IterationCost, iterations: Union[int, float]):
    """Total per-lane evals for a prefix-truncated run: refinement ``p``
    (0-indexed) costs ``cost.refine_evals_at(prefix_frontier(p))`` because
    its fine solves and corrector sweep cover only the non-frozen suffix —
    the same frontier schedule :func:`run_parareal` executes, so billing
    and benchmarks can never disagree with the loop.  A float
    ``iterations`` (e.g. an EMA estimate) is extended continuously: the
    fractional part is charged at the next refinement's truncated rate.
    """
    k = int(iterations)
    total = cost.init_evals + sum(cost.refine_evals_at(prefix_frontier(p))
                                  for p in range(k))
    frac = float(iterations) - k
    if frac > 0.0:
        return total + frac * cost.refine_evals_at(prefix_frontier(k))
    return total


def windowed_evals(cost: IterationCost, lo_schedule):
    """Total per-lane evals for a run whose refinement ``p`` executed the
    window ``[lo_schedule[p], B)`` — the *realized* schedule of a
    residual-window run (e.g. ``SRDSResult.window_history``), as opposed
    to :func:`truncated_evals`'s provable ExactPrefix schedule.  Entries
    ``< 0`` mark refinements that never ran (the history's fill value)
    and are skipped.  A per-sample ``(max_iters, K)`` history (the
    ``per_sample`` engines') returns a ``(K,)`` array of per-sample
    totals."""
    los = np.asarray(lo_schedule)
    if los.ndim == 2:
        return np.asarray([windowed_evals(cost, los[:, s])
                           for s in range(los.shape[1])])
    total = cost.init_evals
    for lo in los:
        lo = int(lo)
        if lo >= 0:
            total += cost.refine_evals_window(lo)
    return total


def resolve_fused(flag: Optional[bool]) -> bool:
    """Resolve a ``use_fused_*`` tri-state: an explicit bool wins; ``None``
    means "on where supported" (compiled Pallas on TPU and GPU — interpreted
    Pallas elsewhere would dominate runtime, so e.g. CPU stays on the jnp
    path)."""
    if flag is None:
        from repro.kernels import ops as kops
        return kops.fused_default()
    return bool(flag)


def parareal_update(y, g_cur, g_prev, use_fused: Optional[bool] = False):
    """Predictor-corrector update (Alg 1, line 11): ``y + G_cur - G_prev``."""
    if resolve_fused(use_fused):
        from repro.kernels import ops as kops
        out, _ = kops.parareal_update(y, g_cur, g_prev)
        return out
    return y + g_cur - g_prev


def coarse_init_sweep(G, x_init: jnp.ndarray, starts: jnp.ndarray,
                      unroll: bool = False) -> jnp.ndarray:
    """Sequential coarse sweep producing the initial trajectory tail x^0.

    Returns the (B, ...) stack ``[x_1^0, ..., x_B^0]`` where
    ``x_{i+1}^0 = G(x_i^0)`` — which doubles as ``prev_coarse`` at init.
    """
    def body(x, i0):
        g = G(x, i0)
        return g, g

    _, x_tail = jax.lax.scan(body, x_init, starts, unroll=unroll)
    return x_tail


def corrector_sweep(G, x_init: jnp.ndarray, y: jnp.ndarray,
                    prev_coarse: jnp.ndarray, starts: jnp.ndarray, *,
                    use_fused: bool = False, unroll: bool = False,
                    residual_from: Optional[jnp.ndarray] = None,
                    batched: bool = False,
                    frozen: Optional[jnp.ndarray] = None):
    """Sequential coarse sweep + predictor-corrector (Alg 1, lines 9-12).

    Returns ``(new_tail, cur_all)``: the refined trajectory tail and the
    coarse results ``G(x_i^p)`` that become next iteration's prev_coarse.

    ``residual_from`` (the previous trajectory tail, same shape as ``y``)
    switches on the in-sweep residual feed: each block's raw L1 sum
    ``sum|x_new - x_old|`` is accumulated in the same pass as the update
    (the Pallas kernel's per-tile partials when ``use_fused``, a plain
    per-block reduction otherwise) — no second full-tensor pass — and the
    sweep returns a third output, the per-block raw L1 sums ``(B,)`` (or
    ``(B, K)`` per sample with ``batched``).  Callers divide by the
    per-sample element count to obtain ``l1_mean`` residuals; the final
    entry is the convergence residual's raw sum.

    ``frozen`` (per-block bool, ``(B,)`` or ``(B, K)`` per sample with
    ``batched``; requires ``residual_from`` for the old values) is the
    residual-window mask: a frozen block's update is discarded — its
    trajectory value stays ``residual_from[i]``, its coarse result stays
    ``prev_coarse[i]``, its residual reports 0 — and, because the scan
    carry takes the frozen (old) value, downstream blocks see exactly the
    boundary a sweep that *started* past the frozen run would have seen.
    This is the masked equivalent of the serving engine's physical window
    skip, so both drivers realize the same math.
    """
    if frozen is not None and residual_from is None:
        raise ValueError("frozen blocks need residual_from (the previous "
                         "trajectory tail) to hold their old values")
    if residual_from is not None:
        if use_fused:
            from repro.kernels import ops as kops

        def sweep_r(x_cur, inp):
            y_i, prev_i, old_i, i0 = inp[:4]
            cur = G(x_cur, i0)
            if use_fused:
                x_next, r = kops.parareal_update_residual(
                    y_i, cur, prev_i, old_i, batched=batched)
            else:
                x_next = y_i + cur - prev_i
                d = (x_next - old_i).astype(jnp.float32)
                r = jnp.sum(jnp.abs(d),
                            axis=tuple(range(1, d.ndim)) if batched else None)
            if frozen is not None:
                fz_i = inp[4]
                m = fz_i.reshape(fz_i.shape + (1,) * (x_next.ndim - fz_i.ndim))
                x_next = jnp.where(m, old_i, x_next)
                cur = jnp.where(m, prev_i, cur)
                r = jnp.where(fz_i, jnp.zeros_like(r), r)
            return x_next, (x_next, cur, r)

        xs = (y, prev_coarse, residual_from, starts)
        if frozen is not None:
            xs = xs + (frozen,)
        _, (new_tail, cur_all, r_all) = jax.lax.scan(sweep_r, x_init, xs,
                                                     unroll=unroll)
        return new_tail, cur_all, r_all

    def sweep(x_cur, inp):
        y_i, prev_i, i0 = inp
        cur = G(x_cur, i0)
        x_next = parareal_update(y_i, cur, prev_i, use_fused)
        return x_next, (x_next, cur)

    _, (new_tail, cur_all) = jax.lax.scan(sweep, x_init,
                                          (y, prev_coarse, starts),
                                          unroll=unroll)
    return new_tail, cur_all


def suffix_refinement(G, y, x_init: jnp.ndarray, x_tail: jnp.ndarray,
                      prev_coarse: jnp.ndarray, starts: jnp.ndarray,
                      frontier: int, *, use_fused: bool = False,
                      norm: str = "l1_mean", batched: bool = False,
                      unroll: bool = False, window_lo=None,
                      block_resids: bool = False):
    """One predictor-corrector refinement truncated to ``[frontier, B)``.

    The single implementation of the sliding-window refinement body,
    shared by :func:`run_parareal`'s unrolled loop and the serving
    engine's per-frontier step programs — the frontier plumbing (suffix
    sweep resuming from the last frozen boundary, prefix re-concatenation,
    fused-vs-plain residual dispatch, residual-window freezing) can never
    drift between the two.

    ``y`` holds the fine-solve results for the suffix heads (the
    sampler-specific part stays with the caller).  Returns ``(new_tail,
    cur_all, resid)`` where ``resid`` is the final-block convergence
    residual in ``norm`` (scalar, or per-sample ``(K,)`` with
    ``batched``), computed *before* any caller-side freezing — callers
    that mask converged lanes discard those entries, and active lanes'
    values are unaffected by the mask.  With the fused path and
    ``l1_mean`` the residual comes from the update kernel's per-tile L1
    partials (no second full-tensor pass).

    ``window_lo`` (traced int, scalar or per-sample ``(K,)`` with
    ``batched``) enables the residual-window path: suffix blocks with
    absolute index ``< window_lo`` are *frozen* — their update is masked
    to a no-op inside the sweep (see :func:`corrector_sweep`), exactly
    mirroring the serving engine's physical window skip.  Implies
    ``block_resids``.  With ``block_resids`` (or ``window_lo``) the
    return grows a fourth element: the per-block residual norms of the
    suffix, ``(B - frontier,)`` or ``(B - frontier, K)``, frozen blocks
    reporting 0 — the feed for ``FrontierPolicy.advance``.
    """
    f = int(frontier)
    windowed = window_lo is not None
    block_resids = block_resids or windowed
    fused_resid = use_fused and norm == "l1_mean"
    # the sweep resumes from the last frozen boundary: the prefix's
    # recomputation is a bitwise fixed point, so skipping it changes
    # nothing downstream
    x_carry = x_init if f == 0 else x_tail[f - 1]
    old_sfx = x_tail[f:] if f else x_tail
    prev_sfx = prev_coarse[f:] if f else prev_coarse
    st = starts[f:] if f else starts
    n_per = x_init[0].size if batched else x_init.size
    n_sfx = old_sfx.shape[0]
    block_resid = None
    if windowed:
        # frozen mask per suffix block (trailing sample axis rides along
        # when window_lo is per-sample): absolute block index < lo
        idx = f + jnp.arange(n_sfx, dtype=jnp.int32)
        lo = jnp.asarray(window_lo, jnp.int32)
        fz = idx.reshape((n_sfx,) + (1,) * lo.ndim) < lo
        if norm == "l1_mean":
            # in-sweep residual feed (fused kernel partials or the plain
            # per-block reduction) — no second full-tensor pass
            new_sfx, cur_sfx, r_all = corrector_sweep(
                G, x_carry, y, prev_sfx, st, use_fused=use_fused,
                unroll=unroll, residual_from=old_sfx, batched=batched,
                frozen=fz)
            block_resid = (r_all / float(n_per)).astype(jnp.float32)
        else:
            new_sfx, cur_sfx, _ = corrector_sweep(
                G, x_carry, y, prev_sfx, st, use_fused=use_fused,
                unroll=unroll, residual_from=old_sfx, batched=batched,
                frozen=fz)
            # frozen blocks hold their old value -> their norm is 0
            block_resid = blockwise_norm(new_sfx - old_sfx, norm,
                                         batched=batched)
        resid = block_resid[-1]
    elif fused_resid or block_resids:
        new_sfx, cur_sfx, r_all = corrector_sweep(
            G, x_carry, y, prev_sfx, st, use_fused=use_fused, unroll=unroll,
            residual_from=old_sfx, batched=batched)
        if norm == "l1_mean":
            if block_resids:
                block_resid = (r_all / float(n_per)).astype(jnp.float32)
                resid = block_resid[-1]
            else:
                resid = (r_all[-1] / float(n_per)).astype(jnp.float32)
        else:
            block_resid = blockwise_norm(new_sfx - old_sfx, norm,
                                         batched=batched)
            resid = block_resid[-1]
    else:
        new_sfx, cur_sfx = corrector_sweep(G, x_carry, y, prev_sfx, st,
                                           use_fused=use_fused,
                                           unroll=unroll)
        resid = None
    if f:
        new_tail = jnp.concatenate([x_tail[:f], new_sfx], axis=0)
        cur_all = jnp.concatenate([prev_coarse[:f], cur_sfx], axis=0)
    else:
        new_tail, cur_all = new_sfx, cur_sfx
    if resid is None:
        resid = convergence_norm(new_tail[-1] - x_tail[-1], norm,
                                 batched=batched)
    if block_resids:
        return new_tail, cur_all, resid, block_resid
    return new_tail, cur_all, resid


class RefineState(NamedTuple):
    """Carry of the refinement loop (shared by all non-wavefront samplers).

    Under per-sample gating (``batched=True``), ``delta``/``iters``/``active``
    are ``(K,)`` vectors over the leading batch axis and ``history`` is
    ``(max_iters, K)``; otherwise they are the scalar joint-gating carries.
    """
    p: jnp.ndarray             # refinement counter (scalar int32, lockstep)
    x_tail: jnp.ndarray        # (B, ...) running trajectory x_1..x_B
    prev_coarse: jnp.ndarray   # (B, ...) G(x_i^{p-1}) for each block
    y_prev: jnp.ndarray        # (B, ...) last fine results when
                               # carry_fine_results (straggler reuse),
                               # else a scalar placeholder
    delta: jnp.ndarray         # last convergence residual, f32 () or (K,)
    history: jnp.ndarray       # residual history, f32 (max_iters,[ K])
    iters: jnp.ndarray         # refinements applied, int32 () or (K,)
    active: jnp.ndarray        # frozen-when-converged mask, bool () or (K,)
    # --- residual-window carries (None unless the frontier policy needs
    # block residuals — see repro.core.window; None is an empty pytree, so
    # exact-policy loop carries stay byte-identical to the pre-window ones)
    block_resid: Optional[jnp.ndarray] = None
                               # per-block residual norms, f32 (B,[ K])
    window_lo: Optional[jnp.ndarray] = None
                               # window lower bound, int32 () or (K,)
    lo_hist: Optional[jnp.ndarray] = None
                               # window lower bound used by refinement p,
                               # int32 (max_iters,[ K]), -1 beyond iters
    # --- fixed-point-acceleration carry (None unless the accelerator
    # mixes — see repro.core.accel; None is an empty pytree, so
    # unaccelerated loop carries stay byte-identical to the pre-seam ones)
    accel: Optional[object] = None
                               # repro.core.accel.AccelState ring buffers


FineFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def vmap_fine_fn(F, starts: jnp.ndarray, constrain=None) -> FineFn:
    """The single-program :data:`FineFn`: fine solves batched over the
    block dim with ``vmap``, suffix-aware under truncation.

    ``F(x, i0)`` is one block's fine solve (typically ``solve(...)`` over a
    :class:`repro.core.denoiser.Denoiser`); ``starts`` the ``(B,)`` block
    start indices.  Under truncation the heads are the active suffix — the
    static offset is recovered from the stack length.  ``constrain``
    (optional) re-applies a block-dim sharding constraint around the vmap.
    Shared by ``srds_sample`` and the serve engine's meshless fine path.
    """
    B = starts.shape[0]
    cb = constrain if constrain is not None else (lambda t: t)

    def fine_fn(x_heads, p, y_prev):
        f = B - x_heads.shape[0]
        st = starts[f:] if f else starts
        return cb(jax.vmap(lambda xi, i0: F(xi, i0))(cb(x_heads), st))

    return fine_fn


def _batch_mask(mask: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (K,) sample mask against a (B, K, ...) trajectory tensor."""
    return mask.reshape((1,) + mask.shape + (1,) * (t.ndim - 2))


def run_parareal(G, fine_fn: FineFn, x_init: jnp.ndarray,
                 starts: jnp.ndarray, *, tol, max_iters: int,
                 norm: str = "l1_mean",
                 use_fused_update: Optional[bool] = None,
                 fixed_iters: bool = False, scan_unroll: bool = False,
                 constrain=None, carry_fine_results: bool = False,
                 batched: bool = False, truncate: bool = False,
                 window=None, accel=None) -> RefineState:
    """The complete Parareal refinement loop (Alg 1 minus the fine solves).

    ``fine_fn(x_heads, p, y_prev) -> y`` computes the fine-solve results
    for block heads ``x_heads`` at refinement ``p`` — this is the only
    sampler-specific part (vmap in one program; local vmap + all_gather +
    straggler masking under shard_map).  Untruncated, ``x_heads`` is the
    full ``(B, ...)`` stack ``[x_0, ..., x_{B-1}]``; under ``truncate`` it
    is the active suffix ``[x_frontier, ..., x_{B-1}]`` — samplers recover
    the static offset as ``B - x_heads.shape[0]``.
    ``tol`` may be a python float, a traced scalar, or — with ``batched`` —
    a per-sample ``(K,)`` vector (mixed-tolerance micro-batches).
    ``constrain`` (optional) re-applies a block-dim sharding constraint to
    the trajectory tensors each iteration (GSPMD time-parallel path).
    ``carry_fine_results`` keeps the previous iteration's (B, ...) fine
    results in the loop carry, handed to ``fine_fn`` as ``y_prev`` (needed
    for straggler reuse); off by default so samplers that never read it
    don't pay an extra trajectory-sized buffer of loop state.
    ``batched`` treats the leading axis of ``x_init`` as a batch of K
    independent samples and gates convergence per sample: each sample's
    residual/iteration-count/history evolves on its own, converged samples
    freeze (their updates become no-ops via ``jnp.where``, so the result is
    bit-identical to K independent runs), and the loop exits when every
    sample converged or at ``max_iters``.  Under ``fixed_iters`` no freezing
    happens (all samples run the full budget, matching K independent
    fixed-budget runs) but the carries stay per-sample.

    ``truncate`` switches the loop to converged-prefix truncation (see
    :class:`SRDSConfig`): the loop unrolls over ``p`` so refinement ``p``
    statically restricts its fine solves and corrector sweep to the suffix
    ``[prefix_frontier(p), B)`` — the frozen prefix's recomputation is a
    bitwise fixed point (see :func:`prefix_frontier`), so skipping it is a
    no-op.
    Early exit is preserved via ``lax.cond`` per unrolled step (the skipped
    branch is genuinely not executed), so ``iterations``/``delta_history``
    match the while_loop bit for bit.  Incompatible with ``constrain`` and
    ``carry_fine_results``.

    ``window`` is the generalization: a
    :class:`repro.core.window.FrontierPolicy` controlling the active
    refinement window (``truncate`` is shorthand for ``ExactPrefix``; see
    :func:`repro.core.window.resolve_policy`).  A residual-driven policy
    (``ResidualWindow``) keeps the unrolled static suffix of the provable
    frontier and *additionally* freezes blocks the policy advanced past,
    by masking inside the sweep — the carried per-block residuals, window
    bound and per-refinement window history live in the returned state's
    ``block_resid`` / ``window_lo`` / ``lo_hist`` fields (None for
    non-residual policies).

    ``accel`` is a :class:`repro.core.accel.Accelerator` mixing the
    refinement fixed point: after each refinement's corrector sweep (and
    convergence-gate masking) the joint iterate ``(x_tail, prev_coarse)``
    is extrapolated over the accelerator's ring-buffer history — fewer
    iterations to tolerance, zero extra model evals.  The convergence
    residual is recomputed from the *mixed* state (the gate must see what
    is committed) and the live-window mask keeps frozen blocks bitwise
    untouched.  ``None`` resolves to ``NoAccel`` (no mixing, no extra
    carry — bit-identical).  Incompatible with ``carry_fine_results``
    (stale fine results are not iterates of the mixed sequence) and —
    unless the accelerator is ``prefix_exact`` (``TriangularAccel``) —
    with truncating frontier policies, whose provable-prefix schedule is
    a theorem about the plain iteration only.
    """
    from .accel import resolve_accel
    from .window import resolve_policy
    policy = resolve_policy(window, truncate)
    acc = resolve_accel(accel)
    accel_on = acc.accelerates
    if accel_on and carry_fine_results:
        raise ValueError("an accelerating Accelerator is incompatible with "
                         "straggler reuse (carry_fine_results): stale fine "
                         "results are not iterates of the mixed sequence.")
    if accel_on and policy.truncates and not acc.prefix_exact:
        # truncating policies freeze blocks on the provable serial-prefix
        # schedule ("block i is exact after i+1 refinements") — a theorem
        # about the PLAIN iteration that joint mixing invalidates, so the
        # frozen prefix would pin not-yet-converged mixed values and the
        # committed trajectory diverges.  TriangularAccel restores the
        # invariant by construction.
        raise ValueError(
            f"{type(acc).__name__} does not preserve the serial-prefix "
            f"invariant that truncating frontier policies "
            f"({type(policy).__name__}) rely on; use TriangularAccel "
            f"(prefix-exact mixing), or disable truncation "
            f"(truncate=False / window=FixedBudget()).")
    truncate = policy.truncates
    windowed = policy.needs_block_residuals
    if truncate and constrain is not None:
        raise ValueError("truncate is incompatible with a block-sharding "
                         "constraint (the GSPMD path keeps full-width "
                         "trajectory tensors); drop one of the two.")
    if truncate and carry_fine_results:
        raise ValueError("truncate is incompatible with straggler reuse "
                         "(carry_fine_results): stale fine results are "
                         "indexed on the full block axis.")
    cb = constrain if constrain is not None else (lambda t: t)
    use_fused = resolve_fused(use_fused_update)
    B = starts.shape[0]
    # Early-exit per-sample mode freezes converged samples; fixed-iters mode
    # never gates updates (scan runs the full budget for every sample).
    gate = batched and not fixed_iters

    x_tail = coarse_init_sweep(G, x_init, starts, unroll=scan_unroll)
    # prev_coarse_i == G(x_i^0) == x_{i+1}^0 at init; y_prev's init value is
    # never read (straggler substitution is gated on p > 0).
    y_prev0 = x_tail if carry_fine_results else jnp.zeros((), x_tail.dtype)
    if batched:
        k = x_init.shape[0]
        delta0 = jnp.full((k,), jnp.inf, jnp.float32)
        hist0 = jnp.full((max_iters, k), jnp.inf, jnp.float32)
        iters0 = jnp.zeros((k,), jnp.int32)
        active0 = jnp.ones((k,), bool)
    else:
        delta0 = jnp.float32(jnp.inf)
        hist0 = jnp.full((max_iters,), jnp.inf, jnp.float32)
        iters0 = jnp.int32(0)
        active0 = jnp.asarray(True)
    if windowed:
        kd = (x_init.shape[0],) if batched else ()
        br0 = jnp.full((B,) + kd, jnp.inf, jnp.float32)
        lo0 = jnp.zeros(kd, jnp.int32)
        loh0 = jnp.full((max_iters,) + kd, -1, jnp.int32)
    else:
        br0 = lo0 = loh0 = None
    astate0 = acc.init_state(jnp.stack([x_tail, x_tail]), max_iters,
                             batched=batched) if accel_on else None
    init = RefineState(jnp.int32(0), x_tail, x_tail, y_prev0,
                       delta0, hist0, iters0, active0, br0, lo0, loh0,
                       astate0)

    def cond(c: RefineState):
        return jnp.logical_and(c.p < max_iters, jnp.any(c.active))

    def body(c: RefineState, f: int = 0) -> RefineState:
        """One refinement; ``f`` is the static frontier (0 = untruncated)."""
        heads = jnp.concatenate([x_init[None], c.x_tail[:-1]], axis=0)
        if f:
            heads = heads[f:]
        # ---- fine solves (Alg 1, lines 7-8) — sampler-specific ----
        y = fine_fn(heads, c.p, c.y_prev)
        # ---- sequential coarse sweep + predictor-corrector (lines 9-12),
        # truncated to the suffix — the one shared implementation ----
        new_tail, cur_all, resid = suffix_refinement(
            G, y, x_init, c.x_tail, c.prev_coarse, starts, f,
            use_fused=use_fused, norm=norm, batched=batched,
            unroll=scan_unroll)
        new_tail = cb(new_tail)
        cur_all = cb(cur_all)
        if gate:
            # converged samples' fine solves are no-ops: freeze their
            # trajectory and coarse state so they stay bit-identical to an
            # independent run that exited at their convergence iteration
            # (their pre-mask resid entries are discarded just below)
            m = _batch_mask(c.active, new_tail)
            new_tail = jnp.where(m, new_tail, c.x_tail)
            cur_all = jnp.where(m, cur_all, c.prev_coarse)
        if accel_on:
            # mix the joint fixed-point iterate AFTER gate masking (frozen
            # lanes are fixed points of the mix) with the live-window mask
            # (the truncated prefix must stay bitwise untouched); the
            # convergence residual is recomputed from the committed state
            live = jnp.arange(B, dtype=jnp.int32) >= f if f else None
            z_mix, astate = acc.apply(
                c.accel, jnp.stack([c.x_tail, c.prev_coarse]),
                jnp.stack([new_tail, cur_all]), live=live, batched=batched)
            new_tail, cur_all = cb(z_mix[0]), cb(z_mix[1])
            if gate:
                new_tail = jnp.where(m, new_tail, c.x_tail)
                cur_all = jnp.where(m, cur_all, c.prev_coarse)
            resid = convergence_norm(new_tail[-1] - c.x_tail[-1], norm,
                                     batched=batched)
        else:
            astate = c.accel

        if gate:
            delta = jnp.where(c.active, resid, c.delta)
            history = c.history.at[c.p].set(
                jnp.where(c.active, resid, c.history[c.p]))
            iters = c.iters + c.active.astype(jnp.int32)
        else:
            delta = resid
            history = c.history.at[c.p].set(resid)
            iters = c.iters + 1
        active = jnp.logical_and(c.active, still_refining(delta, tol))
        if carry_fine_results:
            y_keep = jnp.where(_batch_mask(c.active, y), y, c.y_prev) \
                if gate else y
        else:
            y_keep = c.y_prev
        return RefineState(c.p + 1, new_tail, cur_all, y_keep, delta, history,
                           iters, active, c.block_resid, c.window_lo,
                           c.lo_hist, astate)

    def body_windowed(c: RefineState, f: int) -> RefineState:
        """One refinement under a residual-driven window policy: the
        compiled suffix is the static provable frontier ``f`` (same
        shapes as the exact policy), and blocks ``[f, lo)`` the policy
        advanced past are additionally frozen by masking inside the
        sweep — the approximate part, bounded by the policy's
        ``window_tol`` knob."""
        lo_eff = jnp.maximum(c.window_lo, jnp.int32(f))
        heads = jnp.concatenate([x_init[None], c.x_tail[:-1]], axis=0)
        if f:
            heads = heads[f:]
        y = fine_fn(heads, c.p, c.y_prev)
        new_tail, cur_all, resid, br_sfx = suffix_refinement(
            G, y, x_init, c.x_tail, c.prev_coarse, starts, f,
            use_fused=use_fused, norm=norm, batched=batched,
            unroll=scan_unroll, window_lo=lo_eff)
        if gate:
            m = _batch_mask(c.active, new_tail)
            new_tail = jnp.where(m, new_tail, c.x_tail)
            cur_all = jnp.where(m, cur_all, c.prev_coarse)
        if accel_on:
            # mix with the dynamic window's live mask (blocks below lo_eff
            # stay bitwise frozen through mixing), then recompute the
            # full-width per-block residuals and the convergence residual
            # from the committed (mixed) state — frozen blocks are bitwise
            # unchanged, so their recomputed residual is exactly 0
            idx = jnp.arange(B, dtype=jnp.int32)
            live = idx.reshape((B,) + (1,) * lo_eff.ndim) >= lo_eff
            z_mix, astate = acc.apply(
                c.accel, jnp.stack([c.x_tail, c.prev_coarse]),
                jnp.stack([new_tail, cur_all]), live=live, batched=batched)
            new_tail, cur_all = z_mix[0], z_mix[1]
            if gate:
                new_tail = jnp.where(m, new_tail, c.x_tail)
                cur_all = jnp.where(m, cur_all, c.prev_coarse)
            br = blockwise_norm(new_tail - c.x_tail, norm, batched=batched)
            resid = br[-1]
        else:
            astate = c.accel
            # full-width per-block residuals: the statically-skipped prefix
            # is bitwise frozen, i.e. residual 0
            if f:
                br = jnp.concatenate(
                    [jnp.zeros((f,) + br_sfx.shape[1:], br_sfx.dtype),
                     br_sfx], axis=0)
            else:
                br = br_sfx
        if gate:
            delta = jnp.where(c.active, resid, c.delta)
            history = c.history.at[c.p].set(
                jnp.where(c.active, resid, c.history[c.p]))
            iters = c.iters + c.active.astype(jnp.int32)
        else:
            delta = resid
            history = c.history.at[c.p].set(resid)
            iters = c.iters + 1
        active = jnp.logical_and(c.active, still_refining(delta, tol))
        new_lo = policy.advance(lo_eff, br, B)
        if gate:
            # converged samples' window state freezes with them
            br = jnp.where(c.active[None], br, c.block_resid)
            new_lo = jnp.where(c.active, new_lo, c.window_lo)
            lo_hist = c.lo_hist.at[c.p].set(
                jnp.where(c.active, lo_eff, c.lo_hist[c.p]))
        else:
            lo_hist = c.lo_hist.at[c.p].set(lo_eff)
        return RefineState(c.p + 1, new_tail, cur_all, c.y_prev, delta,
                           history, iters, active, br, new_lo, lo_hist,
                           astate)

    if truncate:
        # Unrolled: refinement p's suffix shape is static, so the fine
        # solves and corrector sweep genuinely shrink each iteration; the
        # cond's skipped branch is never executed, preserving the early
        # exit physically as well as in the reported iteration counts.
        state = init
        loop_body = body_windowed if windowed else body
        for p in range(max_iters):
            # the policy's static frontier (for ExactPrefix: the
            # bitwise-frozen prefix, lagging exactness by one refinement —
            # see prefix_frontier; the final block never retires)
            f = policy.static_frontier(p, B)
            step = lambda c, _f=f: loop_body(c, _f)
            if fixed_iters:
                state = step(state)
            else:
                state = jax.lax.cond(jnp.any(state.active), step,
                                     lambda c: c, state)
        return state
    if fixed_iters:
        out, _ = jax.lax.scan(lambda c, _: (body(c), None), init, None,
                              length=max_iters, unroll=scan_unroll)
        return out
    return jax.lax.while_loop(cond, body, init)


def assemble_result(sample: jnp.ndarray, iterations: jnp.ndarray,
                    final_delta: jnp.ndarray, delta_history: jnp.ndarray,
                    trajectory: Optional[jnp.ndarray] = None,
                    window_history: Optional[jnp.ndarray] = None
                    ) -> SRDSResult:
    """The one place an ``SRDSResult`` is put together from loop outputs."""
    return SRDSResult(sample=sample, iterations=iterations,
                      final_delta=final_delta, delta_history=delta_history,
                      trajectory=trajectory, window_history=window_history)


def result_from_state(state: RefineState,
                      trajectory: Optional[jnp.ndarray] = None) -> SRDSResult:
    return assemble_result(state.x_tail[-1], state.iters, state.delta,
                           state.history, trajectory,
                           window_history=state.lo_hist)
