"""Version-adaptive JAX compatibility substrate.

JAX's public surface drifts between minor releases: ``shard_map`` moved
from ``jax.experimental.shard_map`` to ``jax.shard_map`` and renamed its
replication-check kwarg ``check_rep`` -> ``check_vma``; ``jax.make_mesh``
grew an ``axis_types=`` kwarg (with ``jax.sharding.AxisType``) that older
releases reject; ``jax.tree`` aliases ``jax.tree_util``.  Hard-coding any
one release's spelling makes the repo dead on every other release.

Policy (see ROADMAP.md): **never call drifted JAX APIs directly — go
through ``repro.compat``**.  Each wrapper resolves the installed API *at
call time* by introspecting what the runtime actually provides, so a
single source tree runs unmodified on JAX 0.4.x and ≥0.5.

Wrappers use the *modern* spelling (``check_vma``, ``axis_types``) and
translate downward; new code should read like new-JAX code.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax

__all__ = [
    "shard_map",
    "make_mesh",
    "axis_type_auto",
    "default_axis_types",
    "axis_size",
    "tpu_compiler_params",
    "gpu_compiler_params",
    "cost_analysis",
    "tree",
]


# --------------------------------------------------------------------------
# shard_map: jax.shard_map (>=0.5, check_vma=) vs
#            jax.experimental.shard_map.shard_map (0.4.x, check_rep=)
# --------------------------------------------------------------------------

def _raw_shard_map():
    """The installed shard_map callable, wherever this JAX hides it."""
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl
    from jax.experimental.shard_map import shard_map as legacy
    return legacy


def _replication_check_kwarg(impl) -> Optional[str]:
    """Name of the replication-check kwarg accepted by ``impl`` (or None)."""
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):
        return None
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None, **kwargs):
    """Blessed ``shard_map``: modern kwargs, any JAX.

    ``check_vma`` is translated to whatever replication-check kwarg the
    installed implementation takes (``check_vma`` on new JAX, ``check_rep``
    on 0.4.x); pass ``None`` to use the implementation's default.  Extra
    kwargs are forwarded verbatim.
    """
    impl = _raw_shard_map()
    if check_vma is not None:
        kw = _replication_check_kwarg(impl)
        if kw is not None:
            kwargs[kw] = check_vma
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **kwargs)


# --------------------------------------------------------------------------
# mesh construction: axis_types=AxisType.Auto exists only on new JAX
# --------------------------------------------------------------------------

def axis_type_auto():
    """``jax.sharding.AxisType.Auto`` on new JAX, else ``None``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return getattr(axis_type, "Auto", None) if axis_type is not None else None


def default_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n_axes`` when the enum exists, else ``None``."""
    auto = axis_type_auto()
    return None if auto is None else (auto,) * n_axes


def _raw_make_mesh():
    return getattr(jax, "make_mesh", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types: Any = "auto"):
    """Blessed mesh constructor.

    ``axis_types="auto"`` (the default) requests ``AxisType.Auto`` on every
    axis *when the installed JAX understands axis types* and is silently
    dropped otherwise — this matches old-JAX behavior, where every mesh
    axis is implicitly auto-sharded.  Pass ``None`` to never send the
    kwarg, or an explicit tuple to forward it (ignored if unsupported).
    """
    impl = _raw_make_mesh()
    if impl is None:
        # Pre-make_mesh JAX: reshape the device list by hand.
        import numpy as np
        from jax.sharding import Mesh
        devs = list(jax.devices()) if devices is None else list(devices)
        return Mesh(np.asarray(devs).reshape(tuple(axis_shapes)),
                    tuple(axis_names))
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):
        params = {}
    if "axis_types" in params:
        if axis_types == "auto":
            axis_types = default_axis_types(len(tuple(axis_names)))
        if axis_types is not None:
            kwargs["axis_types"] = axis_types
    return impl(tuple(axis_shapes), tuple(axis_names), **kwargs)


# --------------------------------------------------------------------------
# named-axis introspection: jax.lax.axis_size is a newer addition
# --------------------------------------------------------------------------

def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap.

    New JAX spells this ``jax.lax.axis_size``; on 0.4.x, ``psum`` of the
    literal 1 constant-folds to the same static Python int.
    """
    impl = getattr(jax.lax, "axis_size", None)
    if impl is not None:
        return impl(axis_name)
    return jax.lax.psum(1, axis_name)


# --------------------------------------------------------------------------
# Pallas TPU compiler params: TPUCompilerParams (0.4.x) -> CompilerParams
# --------------------------------------------------------------------------

def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` across the rename.

    New JAX calls the dataclass ``CompilerParams``; 0.4.x spells it
    ``TPUCompilerParams`` (same fields).  Kernels must build it through
    here — the interpret-mode path still constructs the object at trace
    time, so the wrong name breaks CPU test runs, not just TPU.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def gpu_compiler_params(**kwargs):
    """``pltriton.CompilerParams(**kwargs)`` across the same rename.

    The Triton lowering's options dataclass (``num_warps``,
    ``num_stages``) is ``TritonCompilerParams`` on 0.4.x/0.5.x and
    ``CompilerParams`` on newer JAX — the mirror image of the TPU
    rename above.  Kernels must build it through here."""
    from jax.experimental.pallas import triton as pltriton
    cls = getattr(pltriton, "CompilerParams", None)
    if cls is None:
        cls = pltriton.TritonCompilerParams
    return cls(**kwargs)


# --------------------------------------------------------------------------
# compiled-program introspection: cost_analysis() drifted list[dict] -> dict
# --------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    JAX 0.4.x returns a one-element list of per-program dicts; newer JAX
    returns the dict directly (and may return None for unsupported
    backends).  Callers always get a (possibly empty) dict.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if len(cost) else {}
    return dict(cost)


# --------------------------------------------------------------------------
# pytree utilities: jax.tree is the modern alias of jax.tree_util
# --------------------------------------------------------------------------

def _tree_module():
    mod = getattr(jax, "tree", None)
    if mod is not None and hasattr(mod, "map"):
        return mod
    return jax.tree_util


class _TreeShim:
    """``jax.tree``-shaped facade over whichever tree module exists."""

    @staticmethod
    def map(f, tree_, *rest, **kwargs):
        mod = _tree_module()
        fn = getattr(mod, "map", None) or mod.tree_map
        return fn(f, tree_, *rest, **kwargs)

    @staticmethod
    def flatten(tree_, *args, **kwargs):
        mod = _tree_module()
        fn = getattr(mod, "flatten", None) or mod.tree_flatten
        return fn(tree_, *args, **kwargs)

    @staticmethod
    def unflatten(treedef, leaves):
        mod = _tree_module()
        fn = getattr(mod, "unflatten", None) or mod.tree_unflatten
        return fn(treedef, leaves)

    @staticmethod
    def leaves(tree_, *args, **kwargs):
        mod = _tree_module()
        fn = getattr(mod, "leaves", None) or mod.tree_leaves
        return fn(tree_, *args, **kwargs)

    @staticmethod
    def structure(tree_, *args, **kwargs):
        mod = _tree_module()
        fn = getattr(mod, "structure", None) or mod.tree_structure
        return fn(tree_, *args, **kwargs)

    @staticmethod
    def map_with_path(f, tree_, *rest, **kwargs):
        # jax.tree.map_with_path only landed in 0.5.x; the tree_util
        # spelling exists across the whole supported range
        mod = _tree_module()
        fn = getattr(mod, "map_with_path", None) \
            or jax.tree_util.tree_map_with_path
        return fn(f, tree_, *rest, **kwargs)


tree = _TreeShim()
