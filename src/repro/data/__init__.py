from .pipeline import (AudioStream, DataConfig, ImageStream, LMStream,
                       VLMStream, make_stream)
