"""Deterministic, restart-safe synthetic data pipeline.

Every batch is a pure function of (dataset seed, step, host layout): a
counter-based PRNG keyed by the global step means a restarted trainer
resumes on *exactly* the batch it would have seen — the property the
fault-tolerance tests assert.  Hosts slice the global batch by
``process_index`` (single-host here, but the slicing logic is real).

Streams:
  * ``LMStream``      — token sequences with a learnable structure
                        (affine-progression segments + noise) so short
                        training runs visibly reduce loss.
  * ``ImageStream``   — procedural images (Gaussian blobs on gradients) for
                        diffusion training.
  * ``AudioStream``   — frame embeddings + unit labels (HuBERT-style stub).
  * ``VLMStream``     — tokens + synthetic patch embeddings prefix.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    prefetch: int = 2


def _host_slice(global_batch: int) -> tuple[int, int]:
    n = jax.process_count()
    idx = jax.process_index()
    per = global_batch // n
    return idx * per, per


class LMStream:
    """Structured synthetic LM data: each sequence interleaves segments of
    an affine progression (t_{i+1} = a*t_i + b mod V) with uniform noise."""

    def __init__(self, cfg: DataConfig, vocab: int):
        self.cfg = cfg
        self.vocab = vocab

    @partial(jax.jit, static_argnums=(0, 2))
    def _make(self, key, batch):
        c = self.cfg
        ks = jax.random.split(key, 4)
        a = jax.random.randint(ks[0], (batch, 1), 1, 8)
        b = jax.random.randint(ks[1], (batch, 1), 0, self.vocab)
        i = jnp.arange(c.seq_len)[None, :]
        prog = (a * i + b) % self.vocab
        noise = jax.random.randint(ks[2], (batch, c.seq_len), 0, self.vocab)
        use_noise = jax.random.bernoulli(ks[3], 0.15, (batch, c.seq_len))
        return jnp.where(use_noise, noise, prog).astype(jnp.int32)

    def batch(self, step: int):
        start, per = _host_slice(self.cfg.global_batch)
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)
        key = jax.random.fold_in(key, start)
        tokens = self._make(key, per)
        return {"tokens": tokens, "labels": tokens}


class ImageStream:
    """Procedural images in [-1, 1]: Gaussian blobs over linear gradients."""

    def __init__(self, cfg: DataConfig, size: int, channels: int):
        self.cfg = cfg
        self.size = size
        self.channels = channels

    @partial(jax.jit, static_argnums=(0, 2))
    def _make(self, key, batch):
        s, c = self.size, self.channels
        ks = jax.random.split(key, 5)
        yy, xx = jnp.mgrid[0:s, 0:s] / s
        cx = jax.random.uniform(ks[0], (batch, 1, 1, 1))
        cy = jax.random.uniform(ks[1], (batch, 1, 1, 1))
        sig = jax.random.uniform(ks[2], (batch, 1, 1, 1), minval=0.05, maxval=0.3)
        blob = jnp.exp(-((xx[None, :, :, None] - cx) ** 2
                         + (yy[None, :, :, None] - cy) ** 2) / (2 * sig ** 2))
        grad_dir = jax.random.uniform(ks[3], (batch, 1, 1, c), minval=-1, maxval=1)
        base = grad_dir * (xx + yy)[None, :, :, None] / 2
        amp = jax.random.uniform(ks[4], (batch, 1, 1, c), minval=0.3, maxval=1.0)
        img = jnp.clip(base + amp * blob, -1, 1)
        return img.astype(jnp.float32)

    def batch(self, step: int):
        start, per = _host_slice(self.cfg.global_batch)
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed ^ 0xD1F), step)
        key = jax.random.fold_in(key, start)
        return {"images": self._make(key, per)}


class AudioStream:
    def __init__(self, cfg: DataConfig, d_model: int, vocab: int):
        self.cfg = cfg
        self.d_model = d_model
        self.vocab = vocab

    def batch(self, step: int):
        c = self.cfg
        start, per = _host_slice(c.global_batch)
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed ^ 0xA0D10), step)
        key = jax.random.fold_in(key, start)
        k1, k2, k3 = jax.random.split(key, 3)
        feats = jax.random.normal(k1, (per, c.seq_len, self.d_model)) * 0.5
        labels = jax.random.randint(k2, (per, c.seq_len), 0, self.vocab)
        mask = jax.random.bernoulli(k3, 0.3, (per, c.seq_len))
        return {"features": feats, "labels": labels, "mask": mask}


class VLMStream:
    def __init__(self, cfg: DataConfig, vocab: int, num_prefix: int, d_model: int):
        self.cfg = cfg
        self.lm = LMStream(cfg, vocab)
        self.num_prefix = num_prefix
        self.d_model = d_model

    def batch(self, step: int):
        b = self.lm.batch(step)
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed ^ 0x1AB), step)
        per = b["tokens"].shape[0]
        b["image_embeds"] = jax.random.normal(
            key, (per, self.num_prefix, self.d_model)) * 0.2
        return b


def make_stream(cfg: ArchConfig, data_cfg: DataConfig):
    if cfg.frontend == "audio":
        return AudioStream(data_cfg, cfg.d_model, cfg.vocab_size)
    if cfg.frontend == "vision":
        return VLMStream(data_cfg, cfg.vocab_size, cfg.num_prefix_embeds,
                         cfg.d_model)
    if cfg.family == "dit":
        size = {"srds-dit-cifar": 32, "srds-dit-lsun": 128,
                "srds-dit-sd2": 64}.get(cfg.name, 32)
        return ImageStream(data_cfg, size, cfg.in_channels)
    return LMStream(data_cfg, cfg.vocab_size)
