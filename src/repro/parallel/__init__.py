from .collectives import compressed_psum_mean, lse_combine
from .sharding import (batch_shardings, cache_shardings, opt_state_shardings,
                       param_shardings)
