"""Parameter / activation / cache sharding rules.

One rule table maps parameter paths to PartitionSpecs:

  * TP on ``model``: attention q-heads (padded when needed), kv-heads when
    divisible (else replicated — they are small), MLP & expert d_ff, vocab.
  * EP on ``data``: MoE expert dim (the shard_map a2a in models/moe.py
    consumes exactly these local slices).
  * FSDP on ``data``: optional second shard dim for large dense weights.
  * ZeRO-1: optimizer moments reuse the param rules with FSDP forced on.
  * Stacked layers: everything under ``blocks`` gets a leading ``None``.

Cache rules implement the flash-decoding layout: KV sequence sharded over
``model`` (batch over data/pod), combined at attention time with an LSE
merge (repro/serve).

Rules work on any mesh built by :func:`repro.compat.make_mesh` — the only
mesh attributes consumed here (``axis_names``, ``devices.shape``) are stable
across JAX versions; pytree traversal rides :data:`repro.compat.tree`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

from repro.configs.base import ArchConfig
from repro.models.transformer import ParallelCtx


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _param_spec(cfg: ArchConfig, path: Tuple[str, ...], shape, *,
                mp_axis: Optional[str], data_axis: Optional[str],
                fsdp: bool, kv_shardable: bool) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    in_blocks = "blocks" in keys
    fa = data_axis if fsdp else None

    def wrap(spec: P) -> P:
        return P(None, *spec) if in_blocks else spec

    # ---- embeddings ----
    if name == "table":
        return P(mp_axis, None)
    if keys[-2:] == ["unembed", "w"] or (name == "w" and "unembed" in keys):
        return P(fa, mp_axis)
    if name in ("time_in", "eps_out"):
        return P(None, None)
    # ---- MoE ----
    if "moe" in keys:
        if name == "router":
            return wrap(P(None, None))
        if name in ("w_up", "w_gate"):
            return wrap(P(data_axis, None, mp_axis))
        if name == "w_down":
            return wrap(P(data_axis, mp_axis, None))
    # ---- RWKV (before attention: tmix reuses wk/wv names) ----
    if "tmix" in keys:
        if name in ("wr", "wk", "wv", "wg"):
            return wrap(P(fa, mp_axis))
        if name == "wo":
            return wrap(P(mp_axis, fa))
        rank = len(shape) - 1
        return wrap(P(*([None] * rank)))
    if "cmix" in keys:
        if name in ("wk_c", "wr_c"):
            return wrap(P(fa, mp_axis))
        if name == "wv_c":
            return wrap(P(mp_axis, fa))
        rank = len(shape) - 1
        return wrap(P(*([None] * rank)))
    # ---- attention ----
    if name == "wq":
        return wrap(P(fa, mp_axis))
    if name in ("wk", "wv"):
        return wrap(P(fa, mp_axis if kv_shardable else None))
    if name == "wo":
        return wrap(P(mp_axis, fa))
    if name == "bq":
        return wrap(P(mp_axis))
    if name in ("bk", "bv"):
        return wrap(P(mp_axis if kv_shardable else None))
    # ---- MLP ----
    if name in ("w_up", "w_gate"):
        return wrap(P(fa, mp_axis))
    if name == "w_down":
        return wrap(P(mp_axis, fa))
    # ---- RWKV time/channel mix ----
    if name in ("wr", "wk_", "wv_", "wg"):
        return wrap(P(fa, mp_axis))
    if name in ("wk_c", "wr_c"):
        return wrap(P(fa, mp_axis))
    if name == "wv_c":
        return wrap(P(mp_axis, fa))
    # ---- Hymba SSM ----
    if name == "w_in":
        return wrap(P(fa, mp_axis))
    if name in ("w_dt", "w_B", "w_C", "A_log"):
        return wrap(P(mp_axis, None))
    if name == "D":
        return wrap(P(mp_axis))
    if name == "w_out":
        return wrap(P(mp_axis, fa))
    # ---- DiT ----
    if name in ("patch_in", "patch_out", "t_mlp1", "t_mlp2", "pos",
                "mod", "mod_b", "mod_f", "mod_fb"):
        return wrap(P(*([None] * len(shape[1 if in_blocks else 0:]))))
    # ---- everything else (norms, loras, u, mus, ...) replicated ----
    rank = len(shape) - (1 if in_blocks else 0)
    return wrap(P(*([None] * rank)))


def param_shardings(cfg: ArchConfig, mesh, params, parallel: ParallelCtx, *,
                    fsdp: bool = False, zero1: bool = False):
    """Pytree of NamedSharding matching ``params`` (shapes or arrays)."""
    mp = parallel.model_axis
    da = parallel.data_axis
    mp_size = parallel.model_parallel
    _, hkv = cfg.padded_heads(mp_size)
    kv_shardable = mp_size > 1 and hkv % mp_size == 0

    def rule(path, leaf):
        shape = leaf.shape
        spec = _param_spec(cfg, path, shape, mp_axis=mp, data_axis=da,
                           fsdp=fsdp or zero1, kv_shardable=kv_shardable)
        # drop axes that don't divide the dim (e.g. tiny reduced configs)
        fixed = []
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                fixed.append(None)
            else:
                sz = axis_sizes[ax] if isinstance(ax, str) else 1
                fixed.append(ax if dim % max(sz, 1) == 0 else None)
        return _ns(mesh, P(*fixed))

    return compat.tree.map_with_path(rule, params)


def opt_state_shardings(cfg, mesh, opt_state, parallel):
    """ZeRO-1: moments take the param rules with FSDP forced on."""
    m = param_shardings(cfg, mesh, opt_state["m"], parallel, zero1=True)
    v = param_shardings(cfg, mesh, opt_state["v"], parallel, zero1=True)
    return {"m": m, "v": v, "step": _ns(mesh, P())}


def _axes_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes[axes]
    out = 1
    for a in axes:
        out *= sizes[a]
    return out


def batch_shardings(mesh, batch, batch_axes):
    def rule(leaf):
        ba = batch_axes if leaf.shape[0] % _axes_size(mesh, batch_axes) == 0 \
            else None
        spec = P(ba, *([None] * (leaf.ndim - 1)))
        return _ns(mesh, spec)

    return compat.tree.map(rule, batch)


def _check_axes_bound(mesh, spec_axes) -> None:
    """Clear ``ValueError`` for axis names a mesh does not bind — XLA's own
    unbound-axis failure surfaces deep inside shard_map tracing and names
    neither the axis nor the call site."""
    if mesh is None:
        return
    known = set(mesh.axis_names)
    for ax in spec_axes:
        if ax is not None and ax not in known:
            raise ValueError(
                f"axis {ax!r} is not bound by the mesh (axes: "
                f"{tuple(mesh.axis_names)})")


def microbatch_spec(data_axis: str, *, mesh=None) -> P:
    """PartitionSpec for a serving micro-batch sharded over ``data_axis``.

    The diffusion sampling service's slot batch stacks K independent samples
    on one axis; the per-iteration fine solves see a ``(B, K, *sample)``
    block-heads tensor.  Sharding K over a data axis needs no collectives —
    every lane's refinement is independent — so the spec is just
    ``P(None, data_axis)``: block dim replicated (or handled separately by
    the block/time axis inside the shard_map body), K split, trailing sample
    dims implicitly replicated (PartitionSpec pads with None).  Callers must
    check ``K % axis_size == 0``; uneven slot batches are a config error,
    not something to pad silently.

    Pass ``mesh`` to validate that ``data_axis`` is actually bound —
    raising a clear ``ValueError`` instead of XLA's opaque unbound-axis
    failure at trace time.
    """
    _check_axes_bound(mesh, (data_axis,))
    return P(None, data_axis)


def denoiser_spec(data_axis: Optional[str], denoiser=None, *, mesh=None) -> P:
    """Block-heads spec composing the data axis with a denoiser's model axes.

    The serving engine's fine program maps a ``(B, K, *sample)`` heads
    tensor; :func:`microbatch_spec` shards K over ``data_axis``.  A
    sharding-aware :class:`repro.core.denoiser.Denoiser` additionally
    shards *sample* dims over its own mesh axes (``in_spec``, e.g. DiT
    patch rows over ``model``), so the composed spec is::

        P(None, data_axis, *in_spec[1:])
          ^B    ^K          ^sample dims, shifted past the K dim

    (the denoiser's ``in_spec`` is over the sample layout
    ``(K, *sample_shape)``; its leading K entry — replicated by
    convention — is dropped and the remaining entries shift right by one
    to land on the heads tensor's sample dims).  Inside the shard_map
    body the denoiser evaluates via ``shard_eval()`` — its per-shard
    ``shard_fn`` directly, no per-eval slice/gather glue — which is how
    the block ``time`` axis, the ``data`` axis and the ``model`` axis
    compose into one (time, data, model) mesh
    (:func:`repro.launch.mesh.make_srds_mesh` builds it).

    With ``denoiser=None`` (or a plain adapted fn) this degrades to
    :func:`microbatch_spec`.  Pass ``mesh`` to validate every named axis
    is bound (clear ``ValueError`` instead of XLA's unbound-axis error).
    """
    from repro.core.denoiser import as_denoiser
    den = as_denoiser(denoiser) if denoiser is not None else None
    sample_axes = ()
    if den is not None and den.is_model_parallel:
        in_spec = tuple(den.in_spec)
        if in_spec and in_spec[0] is not None:
            raise ValueError(
                "denoiser in_spec shards the sample-batch dim "
                f"({in_spec[0]!r}); the serving engine owns that dim via "
                "data_axis")
        if mesh is not None:
            den.check_mesh(mesh)
        sample_axes = in_spec[1:]
    _check_axes_bound(mesh, (data_axis,) + tuple(sample_axes))
    return P(None, data_axis, *sample_axes)


def cache_shardings(cfg: ArchConfig, mesh, cache, parallel: ParallelCtx, *,
                    kv_seq_shard: bool = True):
    """Decode-cache layout: batch over (pod, data); KV sequence over model
    (flash-decoding) for dense caches; SSM state dims over model."""
    ba = parallel.batch_axes
    mp = parallel.model_axis
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def rule(path, leaf):
        shape = leaf.shape
        bsz = shape[1] if leaf.ndim >= 2 else 1
        b_ax = ba if bsz % _axes_size(mesh, ba) == 0 else None
        if leaf.ndim == 5:            # (L, B, S, Hkv, Dh) dense KV
            seq_ax = mp if (kv_seq_shard and shape[2] % axis_sizes.get(mp, 1) == 0) else None
            return _ns(mesh, P(None, b_ax, seq_ax, None, None))
        if leaf.ndim == 4:            # (L, B, din, n) ssm / (L,B,H?,..)
            dim_ax = mp if shape[2] % axis_sizes.get(mp, 1) == 0 else None
            return _ns(mesh, P(None, b_ax, dim_ax, None))
        if leaf.ndim == 3:            # (L, B, d)
            d_ax = mp if shape[2] % axis_sizes.get(mp, 1) == 0 else None
            return _ns(mesh, P(None, b_ax, d_ax))
        if leaf.ndim == 2:            # (L, W) ring positions
            return _ns(mesh, P(None, None))
        return _ns(mesh, P(*([None] * leaf.ndim)))

    return compat.tree.map_with_path(rule, cache)
