"""Distributed-optimization collectives.

``compressed_psum_mean``: int8-quantized gradient all-reduce with
error-feedback (1-bit-Adam-family trick, int8 variant).  Each shard
quantizes (grad + ef_carry) to int8 with a per-tensor scale, psums the int8
payload (exact in int32), dequantizes, and keeps the quantization residual
in the carry — so the *long-run* gradient information is lossless while the
wire format is 4x smaller than fp32 / 2x smaller than bf16.

``lse_combine``: flash-decoding reduction — combine per-shard partial
attention outputs computed over disjoint KV-sequence slices using their
logsumexps (used by the model-axis-sharded decode path in repro.serve).

Pytree plumbing goes through :data:`repro.compat.tree` (the ``jax.tree``
alias only exists on newer JAX; ``jax.tree_util`` is the 0.4.x spelling).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def _quantize(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8), scale, x - q * scale   # payload, scale, residual


def compressed_psum_mean(grads, axis: str, ef_carry):
    """Mean-all-reduce of a gradient pytree in int8 with error feedback.

    Returns (mean_grads_f32, new_ef_carry).  Scales are psum'd alongside
    (one f32 scalar per tensor); payloads are summed exactly in int32 and
    dequantized with the *max* scale across shards (conservative, keeps the
    estimate unbiased under the shared-scale approximation; the residual
    goes back into the carry either way).
    """
    n = None

    def one(g, ef):
        nonlocal n
        gf = g.astype(jnp.float32) + ef
        q, scale, resid = _quantize(gf)
        scale_max = jax.lax.pmax(scale, axis)
        # requantize against the shared scale so the integer sum is coherent
        q = jnp.clip(jnp.round(gf / scale_max), -127, 127).astype(jnp.int8)
        resid = gf - q.astype(jnp.float32) * scale_max
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        if n is None:
            n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
        mean = total.astype(jnp.float32) * scale_max / n.astype(jnp.float32)
        return mean, resid

    flat_g, tdef = compat.tree.flatten(grads)
    flat_e = compat.tree.leaves(ef_carry)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (compat.tree.unflatten(tdef, [o[0] for o in out]),
            compat.tree.unflatten(tdef, [o[1] for o in out]))


def lse_combine(o_parts, lse_parts, axis: str):
    """Combine per-shard attention partials over KV-sequence shards.

    o_parts: (..., D) partial softmax-weighted values with *local* softmax
    normalization; lse_parts: (...) local logsumexp.  Standard
    flash-decoding merge: renormalize by global lse via psum.
    """
    lse_max = jax.lax.pmax(lse_parts, axis)
    w = jnp.exp(lse_parts - lse_max)
    num = jax.lax.psum(o_parts * w[..., None], axis)
    den = jax.lax.psum(w, axis)
    return num / den[..., None]
