"""Runtime platform configuration: XLA flags + backend selection in one
place, applied BEFORE the JAX backend initializes.

``configure_platform`` is the launch-time front door for the knobs every
deployment (and benchmark emitter) otherwise re-derives by hand:

* ``platform`` — pin the JAX backend (``jax.config.update(
  'jax_platform_name', ...)``).  On ``"gpu"`` it also installs the XLA
  GPU performance preset (ROADMAP "GPU parity" item): triton softmax
  fusion, triton gemms, async collectives, the latency-hiding scheduler
  and the highest-priority async stream — the flag set upstream JAX
  documents for GPU serving workloads.
* ``host_device_count`` — fake N host devices via
  ``--xla_force_host_platform_device_count`` (the CPU-backed mesh trick
  the dry-run and the multi-process tests already use), so sharded
  drivers and mesh code run on a laptop.

Flags are **merged** into any existing ``XLA_FLAGS`` (ours win on
conflict, everything else is preserved) — clobbering would silently undo
a dry-run's fake-device count or a user's own tuning.

Ordering matters: XLA reads the environment once, when the backend
first initializes.  Importing JAX is fine; *running* anything is not.
``configure_platform`` raises if the backend is already up rather than
half-apply (an env var mutated after init is a silent no-op — the
worst failure mode for a performance preset).  Benchmark emitters call
it from their ``--platform`` / ``--host-devices`` CLI flags before any
device work (see docs/benchmarks.md).
"""
from __future__ import annotations

import os
from typing import Optional

__all__ = ["GPU_PERF_FLAGS", "configure_platform"]

# the XLA GPU performance preset (upstream gpu_performance_tips set):
# fusion + async collectives + latency hiding, for serving-shaped work
GPU_PERF_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _merge_xla_flags(new_flags) -> str:
    """Merge ``new_flags`` into XLA_FLAGS, replacing same-name flags and
    preserving everything else (order: survivors first, ours last)."""
    names = {f.split("=", 1)[0] for f in new_flags}
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if f.split("=", 1)[0] not in names]
    merged = " ".join(kept + list(new_flags))
    os.environ["XLA_FLAGS"] = merged
    return merged


def _backend_initialized() -> bool:
    """True once the JAX backend is up (env mutations no longer apply).

    Probes private-ish state defensively across JAX versions: absent
    introspection, assume NOT initialized (the caller is about to set
    env vars, which is harmless when wrong but load-bearing when
    right)."""
    try:
        import jax._src.xla_bridge as xb
        backends = getattr(xb, "_backends", None)
        return bool(backends)
    except Exception:
        return False


def configure_platform(platform: Optional[str] = None,
                       host_device_count: Optional[int] = None) -> dict:
    """Configure the JAX runtime for ``platform`` before backend init.

    Args:
      platform: ``"cpu"`` | ``"gpu"`` | ``"tpu"`` — pins
        ``jax_platform_name``.  ``"gpu"`` additionally merges
        :data:`GPU_PERF_FLAGS` into ``XLA_FLAGS``.  ``None`` leaves the
        backend choice to JAX (useful when only faking host devices).
      host_device_count: fake this many host (CPU) devices via
        ``--xla_force_host_platform_device_count`` — the local-mesh
        substrate for the sharded/wavefront drivers and the serving
        engine's ``data_axis`` on machines without real accelerators.

    Returns a dict of what was applied (``platform``, ``xla_flags``) —
    handy for benchmark metadata blocks.

    Raises ``RuntimeError`` if the JAX backend already initialized:
    XLA reads the environment exactly once, so a late call would be a
    silent no-op for the flag-carried settings.
    """
    if platform is not None and platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"platform must be cpu|gpu|tpu, got {platform!r}")
    if _backend_initialized():
        raise RuntimeError(
            "configure_platform() after the JAX backend initialized: "
            "XLA_FLAGS are read once at backend init, so this call would "
            "silently not apply — call it before any jax computation "
            "(importing jax is fine)")
    flags = []
    if host_device_count is not None:
        flags.append("--xla_force_host_platform_device_count="
                     f"{int(host_device_count)}")
    if platform == "gpu":
        flags.extend(GPU_PERF_FLAGS)
    xla_flags = _merge_xla_flags(flags) if flags \
        else os.environ.get("XLA_FLAGS", "")
    if platform is not None:
        import jax
        jax.config.update("jax_platform_name", platform)
    return {"platform": platform, "xla_flags": xla_flags}
