"""Runtime platform configuration: XLA flags + backend selection in one
place, applied BEFORE the JAX backend initializes.

``configure_platform`` is the launch-time front door for the knobs every
deployment (and benchmark emitter) otherwise re-derives by hand:

* ``platform`` — pin the JAX backend (``jax.config.update(
  'jax_platform_name', ...)``).  On ``"gpu"`` it also installs the XLA
  GPU performance preset (ROADMAP "GPU parity" item): triton softmax
  fusion, triton gemms, async collectives, the latency-hiding scheduler
  and the highest-priority async stream — the flag set upstream JAX
  documents for GPU serving workloads.
* ``host_device_count`` — fake N host devices via
  ``--xla_force_host_platform_device_count`` (the CPU-backed mesh trick
  the dry-run and the multi-process tests already use), so sharded
  drivers and mesh code run on a laptop.

Flags are **merged** into any existing ``XLA_FLAGS`` (ours win on
conflict, everything else is preserved) — clobbering would silently undo
a dry-run's fake-device count or a user's own tuning.

Ordering matters: XLA reads the environment once, when the backend
first initializes.  Importing JAX is fine; *running* anything is not.
``configure_platform`` raises if the backend is already up rather than
half-apply (an env var mutated after init is a silent no-op — the
worst failure mode for a performance preset).  Benchmark emitters call
it from their ``--platform`` / ``--host-devices`` CLI flags before any
device work (see docs/benchmarks.md).
"""
from __future__ import annotations

import os
from typing import Optional

__all__ = ["GPU_PERF_FLAGS", "GPU_RUNTIME_ENV", "configure_platform"]

# the XLA GPU performance preset (upstream gpu_performance_tips set):
# fusion + async collectives + latency hiding, for serving-shaped work
GPU_PERF_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

# GPU runtime preset env vars NOT carried in XLA_FLAGS: the client
# allocator knobs (serving processes share the device with dataloaders /
# sidecars, so the 75%-grab default is the first thing every deployment
# script overrides) and runtime log verbosity.  Keys here are the
# ``configure_platform`` kwarg names; values the env vars they set.
GPU_RUNTIME_ENV = {
    "gpu_preallocate": "XLA_PYTHON_CLIENT_PREALLOCATE",
    "gpu_mem_fraction": "XLA_PYTHON_CLIENT_MEM_FRACTION",
    "gpu_allocator": "XLA_PYTHON_CLIENT_ALLOCATOR",
    "log_level": "TF_CPP_MIN_LOG_LEVEL",
}


def _merge_xla_flags(new_flags) -> str:
    """Merge ``new_flags`` into XLA_FLAGS, replacing same-name flags and
    preserving everything else (order: survivors first, ours last)."""
    names = {f.split("=", 1)[0] for f in new_flags}
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if f.split("=", 1)[0] not in names]
    merged = " ".join(kept + list(new_flags))
    os.environ["XLA_FLAGS"] = merged
    return merged


def _backend_initialized() -> bool:
    """True once the JAX backend is up (env mutations no longer apply).

    Probes private-ish state defensively across JAX versions: absent
    introspection, assume NOT initialized (the caller is about to set
    env vars, which is harmless when wrong but load-bearing when
    right)."""
    try:
        import jax._src.xla_bridge as xb
        backends = getattr(xb, "_backends", None)
        return bool(backends)
    except Exception:
        return False


def configure_platform(platform: Optional[str] = None,
                       host_device_count: Optional[int] = None, *,
                       gpu_preallocate: Optional[bool] = None,
                       gpu_mem_fraction: Optional[float] = None,
                       gpu_allocator: Optional[str] = None,
                       log_level: Optional[int] = None) -> dict:
    """Configure the JAX runtime for ``platform`` before backend init.

    Args:
      platform: ``"cpu"`` | ``"gpu"`` | ``"tpu"`` — pins
        ``jax_platform_name``.  ``"gpu"`` additionally merges
        :data:`GPU_PERF_FLAGS` into ``XLA_FLAGS``.  ``None`` leaves the
        backend choice to JAX (useful when only faking host devices).
      host_device_count: fake this many host (CPU) devices via
        ``--xla_force_host_platform_device_count`` — the local-mesh
        substrate for the sharded/wavefront drivers and the serving
        engine's ``data_axis`` on machines without real accelerators.
      gpu_preallocate: ``XLA_PYTHON_CLIENT_PREALLOCATE`` — whether the
        client grabs its memory pool up front (JAX default True/75%).
        ``False`` is the serving-friendly setting when the device is
        shared with other processes.
      gpu_mem_fraction: ``XLA_PYTHON_CLIENT_MEM_FRACTION`` — pool size as
        a fraction of device memory (only meaningful with preallocation).
      gpu_allocator: ``XLA_PYTHON_CLIENT_ALLOCATOR`` — ``"default"`` |
        ``"platform"`` (allocate/free on demand; slow but exact — the
        autotune sweep's setting so candidate configs don't fight the
        pool) | ``"bfc"`` | ``"cuda_async"``.
      log_level: ``TF_CPP_MIN_LOG_LEVEL`` — runtime log verbosity (4
        silences the C++ backend chatter in benchmark output).

    The allocator knobs are plain env vars (not XLA_FLAGS) but obey the
    same read-once-at-init rule, hence they live behind the same
    before-init guard.  They are only *applied* when explicitly passed —
    ``configure_platform("gpu")`` alone never overrides a deployment's
    externally-set allocator env.

    Returns a dict of what was applied (``platform``, ``xla_flags``,
    ``env``) — handy for benchmark metadata blocks.

    Raises ``RuntimeError`` if the JAX backend already initialized:
    XLA reads the environment exactly once, so a late call would be a
    silent no-op for the flag-carried settings.
    """
    if platform is not None and platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"platform must be cpu|gpu|tpu, got {platform!r}")
    if gpu_allocator is not None and gpu_allocator not in (
            "default", "platform", "bfc", "cuda_async"):
        raise ValueError(f"gpu_allocator must be default|platform|bfc|"
                         f"cuda_async, got {gpu_allocator!r}")
    if _backend_initialized():
        raise RuntimeError(
            "configure_platform() after the JAX backend initialized: "
            "XLA_FLAGS are read once at backend init, so this call would "
            "silently not apply — call it before any jax computation "
            "(importing jax is fine)")
    flags = []
    if host_device_count is not None:
        flags.append("--xla_force_host_platform_device_count="
                     f"{int(host_device_count)}")
    if platform == "gpu":
        flags.extend(GPU_PERF_FLAGS)
    xla_flags = _merge_xla_flags(flags) if flags \
        else os.environ.get("XLA_FLAGS", "")
    env = {}
    if gpu_preallocate is not None:
        env[GPU_RUNTIME_ENV["gpu_preallocate"]] = \
            "true" if gpu_preallocate else "false"
    if gpu_mem_fraction is not None:
        env[GPU_RUNTIME_ENV["gpu_mem_fraction"]] = f"{gpu_mem_fraction:.2f}"
    if gpu_allocator is not None:
        env[GPU_RUNTIME_ENV["gpu_allocator"]] = gpu_allocator
    if log_level is not None:
        env[GPU_RUNTIME_ENV["log_level"]] = str(int(log_level))
    os.environ.update(env)
    if platform is not None:
        import jax
        jax.config.update("jax_platform_name", platform)
    return {"platform": platform, "xla_flags": xla_flags, "env": env}
