"""Sweep driver: run every (arch x shape x mesh) dry-run cell in isolated
subprocesses (crash-safe, parallel).

  PYTHONPATH=src python -m repro.launch.dryrun_all --mesh pod1 --jobs 3
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.configs import arch_names, get_arch, shape_cells

ASSIGNED = ["stablelm-3b", "qwen1.5-32b", "qwen3-8b", "qwen3-14b",
            "phi-3-vision-4.2b", "rwkv6-1.6b", "hymba-1.5b", "arctic-480b",
            "kimi-k2-1t-a32b", "hubert-xlarge"]
DIT = ["srds-dit-cifar", "srds-dit-lsun", "srds-dit-sd2"]


def all_cells(meshes):
    cells = []
    for a in ASSIGNED:
        cfg = get_arch(a)
        for s in shape_cells(cfg):
            for m in meshes:
                cells.append((a, s.name, m))
    for a in DIT:
        for m in meshes:
            cells.append((a, "sample", m))
    return cells


def run_one(arch, shape, mesh, out_dir, timeout, extra_args=()):
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(path):
        return (arch, shape, mesh, "cached", 0.0)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out_dir, *extra_args]
    # (the optimized profile is forwarded via extra_args below)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        status = "ok" if r.returncode == 0 else "FAIL"
        if status == "FAIL":
            with open(path.replace(".json", ".err"), "w") as f:
                f.write(r.stdout[-4000:] + "\n--- stderr ---\n" + r.stderr[-8000:])
    except subprocess.TimeoutExpired:
        status = "TIMEOUT"
    return (arch, shape, mesh, status, time.time() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--opt", action="store_true",
                    help="apply the EXPERIMENTS.md §Perf optimized profile")
    args = ap.parse_args()
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)
    cells = all_cells(meshes)
    print(f"{len(cells)} cells, {args.jobs} workers")
    extra = []
    if args.opt:
        extra = ["--override", "remat_policy=nothing",
                 "--override", "moe_fixed_capacity=True"]
    results = []
    with ThreadPoolExecutor(max_workers=args.jobs) as pool:
        futs = {pool.submit(run_one, a, s, m, args.out, args.timeout,
                            tuple(extra)): (a, s, m)
                for a, s, m in cells}
        for fut in as_completed(futs):
            a, s, m, status, dt = fut.result()
            print(f"[{status:7s}] {a} x {s} x {m}  ({dt:.0f}s)", flush=True)
            results.append((a, s, m, status))
    bad = [r for r in results if r[3] not in ("ok", "cached")]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells passed")
    for r in bad:
        print("FAILED:", r)
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
