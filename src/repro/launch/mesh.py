"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run forces 512 placeholder devices via XLA_FLAGS before first init,
while tests and benches must keep seeing the single real device.

Meshes are built through :func:`repro.compat.make_mesh` (never
``jax.make_mesh`` directly): the ``axis_types=AxisType.Auto`` kwarg only
exists on newer JAX, and the compat layer requests it when available while
degrading cleanly on 0.4.x, where every mesh axis is implicitly auto.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI subprocess tests (8 fake devices)."""
    return compat.make_mesh(shape, axes)


def make_srds_mesh(time: int, data: int = 1, model: int = 1, *,
                   devices=None):
    """The SRDS (time, data, model) mesh: parareal blocks over ``time``,
    independent sample lanes over ``data``, and the denoiser's own
    parallelism (:class:`repro.core.denoiser.Denoiser.mesh_axes`) over
    ``model``.  Axes of size 1 are kept — specs naming them are no-ops, so
    one program covers every composition; requires time*data*model devices.
    """
    return compat.make_mesh((time, data, model), ("time", "data", "model"),
                            devices=devices)


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
