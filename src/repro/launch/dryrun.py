import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first backend init). Everything below is ordinary.

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# the production mesh, prove memory fits, and extract the roofline terms.
#
# Usage:
#   python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k --mesh pod1
#   python -m repro.launch.dryrun --arch srds-dit-sd2 --shape sample --mesh pod1
#   python -m repro.launch.dryrun --list
#
# Writes experiments/dryrun/<arch>__<shape>__<mesh>[__<tag>].json with:
#   flops / bytes-accessed / peak-memory per device (cost & memory analysis),
#   per-collective byte counts parsed from the post-SPMD HLO, the roofline
#   terms (TPU v5e constants), and the dominant bottleneck.
# (module docstring deliberately after the XLA_FLAGS lines — see above)

import argparse
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import SHAPES, get_arch, shape_cells
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import specs as sp
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.transformer import ParallelCtx, decode_step, init_params, prefill
from repro.optim.adamw import AdamWConfig, init_opt_state, warmup_cosine
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     opt_state_shardings, param_shardings)
from repro.train.steps import make_train_step

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output-shape bytes of every collective op in post-SPMD HLO.

    Approximation documented in EXPERIMENTS.md: bytes == op output size
    (for all-gather this counts the gathered result; for all-reduce the
    reduced tensor; close enough for a three-term roofline)."""
    out = {c: {"count": 0, "bytes": 0.0} for c in COLLECTIVES}
    shape_re = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0.0
        for dt, dims in shape_re.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


def build_parallel(cfg: ArchConfig, mesh, *, unroll: bool = False) -> ParallelCtx:
    multi = "pod" in mesh.axis_names
    return ParallelCtx(
        mesh=mesh,
        batch_axes=("pod", "data") if multi else ("data",),
        model_axis="model",
        data_axis="data",
        use_ep=cfg.moe_experts > 0,
        sp=True,
        model_parallel=dict(zip(mesh.axis_names, mesh.devices.shape))["model"],
        moe_chunk=8_192,
        scan_unroll=unroll,
    )


def lower_cell(cfg: ArchConfig, shape: Optional[ShapeConfig], mesh, *,
               sample_blocks: int = 16, overrides: Optional[dict] = None,
               unroll: bool = False):
    """Build + lower + compile the step for one cell. Returns (lowered,
    compiled, meta).  ``unroll=True`` is the ANALYSIS form: scans unrolled so
    cost_analysis/collective counts cover every loop iteration (XLA counts
    while bodies once); the scanned form is the deployment artifact whose
    memory_analysis we report."""
    par = build_parallel(cfg, mesh, unroll=unroll)
    if overrides:
        import dataclasses as dc
        par = dc.replace(par, **{k: v for k, v in overrides.items()
                                 if hasattr(par, k)})
    p_specs = sp.param_specs(cfg, par)
    p_sh = param_shardings(cfg, mesh, p_specs, par, fsdp=par.fsdp)

    if shape is None:  # SRDS sample step for DiT cells
        return _lower_srds_sample(cfg, mesh, par, p_specs, p_sh, sample_blocks,
                                  unroll=unroll)

    b_specs = sp.batch_specs(cfg, shape)
    b_sh = batch_shardings(mesh, b_specs, par.batch_axes)

    if shape.kind == "train":
        opt_specs = jax.eval_shape(init_opt_state, p_specs)
        o_sh = opt_state_shardings(cfg, mesh, opt_specs, par)
        opt_cfg = AdamWConfig(schedule=warmup_cosine(3e-4, 100, 10_000),
                              bf16_grad_sync=par.bf16_grad_sync)
        loss_kind = "diffusion" if cfg.family == "dit" else "lm"
        step = make_train_step(cfg, opt_cfg, parallel=par, remat=True,
                               loss_kind=loss_kind, use_kernel=False)
        jitted = jax.jit(step, donate_argnums=(0, 1),
                         in_shardings=(p_sh, o_sh, b_sh, NamedSharding(mesh, P())),
                         out_shardings=(p_sh, o_sh, None))
        lowered = jitted.lower(p_specs, opt_specs, b_specs,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
    elif shape.kind == "prefill":
        c_specs = sp.cache_specs(cfg, shape, par)
        c_sh = cache_shardings(cfg, mesh, c_specs, par)

        def fn(params, batch):
            return prefill(cfg, params, batch, parallel=par, use_kernel=False)

        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(p_specs, b_specs)
    else:  # decode
        c_specs = sp.cache_specs(cfg, shape, par)
        c_sh = cache_shardings(cfg, mesh, c_specs, par)

        def fn(params, batch, cache, pos):
            return decode_step(cfg, params, batch, cache, pos, parallel=par,
                               use_kernel=False)

        jitted = jax.jit(fn, donate_argnums=(2,),
                         in_shardings=(p_sh, b_sh, c_sh, NamedSharding(mesh, P())),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(p_specs, b_specs, c_specs,
                               jax.ShapeDtypeStruct((), jnp.int32))
    t0 = time.time()
    compiled = lowered.compile()
    return lowered, compiled, {"compile_s": time.time() - t0}


def _lower_srds_sample(cfg, mesh, par, p_specs, p_sh, num_blocks,
                       unroll: bool = False):
    """Paper-representative cell: the SRDS sampler itself on the mesh —
    parareal blocks sharded over `data`, denoiser TP over `model`."""
    from repro.core import SolverConfig, SRDSConfig, make_schedule
    from repro.core.parareal import srds_sample
    from repro.models.dit import dit_forward

    size = {"srds-dit-cifar": 32, "srds-dit-lsun": 128,
            "srds-dit-sd2": 64}.get(cfg.name, 32)
    n_steps = num_blocks * num_blocks
    sched = make_schedule("ddpm_linear", n_steps)
    if par.model_axis is None:
        # no-TP variant (§Perf): denoiser replicated, `model` mesh axis
        # repurposed for the sample batch — denoiser evals become fully
        # local, the only traffic left is parareal boundary exchange.
        batch = 16
        block_sh = NamedSharding(mesh, P("data", "model", None, None, None))
    else:
        batch = 8
        block_sh = NamedSharding(mesh, P("data", None, None, None, None))

    def sample_step(params, x0):
        def model_fn(x, t):
            tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
            return dit_forward(cfg, params, x, tb, use_kernel=False,
                               unroll=unroll)

        res = srds_sample(model_fn, sched,
                          SolverConfig("ddim", unroll=unroll), x0,
                          SRDSConfig(tol=1e-3, num_blocks=num_blocks,
                                     max_iters=4, block_sharding=block_sh,
                                     fixed_iters=unroll))
        return res.sample, res.iterations

    x_spec = jax.ShapeDtypeStruct((batch, size, size, cfg.in_channels),
                                  jnp.float32)
    jitted = jax.jit(sample_step,
                     in_shardings=(p_sh, NamedSharding(mesh, P())),
                     out_shardings=None)
    lowered = jitted.lower(p_specs, x_spec)
    t0 = time.time()
    compiled = lowered.compile()
    return lowered, compiled, {"compile_s": time.time() - t0}


def analyze(cfg: ArchConfig, shape_name: str, mesh, lowered, compiled,
            meta) -> dict:
    n_dev = mesh.devices.size
    cost = compat.cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    coll = parse_collective_bytes(compiled.as_text())
    coll_bytes = sum(v["bytes"] for v in coll.values())

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    shape = SHAPES.get(shape_name)
    if shape is not None:
        tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.active_param_count() * tokens
    else:
        model_flops = None

    return {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": n_dev,
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_acc,
        "memory_analysis": mem_d,
        "collectives": coll,
        "collective_bytes_per_device": coll_bytes,
        "roofline": dict(terms, dominant=dominant,
                         model_flops_global=model_flops,
                         useful_fraction=(model_flops / (flops * n_dev))
                         if model_flops and flops else None),
        **meta,
    }


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             tag: str = "", overrides: Optional[dict] = None,
             skip_analysis_pass: bool = False) -> dict:
    cfg = get_arch(arch)
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    shape = None if shape_name == "sample" else SHAPES[shape_name]
    # pass 1 — deployment form (scan-over-layers): memory proof
    lowered, compiled, meta = lower_cell(cfg, shape, mesh, overrides=overrides)
    result = analyze(cfg, shape_name, mesh, lowered, compiled, meta)
    if not skip_analysis_pass:
        # pass 2 — analysis form: XLA counts while-loop bodies ONCE, so the
        # scanned numbers above undercount.  All layer stacks are homogeneous
        # => every cost metric is affine in L: lower UNROLLED at L=1 and L=2
        # and extrapolate total(L) = f(1) + (L-1) * (f(2) - f(1)).  Exact for
        # matmul/collective costs (validated against a full 32-layer unroll,
        # see EXPERIMENTS.md §Dry-run methodology); the CE/moe chunk scans
        # unroll fully inside each probe.
        import dataclasses as dc
        L = cfg.num_layers
        probes = []
        for lprobe in ([1, 2] if L > 2 else [L]):
            cfg_p = dc.replace(cfg, num_layers=lprobe)
            lo2, co2, meta2 = lower_cell(cfg_p, shape, mesh,
                                         overrides=overrides, unroll=True)
            probes.append(analyze(cfg_p, shape_name, mesh, lo2, co2, meta2))
        result["scanned_flops_per_device"] = result["flops_per_device"]
        result["scanned_collectives"] = result["collectives"]
        if len(probes) == 1:
            ana = probes[0]
            result["flops_per_device"] = ana["flops_per_device"]
            result["bytes_accessed_per_device"] = ana["bytes_accessed_per_device"]
            result["collectives"] = ana["collectives"]
            result["collective_bytes_per_device"] = ana["collective_bytes_per_device"]
        else:
            f1, f2 = probes

            def ext(a, b):
                return a + (L - 1) * (b - a)

            result["flops_per_device"] = ext(f1["flops_per_device"],
                                             f2["flops_per_device"])
            result["bytes_accessed_per_device"] = ext(
                f1["bytes_accessed_per_device"], f2["bytes_accessed_per_device"])
            coll = {}
            for kind in f1["collectives"]:
                coll[kind] = {
                    "count": int(ext(f1["collectives"][kind]["count"],
                                     f2["collectives"][kind]["count"])),
                    "bytes": ext(f1["collectives"][kind]["bytes"],
                                 f2["collectives"][kind]["bytes"]),
                }
            result["collectives"] = coll
            result["collective_bytes_per_device"] = sum(
                v["bytes"] for v in coll.values())
        # recompute roofline with extrapolated numbers
        n_dev = mesh.devices.size
        compute_s = result["flops_per_device"] / PEAK_FLOPS_BF16
        memory_s = result["bytes_accessed_per_device"] / HBM_BW
        collective_s = result["collective_bytes_per_device"] / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        mf = result["roofline"]["model_flops_global"]
        result["roofline"] = dict(
            terms, dominant=max(terms, key=terms.get),
            model_flops_global=mf,
            useful_fraction=(mf / (result["flops_per_device"] * n_dev))
            if mf and result["flops_per_device"] else None)
        result["analysis_compile_s"] = sum(p_["compile_s"] for p_ in probes)
    result["tag"] = tag
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] OK {arch} x {shape_name} x {mesh_name} "
          f"compile={meta['compile_s']:.1f}s dominant={result['roofline']['dominant']}")
    print(json.dumps({k: result[k] for k in
                      ("flops_per_device", "bytes_accessed_per_device",
                       "collective_bytes_per_device")}, indent=1))
    print("memory_analysis:", json.dumps(result["memory_analysis"]))
    print("cost_analysis flops:", result["flops_per_device"])
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", default="train_4k",
                    help="train_4k|prefill_32k|decode_32k|long_500k|sample")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="ParallelCtx overrides, e.g. sp=False moe_chunk=4096")
    args = ap.parse_args()

    if args.list:
        from repro.configs import arch_names
        for a in arch_names():
            cfg = get_arch(a)
            cells = ([s.name for s in shape_cells(cfg)]
                     if cfg.family != "dit" else ["sample"])
            print(f"{a}: {cells}")
        return

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=")
        overrides[k] = ({"True": True, "False": False, "None": None}[v]
                        if v in ("True", "False", "None")
                        else (int(v) if v.isdigit() else v))
    run_cell(args.arch, args.shape, args.mesh, args.out, args.tag,
             overrides or None)


if __name__ == "__main__":
    main()
