"""Training launcher: config -> mesh -> sharded state -> fault-tolerant loop.

CPU-scale examples use --mesh local (single device); the production meshes
are exercised by the dry-run (this launcher accepts the same flags so the
same entrypoint deploys on real hardware).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --steps 50 --batch 8 --seq 128 --mesh local --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.data import DataConfig, make_stream
from repro.launch.mesh import make_production_mesh
from repro.models.dit import init_dit
from repro.models.transformer import LOCAL, ParallelCtx, init_params
from repro.optim import AdamWConfig, init_opt_state, warmup_cosine
from repro.parallel.sharding import (batch_shardings, opt_state_shardings,
                                     param_shardings)
from repro.runtime import LoopConfig, PreemptionSignal, train_loop
from repro.train import make_train_step
from repro.train.steps import jit_train_step


def build(arch: str, *, mesh_kind: str = "local", reduced: bool = False,
          lr: float = 3e-4, total_steps: int = 100, use_kernel=False,
          remat: bool = False):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    if mesh_kind == "local":
        par = LOCAL
        mesh = None
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
        multi = "pod" in mesh.axis_names
        par = ParallelCtx(mesh=mesh,
                          batch_axes=("pod", "data") if multi else ("data",),
                          use_ep=cfg.moe_experts > 0, sp=True,
                          model_parallel=16)
    key = jax.random.PRNGKey(0)
    if cfg.family == "dit":
        params = init_dit(cfg, key)
        loss_kind = "diffusion"
    else:
        params = init_params(cfg, key, par)
        loss_kind = "lm"
    opt_state = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=lr, schedule=warmup_cosine(lr, max(10, total_steps // 10),
                                                        total_steps))
    step = make_train_step(cfg, opt_cfg, parallel=par, remat=remat,
                           loss_kind=loss_kind, use_kernel=use_kernel)
    if mesh is not None:
        p_sh = param_shardings(cfg, mesh, params, par)
        o_sh = opt_state_shardings(cfg, mesh, opt_state, par)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step = jit_train_step(step, in_shardings=(p_sh, o_sh, None, None),
                              out_shardings=(p_sh, o_sh, None))
    else:
        step = jit_train_step(step)
    return cfg, par, params, opt_state, step, loss_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="local", choices=["local", "pod1", "pod2"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg, par, params, opt_state, step, loss_kind = build(
        args.arch, mesh_kind=args.mesh, reduced=args.reduced, lr=args.lr,
        total_steps=args.steps)
    stream = make_stream(cfg, DataConfig(global_batch=args.batch,
                                         seq_len=args.seq))
    ckpt = Checkpointer(args.ckpt)
    losses = []

    def log(step_i, m):
        losses.append(m.get("loss", m.get("mse", 0.0)))
        print(f"step {step_i}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items()))

    train_loop(step, params, opt_state, stream, jax.random.PRNGKey(1), ckpt,
               LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          log_every=10),
               preemption=PreemptionSignal(install_sigterm=True),
               metrics_cb=log)
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")


if __name__ == "__main__":
    main()
