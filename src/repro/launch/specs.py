"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.models.transformer import LOCAL, ParallelCtx, init_params, make_dense_cache

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Inputs for the step this shape lowers (train/prefill: full sequence;
    decode: one token — the cache is separate, see cache_specs)."""
    b = shape.global_batch
    s = shape.seq_len
    if cfg.family == "dit":
        size = {"srds-dit-cifar": 32, "srds-dit-lsun": 128,
                "srds-dit-sd2": 64}.get(cfg.name, 32)
        return {"images": S((b, size, size, cfg.in_channels), jnp.float32)}
    if shape.is_decode:
        if cfg.frontend == "audio":
            return {"features": S((b, 1, cfg.d_model), jnp.bfloat16)}
        return {"tokens": S((b, 1), jnp.int32)}
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio":
        out["features"] = S((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = S((b, s), jnp.int32)
        if cfg.frontend == "vision":
            out["image_embeds"] = S((b, cfg.num_prefix_embeds, cfg.d_model),
                                    jnp.bfloat16)
    if shape.kind == "train":
        out["labels"] = S((b, s), jnp.int32)
        if cfg.frontend == "audio":
            out["mask"] = S((b, s), jnp.bool_)
    return out


def param_specs(cfg: ArchConfig, parallel: ParallelCtx = LOCAL):
    if cfg.family == "dit":
        from repro.models.dit import init_dit
        return jax.eval_shape(lambda k: init_dit(cfg, k),
                              jax.random.PRNGKey(0))
    return jax.eval_shape(lambda k: init_params(cfg, k, parallel),
                          jax.random.PRNGKey(0))


def cache_specs(cfg: ArchConfig, shape: ShapeConfig,
                parallel: ParallelCtx = LOCAL):
    # decode: the input cache; prefill: the output cache layout
    return jax.eval_shape(
        lambda: make_dense_cache(cfg, shape.global_batch, shape.seq_len,
                                 parallel))


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                parallel: ParallelCtx = LOCAL):
    """Everything the lowered step needs, keyed by argument name."""
    specs = {"batch": batch_specs(cfg, shape)}
    if shape.is_decode:
        specs["cache"] = cache_specs(cfg, shape, parallel)
        specs["pos"] = S((), jnp.int32)
    return specs
