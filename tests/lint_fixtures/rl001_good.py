"""Mirror of rl001_bad with every drifted API routed through repro.compat."""
from repro import compat


def bare_alias(tree):
    return compat.tree.map(lambda x: x + 1, tree)


def grep_invisible(tree):
    return compat.tree.map_with_path(lambda p, x: x, tree)


def mesh():
    return compat.make_mesh((1,), ("dp",))


def flops(compiled):
    return compat.cost_analysis(compiled)


def shard(fn, mesh_):
    return compat.shard_map(fn, mesh=mesh_)
