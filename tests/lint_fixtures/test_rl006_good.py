"""Tier-marked twins, plus the monkeypatched-fake-mesh exemption."""
import subprocess

import pytest

from repro import compat


@pytest.mark.slow
def test_spawns_child():
    subprocess.run(["python", "-c", "pass"], check=True)


@pytest.mark.distributed
def test_builds_mesh():
    compat.make_mesh((2, 2), ("dp", "mp"))


def test_fake_mesh(monkeypatch):
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda *a, **k: {})
    compat.make_mesh((2, 2), ("dp", "mp"))
