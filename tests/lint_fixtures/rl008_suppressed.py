"""A deliberate bare eval, recorded (not hidden) via inline suppression."""


def debug_probe(model_fn, x, t):
    return model_fn(x, t)  # reprolint: disable=RL008
