"""Seeded RL009 violations: mixing math re-derived outside the seam."""
from repro.core.accel import _live_mask              # line 2: private import
import jax.numpy as jnp


def solve_gamma(f, df, reg):                         # line 6: owned def
    return f


def driver_mix(z_prev, f, df_cols, dz_cols):
    gm = df_cols @ df_cols.T
    gamma = jnp.linalg.solve(gm, df_cols @ f)        # line 12: secant solve
    return z_prev + f - jnp.tensordot(gamma, dz_cols, axes=1)
