"""A deliberate raw-entry-point literal, recorded via suppression."""
from repro.kernels.elementwise import parareal_update_residual_pallas


def raw_kernel_probe(y, c, p, o):
    # the tile size IS the subject under test  # reprolint: disable=RL010
    return parareal_update_residual_pallas(y, c, p, o, block_rows=2,
                                           interpret=True)
