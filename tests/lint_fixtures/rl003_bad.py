"""Seeded RL003 violations: implicit device->host syncs in a @hot_loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.markers import hot_loop


@hot_loop
def step(state):
    resid = jnp.abs(state).max()
    if float(resid) < 1e-3:
        return state
    gathered = jax.device_get(state)
    hist = np.asarray(resid)
    return resid.item(), gathered, hist
