"""The safe donation idiom: rebind the result over the donated name."""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def fused_step(carry, g):
    return carry + g


def loop(carry, g):
    for _ in range(3):
        carry = fused_step(carry, g)
    return carry
