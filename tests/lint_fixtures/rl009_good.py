"""RL009-clean driver code: mixing goes through the Accelerator seam."""
import jax.numpy as jnp

from repro.core.accel import AndersonAccel, resolve_accel


def driver_step(astate, z_prev, z_new, accel=None):
    acc = resolve_accel(accel)
    z_mixed, astate = acc.apply(astate, z_prev, z_new)
    return z_mixed, astate


def build(max_iters, z):
    acc = AndersonAccel(depth=3, warmup=2)
    return acc.init_state(z, max_iters)


def non_mixing_math(x):
    # reductions / elementwise math are not the seam's signature
    return jnp.sum(x * x) + jnp.linalg.norm(x)
