"""Seeded RL005 violations: ad-hoc backend probes gating the fused path."""
import jax


def use_fused():
    return jax.default_backend() == "tpu"


def use_fused_platform(dev):
    return dev.platform in ("tpu",)
