"""Seeded RL006 violations: heavy tests carrying no tier marker."""
import subprocess

from repro import compat


def test_spawns_child():
    subprocess.run(["python", "-c", "pass"], check=True)


def test_builds_mesh():
    compat.make_mesh((2, 2), ("dp", "mp"))
