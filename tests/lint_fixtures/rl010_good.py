"""RL010-clean dispatch: sizes come from the tuning seam, not literals."""
from repro.kernels import ops, tuning

TUNER = tuning.KernelTuner(overrides={"flash": {"block_q": 32,
                                                "block_k": 32}})


def attend(q, k, v):
    # overrides live inside the tuner config, not at the call site
    return ops.attention(q, k, v, tuner=TUNER)


def attend_resolved(q, k, v, cfg):
    # variables (resolved configs, sweep candidates) are not literals
    return ops.attention(q, k, v, block_q=cfg.params["block_q"],
                         block_k=cfg.params["block_k"])


def recur(r, k, v, w, u, chunk_cap):
    return ops.rwkv6_wkv(r, k, v, w, u, chunk=chunk_cap)


def unrelated_kwargs(fn, x):
    # same-named kwarg families elsewhere are out of rule vocabulary
    return fn(x, moe_chunk=8192, block_size=4)
