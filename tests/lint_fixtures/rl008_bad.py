"""Seeded RL008 violations: bare model evals in driver-shaped code."""
import jax


def driver_step(model_fn, x, t):
    eps = model_fn(x, t)                      # line 6: direct eval
    return x - eps


class Engine:
    def __init__(self, model_fn):
        self.model_fn = model_fn

    def refine(self, x, t):
        return jax.vmap(lambda xi: self.model_fn(xi, t))(x)   # line 15
