"""Seeded RL001 violations — including the aliased-import class the old
``check.sh`` grep could not see (`from jax import tree_map`, module aliases,
and `tree_map_with_path` which the ``tree_map(`` pattern never matched)."""
import jax
import jax.tree_util as tu
from jax import tree_map
from jax.experimental import shard_map as sm


def bare_alias(tree):
    return tree_map(lambda x: x + 1, tree)


def grep_invisible(tree):
    return tu.tree_map_with_path(lambda p, x: x, tree)


def mesh():
    return jax.make_mesh((1,), ("dp",))


def flops(compiled):
    return compiled.cost_analysis()


def shard(fn, mesh_):
    return sm.shard_map(fn, mesh=mesh_)
