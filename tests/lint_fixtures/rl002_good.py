"""Consuming the public engine/window seam is always fine."""
from repro.core.engine import parareal_update, resolve_fused
from repro.core.window import resolve_policy


def refined(y, g_cur, g_prev):
    return parareal_update(y, g_cur, g_prev)


def policy(spec):
    return resolve_policy(spec), resolve_fused(None)
