"""A deliberate out-of-seam solve, recorded (not hidden) via suppression."""
import jax.numpy as jnp


def debug_gamma(gm, rhs):
    return jnp.linalg.solve(gm, rhs)  # reprolint: disable=RL009
