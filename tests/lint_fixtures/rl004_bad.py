"""Seeded RL004 violations: donated buffers read after the donating call."""
import functools

import jax


def jit_value_form(y, g):
    step = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
    out = step(y, g)
    return out + y


@functools.partial(jax.jit, donate_argnums=(1,))
def fused_step(carry, buf):
    return carry + buf


def decorator_form(carry, buf):
    new = fused_step(carry, buf)
    return new, buf.sum()
