"""Seeded RL002 violations: the Parareal seam re-derived outside its owner."""
from repro.core.engine import _residual_scratch


def parareal_update(y, g_cur, g_prev):
    return y + g_cur - g_prev
