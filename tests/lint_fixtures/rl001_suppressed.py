"""A deliberate drifted call, recorded (not hidden) via inline suppression."""
import jax


def mesh_trailer():
    return jax.make_mesh((1,), ("dp",))  # reprolint: disable=RL001


def mesh_standalone():
    # reprolint: disable=RL001
    return jax.make_mesh((1,), ("dp",))
