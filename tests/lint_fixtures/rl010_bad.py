"""Seeded RL010 violations: hardcoded tile sizes at dispatch call sites."""
from repro.kernels import ops


def attend(q, k, v):
    return ops.attention(q, k, v, block_q=32, block_k=32)   # line 6: 2 hits


def recur(r, k, v, w, u):
    return ops.rwkv6_wkv(r, k, v, w, u, chunk=16)           # line 10


def fused(y, c, p, o, helper):
    return helper(y, c, p, o,
                  block_rows=-8)                            # line 14 (call)
