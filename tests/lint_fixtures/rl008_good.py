"""RL008-clean driver code: every eval goes through the Denoiser seam."""
from repro.core.denoiser import as_denoiser


def driver_step(model_fn, x, t):
    den = as_denoiser(model_fn)
    eps = den(x, t)                        # standalone seam call
    return x - eps


def sharded_body(model_fn, x, t):
    eval_fn = as_denoiser(model_fn).inner_eval()
    return eval_fn(x, t)                   # seam glue inside a shard_map


def non_eval_shapes(model_fn, x, t, extra):
    model_fn(x, t, extra)                  # 3 args: not an (x, t) eval
    return as_denoiser(model_fn)
