"""The blessed hot-loop shape: ONE host fetch through the seam, all later
host-side math on the fetched value."""
import jax.numpy as jnp

from repro.analysis.markers import hot_loop


def _host_fetch(x):
    raise NotImplementedError


@hot_loop
def step(state):
    resid = jnp.abs(state).max()
    resid_np = _host_fetch(resid)
    if float(resid_np) < 1e-3:
        return None
    budget = int(len(str(resid_np)))
    return state, budget
