"""Dispatch policy lives in one place: ops.fused_default / resolve_fused."""
from repro.core.engine import resolve_fused
from repro.kernels import ops


def use_fused():
    return ops.fused_default()


def maybe(flag):
    return resolve_fused(flag)
