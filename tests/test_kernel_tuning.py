"""Unit tests for the kernel autotuning seam (repro.kernels.tuning):
bucket rounding, table resolution order, versioning, unknown-key
fallback, malformed-table loud failure, and the divisor helpers that
replaced ops._pick_chunk/_sample_tile_rows.  Pure-Python logic plus the
committed tables — no kernel launches."""
import json

import jax.numpy as jnp
import pytest

from repro.kernels import tuning
from repro.kernels.elementwise import TILE_ROWS


# --------------------------------------------------------------------------
# bucketing
# --------------------------------------------------------------------------

def test_bucket_rounds_to_next_pow2():
    assert tuning.next_pow2(1) == 1
    assert tuning.next_pow2(2) == 2
    assert tuning.next_pow2(3) == 4
    assert tuning.next_pow2(129) == 256
    assert tuning.bucket_for("flash", (33, 49, 16)) == (64, 64, 16)
    assert tuning.bucket_for("rwkv6", (24, 8)) == (32, 8)


def test_bucket_elementwise_flattens_to_total_size():
    """Elementwise ops flatten operands, so only total size matters — a
    (3, 129) and a (387,) operand share a bucket."""
    assert tuning.bucket_for("elementwise", (3, 129)) \
        == tuning.bucket_for("elementwise", (387,)) == (512,)
    assert tuning.bucket_for("elementwise", None) == ()


# --------------------------------------------------------------------------
# resolution order: overrides > table > heuristics
# --------------------------------------------------------------------------

def _table(backend="cpu", entries=()):
    return {"version": tuning.TABLE_SCHEMA_VERSION, "backend": backend,
            "entries": list(entries)}


def test_heuristic_defaults_match_legacy_constants():
    """With no table, the resolved defaults ARE the constants the kernels
    shipped with — the seam changes where sizes live, not their values
    (CPU bit-exactness depends on this)."""
    t = tuning.KernelTuner(table_dir="/nonexistent")
    for backend in ("cpu", "tpu"):
        el = t.resolve("elementwise", backend=backend, shape=(1000,))
        assert el.params == {"tile_rows": TILE_ROWS}
        assert el.source == "heuristic"
        fl = t.resolve("flash", backend=backend, shape=(64, 64, 32))
        assert fl.params["block_q"] == fl.params["block_k"] == 128
    rw = t.resolve("rwkv6", backend="cpu", shape=(48, 64))
    assert rw.params == {"chunk_target": 32}
    # unknown backends fall back to the default row, never error
    assert t.resolve("elementwise", backend="rocm",
                     shape=(8,)).params == {"tile_rows": TILE_ROWS}


def test_gpu_heuristics_are_triton_sized():
    t = tuning.KernelTuner(table_dir="/nonexistent")
    fl = t.resolve("flash", backend="gpu", shape=(64, 64, 32))
    assert fl.params["block_q"] == 64 and "num_warps" in fl.params
    assert t.resolve("elementwise", backend="gpu",
                     shape=(8,)).params["tile_rows"] < TILE_ROWS


def test_table_hit_and_unknown_key_fallback():
    tbl = _table(entries=[{"kernel": "flash", "dtype": "float32",
                           "bucket": [64, 64, 16],
                           "params": {"block_q": 16, "block_k": 8}}])
    t = tuning.KernelTuner(tables={"cpu": tbl})
    hit = t.resolve("flash", backend="cpu", shape=(33, 49, 16))
    assert hit.source == "table"
    assert hit.params["block_q"] == 16 and hit.params["block_k"] == 8
    # different dtype / bucket / backend -> heuristic fallback, no error
    for kwargs in ({"dtype": jnp.bfloat16, "shape": (33, 49, 16)},
                   {"shape": (128, 128, 16)},):
        miss = t.resolve("flash", backend="cpu", **kwargs)
        assert miss.source == "heuristic"
        assert miss.params["block_q"] == 128


def test_override_beats_table_and_merges():
    tbl = _table(entries=[{"kernel": "flash", "dtype": "float32",
                           "bucket": [64, 64, 16],
                           "params": {"block_q": 16, "block_k": 8}}])
    t = tuning.KernelTuner(tables={"cpu": tbl},
                           overrides={"flash": {"block_q": 4}})
    cfg = t.resolve("flash", backend="cpu", shape=(33, 49, 16))
    assert cfg.source == "override"
    assert cfg.params["block_q"] == 4      # instance override wins
    assert cfg.params["block_k"] == 8      # table value survives the merge
    call = t.resolve("flash", backend="cpu", shape=(33, 49, 16),
                     overrides={"block_q": 2})
    assert call.params["block_q"] == 2     # call-level beats instance


def test_key_records_full_lookup():
    t = tuning.KernelTuner(table_dir="/nonexistent")
    cfg = t.resolve("flash", backend="gpu", dtype=jnp.bfloat16,
                    shape=(100, 100, 64))
    assert cfg.key == ("gpu", "flash", "bfloat16", (128, 128, 64))
    with pytest.raises(ValueError, match="unknown kernel"):
        t.resolve("conv", backend="cpu")


# --------------------------------------------------------------------------
# table loading: committed tables valid; malformed tables fail LOUDLY
# --------------------------------------------------------------------------

def test_committed_tables_are_schema_valid():
    import os
    names = sorted(os.listdir(tuning.TABLE_DIR))
    assert {"cpu.json", "gpu.json", "tpu.json"} <= set(names)
    for name in names:
        if name.endswith(".json"):
            with open(os.path.join(tuning.TABLE_DIR, name)) as f:
                tuning.validate_table(json.load(f), name)


def test_missing_table_file_is_empty_not_error(tmp_path):
    t = tuning.KernelTuner(table_dir=str(tmp_path))
    assert t.resolve("flash", backend="cpu",
                     shape=(8, 8, 8)).source == "heuristic"


@pytest.mark.parametrize("mutate, msg", [
    (lambda d: d.update(version=99), "version"),
    (lambda d: d.pop("backend"), "backend"),
    (lambda d: d.update(entries={"not": "a list"}), "entries"),
    (lambda d: d["entries"].append({"kernel": "conv", "dtype": "float32",
                                    "bucket": [8], "params": {"x": 1}}),
     "unknown kernel"),
    (lambda d: d["entries"].append({"kernel": "flash", "dtype": "float32",
                                    "bucket": [8], "params": {}}),
     "params"),
    (lambda d: d["entries"].append({"kernel": "flash", "dtype": "float32",
                                    "bucket": [0], "params": {"block_q": 8}}),
     "bucket"),
    (lambda d: d["entries"].append({"kernel": "flash", "dtype": "float32",
                                    "bucket": [8],
                                    "params": {"block_q": True}}),
     "params"),
])
def test_malformed_table_fails_loudly(tmp_path, mutate, msg):
    """A broken committed table must raise TuningTableError at resolve —
    a silently ignored table would run default sizes in a deployment
    that believes itself tuned."""
    d = _table(entries=[{"kernel": "flash", "dtype": "float32",
                         "bucket": [8, 8, 8], "params": {"block_q": 8}}])
    mutate(d)
    path = tmp_path / "cpu.json"
    path.write_text(json.dumps(d))
    t = tuning.KernelTuner(table_dir=str(tmp_path))
    with pytest.raises(tuning.TuningTableError, match=msg):
        t.resolve("flash", backend="cpu", shape=(8, 8, 8))


def test_unparseable_table_fails_loudly(tmp_path):
    (tmp_path / "cpu.json").write_text("{not json")
    t = tuning.KernelTuner(table_dir=str(tmp_path))
    with pytest.raises(tuning.TuningTableError, match="JSON"):
        t.resolve("flash", backend="cpu", shape=(8, 8, 8))


# --------------------------------------------------------------------------
# divisor helpers (moved here from ops._pick_chunk/_sample_tile_rows)
# --------------------------------------------------------------------------

def test_pick_chunk_divides():
    assert tuning.pick_chunk(48, 32) == 24
    assert tuning.pick_chunk(64, 32) == 32
    assert tuning.pick_chunk(7, 32) == 7
    assert tuning.pick_chunk(13, 4) == 1


def test_sample_tile_rows_divides():
    assert tuning.sample_tile_rows(100, 256) == 100
    assert tuning.sample_tile_rows(100, 64) == 50
    assert tuning.sample_tile_rows(7, 2) == 1


def test_process_default_tuner_install_and_reset():
    custom = tuning.KernelTuner(overrides={"flash": {"block_q": 4}})
    try:
        tuning.set_tuner(custom)
        assert tuning.get_tuner() is custom
        assert tuning.resolve("flash", backend="cpu",
                              shape=(8, 8, 8)).params["block_q"] == 4
    finally:
        tuning.set_tuner(None)
    assert tuning.get_tuner() is not custom


# --------------------------------------------------------------------------
# launch/env GPU runtime knobs (the allocator preset seam)
# --------------------------------------------------------------------------

def test_gpu_runtime_env_knob_mapping_and_validation():
    from repro.launch import env as lenv
    assert set(lenv.GPU_RUNTIME_ENV) == {"gpu_preallocate",
                                         "gpu_mem_fraction",
                                         "gpu_allocator", "log_level"}
    # bad-arg validation fires before the backend-initialized guard
    with pytest.raises(ValueError, match="gpu_allocator"):
        lenv.configure_platform("gpu", gpu_allocator="arena")
    with pytest.raises(ValueError, match="platform"):
        lenv.configure_platform("cuda")
    # in a test process the backend is up: the read-once guard must trip
    import jax
    jax.devices()
    with pytest.raises(RuntimeError, match="backend initialized"):
        lenv.configure_platform("cpu", gpu_preallocate=False)


# --------------------------------------------------------------------------
# the autotune sweep's structural smoke (what ci.yml's bench-smoke runs)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_autotune_smoke_emits_schema_valid_table(tmp_path):
    import jax

    from benchmarks.autotune_kernels import sweep

    payload = sweep(True, cells_dir=str(tmp_path))
    tuning.validate_table(payload, "<smoke>")      # loud on any drift
    assert payload["backend"] == jax.default_backend()
    assert {e["kernel"] for e in payload["entries"]} == set(tuning.KERNELS)
    # one roofline-format cell per swept key, loadable by the harness
    cells = sorted(tmp_path.glob("*.json"))
    assert len(cells) == len(payload["entries"])
    for p in cells:
        cell = json.loads(p.read_text())
        assert {"compute_s", "memory_s", "collective_s",
                "dominant"} <= set(cell["roofline"])
