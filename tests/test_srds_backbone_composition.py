"""SRDS x model-zoo composition (DESIGN.md §Arch-applicability): any
assigned backbone wrapped with time-conditioning is a valid SRDS denoiser —
embedding-space diffusion sampled in parallel, exact vs sequential."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops

kops.FORCE_REF = True

from repro.configs import get_arch
from repro.core import (SolverConfig, SRDSConfig, make_schedule,
                        sample_sequential, srds_sample)
from repro.models.dit import init_time_conditioned, time_conditioned_forward

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-1.6b", "hubert-xlarge"])
def test_backbone_as_srds_denoiser(arch):
    """Dense / SSM / encoder backbones all compose with SRDS: the sampler
    converges to the sequential solve on embedding-space diffusion."""
    cfg = dc.replace(get_arch(arch).reduced(), dtype="float32")
    params = init_time_conditioned(cfg, KEY)

    def model_fn(x, t):
        tb = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (x.shape[0],))
        return time_conditioned_forward(cfg, params, x, tb, use_kernel=False)

    sched = make_schedule("ddpm_linear", 16)
    solver = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 1.0
    ref = sample_sequential(model_fn, sched, solver, x0)
    res = srds_sample(model_fn, sched, solver, x0, SRDSConfig(tol=0.0))
    scale = float(jnp.mean(jnp.abs(ref))) + 1e-9
    rel = float(jnp.mean(jnp.abs(res.sample - ref))) / scale
    assert rel < 1e-3, (arch, rel)          # exact up to f32 rounding
    assert int(res.iterations) <= 4         # <= B
    assert bool(jnp.all(jnp.isfinite(res.sample)))


def test_hybrid_backbone_denoiser_finite():
    """Hymba (attn+SSM) runs as a denoiser trunk too (no-NaN smoke; the
    SSM state is re-zeroed per eval as required for an ODE drift)."""
    cfg = dc.replace(get_arch("hymba-1.5b").reduced(), dtype="float32")
    params = init_time_conditioned(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    eps = time_conditioned_forward(cfg, params, x, jnp.array([5.0, 500.0]),
                                   use_kernel=False)
    assert eps.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(eps)))
    # time-conditioning must actually matter
    eps2 = time_conditioned_forward(cfg, params, x, jnp.array([900.0, 1.0]),
                                    use_kernel=False)
    assert bool(jnp.any(jnp.abs(eps - eps2) > 1e-6))
