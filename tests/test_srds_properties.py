"""Hypothesis property tests for the SRDS invariants."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import assume, given, settings, strategies as st

from repro.core import (SolverConfig, SRDSConfig, make_schedule,
                        resolve_blocks, sample_sequential, srds_sample)
from conftest import to_f64

SOLVERS = ["ddim", "heun", "dpm2", "ddpm"]


def _model(seed, dim):
    w = jax.random.normal(jax.random.PRNGKey(seed), (dim, dim),
                          dtype=jnp.float64) * 0.35

    def model_fn(x, t):
        return jnp.tanh(x @ w) * (0.4 + 0.0008 * t)

    return model_fn


@settings(max_examples=12, deadline=None)
@given(n=st.integers(min_value=4, max_value=48),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       solver=st.sampled_from(SOLVERS),
       kind=st.sampled_from(["ddpm_linear", "cosine", "karras"]))
def test_srds_always_equals_sequential(n, seed, solver, kind):
    """INVARIANT (Prop 1): for any grid size, schedule family, solver and
    random model/init, SRDS at the iteration cap == sequential solve."""
    assume(any(n % d == 0 for d in range(2, n)))  # prime N: resolve raises
    model = _model(seed, 4)
    sched = to_f64(make_schedule(kind, n))
    cfg = SolverConfig(solver, noise_key=jax.random.PRNGKey(seed ^ 0xABCD))
    x0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 4),
                           dtype=jnp.float64)
    ref = sample_sequential(model, sched, cfg, x0)
    res = srds_sample(model, sched, cfg, x0, SRDSConfig(tol=0.0))
    np.testing.assert_allclose(np.asarray(res.sample), np.asarray(ref),
                               rtol=0, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=4, max_value=64),
       b_hint=st.integers(min_value=1, max_value=64))
def test_resolve_blocks_invariants(n, b_hint):
    """Composite N: auto-selection returns a nontrivial split with B*S == N.
    Prime N raises (never a silent serial fallback).  Explicit hints are
    honored exactly when they divide N and rejected otherwise."""
    if any(n % d == 0 for d in range(2, n)):
        b, s = resolve_blocks(n, None)
        assert b * s == n and 1 < b < n
    else:
        with pytest.raises(ValueError):
            resolve_blocks(n, None)
    if b_hint <= n and n % b_hint == 0:
        assert resolve_blocks(n, b_hint) == (b_hint, n // b_hint)
    else:
        with pytest.raises(ValueError):
            resolve_blocks(n, b_hint)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       tol=st.sampled_from([1e-3, 1e-5, 1e-8]))
def test_tolerance_monotonicity(seed, tol):
    """Tighter tolerance never takes fewer iterations, and final residual is
    below tol whenever the sampler reports convergence before the cap."""
    model = _model(seed, 4)
    sched = to_f64(make_schedule("ddpm_linear", 36))
    cfg = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (1, 4), dtype=jnp.float64)
    res_loose = srds_sample(model, sched, cfg, x0, SRDSConfig(tol=1e-2))
    res_tight = srds_sample(model, sched, cfg, x0, SRDSConfig(tol=tol))
    assert int(res_tight.iterations) >= int(res_loose.iterations)
    b, _ = resolve_blocks(36, None)
    if int(res_tight.iterations) < b:
        assert float(res_tight.final_delta) < tol


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       batch=st.integers(min_value=1, max_value=4))
def test_batch_consistency(seed, batch):
    """Sampling a batch == sampling each element independently (SRDS is
    elementwise across the batch; convergence uses the joint norm, so force
    exactness with tol=0)."""
    model = _model(seed, 4)
    sched = to_f64(make_schedule("ddpm_linear", 16))
    cfg = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (batch, 4),
                           dtype=jnp.float64)
    joint = srds_sample(model, sched, cfg, x0, SRDSConfig(tol=0.0)).sample
    for i in range(batch):
        single = srds_sample(model, sched, cfg, x0[i:i + 1],
                             SRDSConfig(tol=0.0)).sample
        np.testing.assert_allclose(np.asarray(joint[i]), np.asarray(single[0]),
                                   rtol=0, atol=1e-9)
