"""Pallas/ref parity for the sampler's fused elementwise kernels —
``ddim_fused``, ``parareal_update`` and the new fused-residual feed —
swept over f32/bf16, non-lane-multiple shapes (the padding path) and the
explicit ``interpret=True`` CPU entry points.

Unlike tests/test_kernels.py this file needs no ``hypothesis``: the parity
matrix here must run on every environment (it is the ground truth for
flipping the fused path on by default where kernels compile)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tuning

KEYS = jax.random.split(jax.random.PRNGKey(42), 4)

# tile sizes for tests come from the tuning seam (RL010): explicit tuner
# overrides, not raw integers at the dispatch call sites
TUNER32 = tuning.KernelTuner(overrides={"flash": {"block_q": 32,
                                                  "block_k": 32}})


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(7,), (128,), (33, 5), (4, 129), (1000,)])
def test_parareal_update_dtype_and_padding(shape, dtype):
    """Kernel/ref parity across dtypes and non-lane-multiple shapes (the
    padding path pads the flattened operands to a multiple of 128)."""
    dt = jnp.dtype(dtype)
    y = jax.random.normal(KEYS[0], shape, dt)
    c = jax.random.normal(KEYS[1], shape, dt)
    p = jax.random.normal(KEYS[2], shape, dt)
    out_k, r_k = ops.parareal_update(y, c, p, use_kernel=True)
    out_r, r_r = ref.parareal_update(y, c, p)
    assert out_k.shape == shape and out_k.dtype == dt
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(r_k), float(r_r),
                               rtol=3e-2 if dtype == "bfloat16" else 1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(13,), (256,), (33, 5), (4, 129)])
def test_parareal_update_residual_parity(shape, dtype):
    """The fused-residual kernel (per-tile L1 partials feeding the
    convergence norm) vs the jnp oracle, across dtypes + padding shapes."""
    dt = jnp.dtype(dtype)
    y, c, p, o = (jax.random.normal(k, shape, dt) for k in KEYS)
    out_k, r_k = ops.parareal_update_residual(y, c, p, o, use_kernel=True)
    out_r, r_r = ref.parareal_update_residual(y, c, p, o)
    assert out_k.shape == shape and out_k.dtype == dt
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(r_k), float(r_r),
                               rtol=3e-2 if dtype == "bfloat16" else 1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(3, 2, 7), (2, 3, 128), (4, 2, 33, 5),
                                   (2, 2, 129), (5, 4)])
def test_parareal_update_residual_per_block(shape, dtype):
    """The sliding-window frontier feed: ``batch_dims=2`` preserves the
    leading (block, sample) axes, emitting per-block per-sample L1
    partials — kernel vs oracle across dtypes and padding shapes (each
    (B, K) slice gets its own padded rows, so tiles never straddle)."""
    dt = jnp.dtype(dtype)
    y, c, p, o = (jax.random.normal(k, shape, dt) for k in KEYS)
    out_k, r_k = ops.parareal_update_residual(y, c, p, o, batch_dims=2,
                                              use_kernel=True)
    out_r, r_r = ref.parareal_update_residual(y, c, p, o, batch_dims=2)
    assert out_k.shape == shape and out_k.dtype == dt
    assert r_k.shape == r_r.shape == shape[:2]
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(r_k, np.float32),
                               np.asarray(r_r, np.float32),
                               rtol=3e-2 if dtype == "bfloat16" else 1e-4)


def test_parareal_update_residual_batch_dims_contract():
    """batch_dims generalizes the legacy ``batched`` flag (0 == default,
    1 == batched=True) and rejects out-of-range reductions."""
    y, c, p, o = (jax.random.normal(k, (3, 5)) for k in KEYS)
    for use_kernel in (True, False):
        _, r0 = ops.parareal_update_residual(y, c, p, o, batch_dims=0,
                                             use_kernel=use_kernel)
        _, r0d = ops.parareal_update_residual(y, c, p, o,
                                              use_kernel=use_kernel)
        _, r1 = ops.parareal_update_residual(y, c, p, o, batch_dims=1,
                                             use_kernel=use_kernel)
        _, r1b = ops.parareal_update_residual(y, c, p, o, batched=True,
                                              use_kernel=use_kernel)
        assert r0.shape == r0d.shape == ()
        assert r1.shape == r1b.shape == (3,)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r1b))
        with pytest.raises(ValueError, match="batch_dims"):
            ops.parareal_update_residual(y, c, p, o, batch_dims=5,
                                         use_kernel=use_kernel)


@pytest.mark.parametrize("shape", [(3, 7), (2, 128), (4, 33, 5), (2, 129),
                                   (5, 1000)])
def test_parareal_update_residual_batched(shape):
    """Batched (K,) path: per-sample partials (rows are padded per sample
    so tiles never straddle samples) vs the oracle's per-sample sums."""
    y, c, p, o = (jax.random.normal(k, shape) for k in KEYS)
    out_k, r_k = ops.parareal_update_residual(y, c, p, o, batched=True,
                                              use_kernel=True)
    out_r, r_r = ref.parareal_update_residual(y, c, p, o, batched=True)
    assert r_k.shape == (shape[0],)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("n", [1, 127, 128, 129, 4096])
def test_ddim_fused_padding_and_dtypes_interpret(n, dtype):
    """ddim_fused kernel/ref parity on CPU via interpret=True, pinned to
    the non-lane-multiple (padding) and exact-multiple row layouts."""
    from repro.kernels.elementwise import ddim_fused_pallas
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEYS[0], (n,), dt)
    e = jax.random.normal(KEYS[1], (n,), dt)
    a, b = 0.37, 0.61
    out = ops.ddim_fused(x, e, a, b, use_kernel=True)
    exp = ref.ddim_fused(x, e, a, b)
    assert out.shape == x.shape and out.dtype == dt
    tol = 1e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)
    # and the raw 2D kernel entry point under explicit interpret=True
    rows = -(-n // 128)
    x2 = jnp.zeros((rows, 128), dt).at[0, 0].set(1.0)
    e2 = jnp.zeros((rows, 128), dt)
    ab = jnp.asarray([[a, b]], jnp.float32)
    o2 = ddim_fused_pallas(x2, e2, ab, interpret=True)
    exp2 = ref.ddim_fused(x2, e2, a, b)
    np.testing.assert_allclose(np.asarray(o2, np.float32),
                               np.asarray(exp2, np.float32),
                               rtol=tol, atol=tol)


def test_parareal_residual_kernel_interpret_entry_point():
    """The raw 2D fused-residual kernel under explicit interpret=True."""
    from repro.kernels.elementwise import parareal_update_residual_pallas
    y, c, p, o = (jax.random.normal(k, (6, 128)) for k in KEYS)
    # raw kernel entry point: the tile size IS the subject under test, so
    # the literal is intentional  # reprolint: disable=RL010
    out, partials = parareal_update_residual_pallas(y, c, p, o,
                                                    block_rows=2,
                                                    interpret=True)
    assert partials.shape == (3, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(y + c - p),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        float(jnp.sum(partials)),
        float(jnp.sum(jnp.abs((y + c - p) - o))), rtol=1e-5)


# B, Hq, Hkv, Sq, Sk, D, causal — the DiT patch-sharding shapes: small
# bidirectional sequences (encoder-style), GQA, local-query-vs-full-KV
# (Sq < Sk, what the model-parallel K/V all-gather produces), and
# non-multiple-of-128 tiles
FLASH_CASES = [
    (2, 2, 2, 16, 16, 16, False),     # DiT-sized bidirectional block
    (1, 4, 2, 8, 32, 16, False),      # patch-sharded: local q, gathered kv
    (2, 4, 4, 64, 64, 32, True),      # causal, tile-exact
    (1, 8, 2, 48, 48, 24, True),      # GQA 4x + ragged tiles
    (1, 2, 2, 40, 104, 32, True),     # Sq < Sk, right-aligned causal mask
]


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "case", FLASH_CASES,
    ids=lambda c: f"B{c[0]}H{c[1]}-{c[2]}S{c[3]}x{c[4]}D{c[5]}c{int(c[6])}")
def test_flash_attention_interpret_parity(case, dtype):
    """Flash kernel (interpret mode on CPU) vs the jnp oracle — the parity
    matrix behind the sharded DiT denoiser's attention path, which feeds
    local queries and all-gathered K/V through ``ops.attention`` with
    ``use_kernel=True``.  Runs everywhere (no hypothesis dependency)."""
    b, hq, hkv, sq, sk, d, causal = case
    dt = jnp.dtype(dtype)
    q = jax.random.normal(KEYS[0], (b, hq, sq, d), dt)
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d), dt)
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d), dt)
    out = ops.attention(q, k, v, causal=causal, tuner=TUNER32,
                        use_kernel=True)
    exp = ref.attention(q, k, v, causal=causal)
    assert out.shape == exp.shape and out.dtype == dt
    tol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_fused_default_resolution():
    """fused_default is on exactly where compiled kernels exist (the
    TPU/GPU capability set) and never under FORCE_REF; the tri-state
    resolver honors explicit bools."""
    from repro.core.engine import resolve_fused
    # this test *is* the resolver's oracle, so the raw backend probe is
    # intentional here  # reprolint: disable=RL005
    compiled = jax.default_backend() in ops._COMPILED_BACKENDS
    assert ops.fused_default() == compiled
    assert resolve_fused(None) == compiled
    assert resolve_fused(True) is True
    assert resolve_fused(False) is False
    saved = ops.FORCE_REF
    try:
        ops.FORCE_REF = True
        assert ops.fused_default() is False
    finally:
        ops.FORCE_REF = saved


@pytest.fixture
def _fake_backend(monkeypatch):
    """Monkeypatch jax.default_backend (what ops probes), reset the
    one-shot warning latch, and pin FORCE_REF=False around each use —
    other test modules flip it True process-wide for CPU speed, which
    would mask the capability logic under test here."""
    def set_backend(name):
        monkeypatch.setattr(jax, "default_backend", lambda: name)
    monkeypatch.setattr(ops, "_warned_degraded", False)
    monkeypatch.setattr(ops, "FORCE_REF", False)
    yield set_backend


def test_fused_default_true_on_gpu(_fake_backend):
    """GPU is in the compiled capability tier: fused on, no warning."""
    _fake_backend("gpu")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert ops.fused_default() is True
        assert ops._interpret() is False
        assert ops._plat() == "gpu"


@pytest.mark.parametrize("backend", ["tpu", "gpu", "cpu"])
def test_fused_default_never_warns_on_known_tiers(_fake_backend, backend):
    """The degrade warning must never fire on tpu/gpu (compiled) or cpu
    (the known interpret-mode dev tier)."""
    _fake_backend(backend)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for _ in range(3):
            ops.fused_default()


def test_fused_default_warns_once_on_unsupported_backend(_fake_backend):
    """A backend with no Pallas lowering gets exactly one structured
    warning naming the knobs (including the tuning seam), then silence."""
    _fake_backend("rocm")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert ops.fused_default() is False
        assert ops.fused_default() is False      # second call: silent
    msgs = [w for w in caught if issubclass(w.category, UserWarning)]
    assert len(msgs) == 1
    text = str(msgs[0].message)
    assert "rocm" in text and "use_fused" in text and "FORCE_REF" in text
    assert "repro.kernels.tuning" in text


def test_fused_default_no_warning_under_force_ref(_fake_backend):
    """FORCE_REF pins the reference path deliberately — no warning even
    on an unsupported backend."""
    _fake_backend("rocm")
    saved = ops.FORCE_REF
    try:
        ops.FORCE_REF = True
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert ops.fused_default() is False
    finally:
        ops.FORCE_REF = saved


# ---------------------------------------------------------------------------
# Tuned-config parity matrix: (default | table-resolved | override) configs
# x f32/bf16 x non-tile-multiple shapes, interpret mode (ISSUE 10)
# ---------------------------------------------------------------------------

def _table_for(kernel, dtype, shape, params):
    """An in-memory one-entry tuning table hitting exactly this lookup."""
    return tuning.KernelTuner(tables={"cpu": {
        "version": tuning.TABLE_SCHEMA_VERSION, "backend": "cpu",
        "entries": [{"kernel": kernel, "dtype": jnp.dtype(dtype).name,
                     "bucket": list(tuning.bucket_for(kernel, shape)),
                     "params": params}]}})


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("cfgname", ["default", "table", "override"])
def test_elementwise_tuned_config_parity(dtype, cfgname):
    """parareal_update_residual under all three tuner resolution tiers:
    f32 main outputs are *bitwise* vs ref (same op order per element);
    the reduction partials differ only in summation order (tolerance)."""
    dt = jnp.dtype(dtype)
    shape = (3, 129)                 # non-lane-multiple -> padding path
    y, c, p, o = (jax.random.normal(k, shape, dt) for k in KEYS)
    if cfgname == "default":
        tuner = tuning.KernelTuner(table_dir="/nonexistent")
        want_src = "heuristic"
    elif cfgname == "table":
        tuner = _table_for("elementwise", dt, shape, {"tile_rows": 2})
        want_src = "table"
    else:
        tuner = tuning.KernelTuner(
            overrides={"elementwise": {"tile_rows": 1}})
        want_src = "override"
    assert tuner.resolve("elementwise", backend="cpu", dtype=dt,
                         shape=shape).source == want_src
    out_k, r_k = ops.parareal_update_residual(y, c, p, o, tuner=tuner,
                                              use_kernel=True)
    out_r, r_r = ref.parareal_update_residual(y, c, p, o)
    if dtype == "float32":
        assert np.array_equal(np.asarray(out_k), np.asarray(out_r))
    else:
        np.testing.assert_allclose(np.asarray(out_k, np.float32),
                                   np.asarray(out_r, np.float32),
                                   rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(r_k), float(r_r),
                               rtol=3e-2 if dtype == "bfloat16" else 1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("cfgname", ["default", "table", "override"])
def test_flash_tuned_config_parity(dtype, cfgname):
    """ops.attention under all three tuner resolution tiers on a
    non-tile-multiple GQA case (boundary buckets)."""
    b, hq, hkv, sq, sk, d, causal = 1, 4, 2, 33, 49, 16, True
    dt = jnp.dtype(dtype)
    q = jax.random.normal(KEYS[0], (b, hq, sq, d), dt)
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d), dt)
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d), dt)
    if cfgname == "default":
        tuner = tuning.KernelTuner(table_dir="/nonexistent")
        want_src = "heuristic"
    elif cfgname == "table":
        tuner = _table_for("flash", dt, (sq, sk, d),
                           {"block_q": 16, "block_k": 8})
        want_src = "table"
    else:
        tuner = TUNER32
        want_src = "override"
    assert tuner.resolve("flash", backend="cpu", dtype=dt,
                         shape=(sq, sk, d)).source == want_src
    out = ops.attention(q, k, v, causal=causal, tuner=tuner,
                        use_kernel=True)
    exp = ref.attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# GPU (Triton-structured) kernel family, exercised via interpret=True
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "case", FLASH_CASES,
    ids=lambda c: f"B{c[0]}H{c[1]}-{c[2]}S{c[3]}x{c[4]}D{c[5]}c{int(c[6])}")
def test_flash_gpu_family_interpret_parity(case, dtype):
    """The Triton-structured flash kernels (in-kernel KV loop, register
    carries) against the same oracle matrix as the TPU family — pinned on
    CPU via interpret=True, plat="gpu"."""
    b, hq, hkv, sq, sk, d, causal = case
    dt = jnp.dtype(dtype)
    q = jax.random.normal(KEYS[0], (b, hq, sq, d), dt)
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d), dt)
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d), dt)
    out = ops.attention(q, k, v, causal=causal, tuner=TUNER32, plat="gpu",
                        use_kernel=True)
    exp = ref.attention(q, k, v, causal=causal)
    assert out.shape == exp.shape and out.dtype == dt
    tol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [None, 7])
def test_flash_gpu_family_grads_and_window(window):
    """Backward parity for the GPU family (dq/dkv kernels with in-kernel
    loops), including the sliding-window live-tile loop bounds."""
    b, hq, hkv, sq, sk, d = 1, 4, 2, 33, 33, 8
    q = jax.random.normal(KEYS[0], (b, hq, sq, d))
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d))
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d))

    def loss(fn):
        return jax.grad(lambda q, k, v: jnp.sum(jnp.cos(fn(q, k, v))),
                        argnums=(0, 1, 2))(q, k, v)

    g_ref = loss(lambda q, k, v: ref.attention(q, k, v, causal=True,
                                               window=window))
    g_gpu = loss(lambda q, k, v: ops.attention(
        q, k, v, causal=True, window=window, tuner=TUNER32, plat="gpu",
        use_kernel=True))
    for a, bb in zip(g_ref, g_gpu):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-4, atol=2e-5)


def test_rwkv6_gpu_family_interpret_parity():
    """The streaming GPU WKV kernel (single fori_loop, register-resident
    state) vs the oracle — including a T that the TPU chunking would
    split, which the GPU family ignores."""
    bsz, h, t, dk, dv = 2, 2, 24, 8, 12
    ks = jax.random.split(KEYS[3], 5)
    r = jax.random.normal(ks[0], (bsz, h, t, dk))
    k = jax.random.normal(ks[1], (bsz, h, t, dk))
    v = jax.random.normal(ks[2], (bsz, h, t, dv))
    w = jax.random.normal(ks[3], (bsz, h, t, dk))
    u = jax.random.normal(ks[4], (h, dk))
    out_k, s_k = ops.rwkv6_wkv(r, k, v, w, u, plat="gpu", use_kernel=True)
    out_r, s_r = ref.rwkv6_wkv(r, k, v, w, u,
                               jnp.zeros((bsz, h, dk, dv), jnp.float32))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
