"""reprolint's own tests: fixture corpus, suppressions, CLI, meta-gate.

This module must collect and pass on a box with NO JAX installed
(``pytest tests/test_reprolint.py``): the linter is stdlib-only by
contract, and the CI lint leg runs it without installing anything.
"""
import ast
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (DEFAULT_PATHS, Finding, LintReport, hot_loop,
                            lint_paths, rule_table)
from repro.analysis.cli import main as cli_main
from repro.analysis.core import Suppressions, collect_aliases, qualname
from repro.analysis.rules import artifact_violations

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "lint_fixtures")


def lint_fixture(name):
    report = lint_paths([os.path.join(FIXTURES, name)], root=REPO)
    assert not report.errors, report.errors
    return report


def codes_and_lines(report):
    return sorted((f.code, f.line) for f in report.findings)


# ---------------------------------------------------------------- RL001

def test_rl001_fires_on_aliased_imports_the_grep_missed():
    report = lint_fixture("rl001_bad.py")
    assert {f.code for f in report.findings} == {"RL001"}
    lines = {f.line for f in report.findings}
    # import bindings: `from jax import tree_map`, shard_map alias
    assert {6, 7} <= lines
    # bare aliased call + tu.tree_map_with_path (grep-invisible) +
    # jax.make_mesh + .cost_analysis() + sm.shard_map
    assert {11, 15, 19, 23, 27} <= lines
    for f in report.findings:
        assert f.path == "tests/lint_fixtures/rl001_bad.py"
        assert f.rule == "compat-drift"


def test_rl001_clean_on_compat_routed_twin():
    report = lint_fixture("rl001_good.py")
    assert report.findings == []


def test_rl001_suppressions_are_recorded_not_discarded():
    report = lint_fixture("rl001_suppressed.py")
    assert report.findings == []
    assert codes_and_lines(
        LintReport(report.suppressed, [], 1, [])) == [("RL001", 6),
                                                      ("RL001", 11)]


# ---------------------------------------------------------------- RL002

def test_rl002_fires_on_seam_rederivation():
    report = lint_fixture("rl002_bad.py")
    assert codes_and_lines(report) == [
        ("RL002", 2),   # private helper imported across the seam
        ("RL002", 5),   # def parareal_update outside the engine
        ("RL002", 6),   # y + g_cur - g_prev by expression shape
    ]


def test_rl002_clean_on_public_seam_consumers():
    assert lint_fixture("rl002_good.py").findings == []


# ---------------------------------------------------------------- RL003

def test_rl003_fires_on_implicit_syncs_in_hot_loop():
    report = lint_fixture("rl003_bad.py")
    assert codes_and_lines(report) == [
        ("RL003", 12),  # float(device)
        ("RL003", 14),  # jax.device_get
        ("RL003", 15),  # np.asarray(device)
        ("RL003", 16),  # .item()
    ]


def test_rl003_clean_when_fetch_goes_through_the_seam():
    assert lint_fixture("rl003_good.py").findings == []


# ---------------------------------------------------------------- RL004

def test_rl004_fires_on_both_donation_forms():
    report = lint_fixture("rl004_bad.py")
    assert codes_and_lines(report) == [
        ("RL004", 10),  # jit-value form: `out + y` after step(y, g)
        ("RL004", 20),  # decorator form: `buf.sum()` after fused_step
    ]


def test_rl004_clean_on_rebind_idiom():
    assert lint_fixture("rl004_good.py").findings == []


# ---------------------------------------------------------------- RL005

def test_rl005_fires_on_adhoc_backend_probes():
    report = lint_fixture("rl005_bad.py")
    assert codes_and_lines(report) == [("RL005", 6), ("RL005", 10)]


def test_rl005_clean_on_fused_default():
    assert lint_fixture("rl005_good.py").findings == []


# ---------------------------------------------------------------- RL006

def test_rl006_fires_on_unmarked_heavy_tests():
    report = lint_fixture("test_rl006_bad.py")
    assert codes_and_lines(report) == [("RL006", 7), ("RL006", 11)]


def test_rl006_clean_on_marked_twins_and_fake_mesh():
    assert lint_fixture("test_rl006_good.py").findings == []


# ---------------------------------------------------------------- RL008

def test_rl008_fires_on_bare_model_evals_in_driver_shaped_code():
    report = lint_fixture("rl008_bad.py")
    assert codes_and_lines(report) == [("RL008", 6), ("RL008", 15)]
    for f in report.findings:
        assert f.rule == "model-eval-seam"
        assert "denoiser" in f.message


def test_rl008_clean_on_seam_consumers_and_non_eval_shapes():
    assert lint_fixture("rl008_good.py").findings == []


def test_rl008_suppressions_are_recorded_not_discarded():
    report = lint_fixture("rl008_suppressed.py")
    assert report.findings == []
    assert codes_and_lines(
        LintReport(report.suppressed, [], 1, [])) == [("RL008", 5)]


def test_rl008_scope_covers_drivers_and_serve_only():
    # models own their forward; tests/benchmarks probe whatever they like —
    # only drivers and the serving engine must go through the seam
    from repro.analysis.rules import _rl008_in_scope
    assert _rl008_in_scope("src/repro/core/parareal.py")
    assert _rl008_in_scope("src/repro/serve/diffusion.py")
    assert _rl008_in_scope("tests/lint_fixtures/rl008_bad.py")
    assert not _rl008_in_scope("src/repro/models/dit.py")
    assert not _rl008_in_scope("benchmarks/common.py")


# ---------------------------------------------------------------- RL009

def test_rl009_fires_on_out_of_seam_mixing_math():
    report = lint_fixture("rl009_bad.py")
    assert codes_and_lines(report) == [
        ("RL009", 2),   # private helper imported across the seam
        ("RL009", 6),   # def solve_gamma outside repro.core.accel
        ("RL009", 12),  # dense secant solve in driver-shaped code
    ]
    for f in report.findings:
        assert f.rule == "accel-seam-ownership"
        assert "repro.core.accel" in f.message


def test_rl009_clean_on_seam_consumers_and_non_solver_linalg():
    assert lint_fixture("rl009_good.py").findings == []


def test_rl009_suppressions_are_recorded_not_discarded():
    report = lint_fixture("rl009_suppressed.py")
    assert report.findings == []
    assert codes_and_lines(
        LintReport(report.suppressed, [], 1, [])) == [("RL009", 6)]


def test_rl009_scope_covers_drivers_and_serve_only():
    # the owner module itself is exempt; models/tests/benchmarks mix
    # whatever they probe — only drivers and the serving engine must go
    # through the Accelerator seam
    from repro.analysis.rules import _rl009_in_scope
    assert _rl009_in_scope("src/repro/core/engine.py")
    assert _rl009_in_scope("src/repro/serve/diffusion.py")
    assert _rl009_in_scope("tests/lint_fixtures/rl009_bad.py")
    assert not _rl009_in_scope("src/repro/models/dit.py")
    assert not _rl009_in_scope("benchmarks/table13_accel.py")


# ---------------------------------------------------------------- RL010

def test_rl010_fires_on_hardcoded_tile_literals():
    report = lint_fixture("rl010_bad.py")
    assert codes_and_lines(report) == [
        ("RL010", 6),   # block_q=32
        ("RL010", 6),   # block_k=32
        ("RL010", 10),  # chunk=16
        ("RL010", 14),  # block_rows=-8 (anchored at the call)
    ]
    for f in report.findings:
        assert f.rule == "kernel-tile-literals"
        assert "repro.kernels.tuning" in f.message


def test_rl010_clean_on_tuner_routed_twin():
    assert lint_fixture("rl010_good.py").findings == []


def test_rl010_suppressions_are_recorded_not_discarded():
    report = lint_fixture("rl010_suppressed.py")
    assert report.findings == []
    assert codes_and_lines(
        LintReport(report.suppressed, [], 1, [])) == [("RL010", 7)]


def test_rl010_kernel_package_owns_its_literals():
    # the tile constants themselves live in repro.kernels — the seam's
    # heuristics and wrapper defaults are the one legitimate home
    from repro.analysis.rules import _rl010_exempt
    assert _rl010_exempt("src/repro/kernels/ops.py")
    assert _rl010_exempt("src/repro/kernels/tuning.py")
    assert not _rl010_exempt("src/repro/core/engine.py")
    assert not _rl010_exempt("benchmarks/table14_kernels.py")
    assert not _rl010_exempt("tests/lint_fixtures/rl010_bad.py")


# ---------------------------------------------------------------- RL007

def test_rl007_pure_pattern_core():
    tracked = [
        "src/repro/compat.py",
        "src/repro/__pycache__/compat.cpython-311.pyc",
        "stale.pyc",
        ".pytest_cache/v/cache/lastfailed",
        "experiments/dryrun/run0.json",
        "experiments/real/keep.json",
        "docs/pycache_notes.md",
    ]
    assert artifact_violations(tracked) == [
        "src/repro/__pycache__/compat.cpython-311.pyc",
        "stale.pyc",
        ".pytest_cache/v/cache/lastfailed",
        "experiments/dryrun/run0.json",
    ]


# reprolint: disable=RL006
def test_rl007_fires_on_a_real_git_checkout(tmp_path):
    subprocess.run(["git", "init", "-q", str(tmp_path)], check=True)
    (tmp_path / "mod.pyc").write_bytes(b"\x00")
    (tmp_path / "ok.py").write_text("x = 1\n")
    subprocess.run(["git", "-C", str(tmp_path), "add", "-f", "."], check=True)
    report = lint_paths([], root=str(tmp_path))
    assert [(f.code, f.path) for f in report.findings] == [("RL007",
                                                            "mod.pyc")]
    assert "artifact lint FAILED" in report.findings[0].message


# ------------------------------------------------------- framework pieces

def test_suppression_directive_parsing():
    src = ("x = 1  # reprolint: disable=RL001\n"
           "# reprolint: disable=rl002, RL003\n"
           "y = 2\n"
           "# reprolint: disable-file=RL005\n")
    s = Suppressions(src)
    assert s.covers("RL001", 1)
    assert s.covers("RL002", 3) and s.covers("RL003", 3)   # next-line scope
    assert s.covers("RL005", 999)                          # file-level
    assert not s.covers("RL004", 1)


def test_alias_resolution_sees_through_imports():
    tree = ast.parse("from jax.experimental import shard_map as sm\n"
                     "import jax.tree_util as tu\n"
                     "from jax import tree_map\n")
    aliases = collect_aliases(tree)
    assert aliases["sm"] == "jax.experimental.shard_map"
    assert aliases["tu"] == "jax.tree_util"
    assert aliases["tree_map"] == "jax.tree_map"
    expr = ast.parse("sm.shard_map").body[0].value
    assert qualname(expr, aliases) == "jax.experimental.shard_map.shard_map"


def test_relative_imports_anchor_at_the_containing_package():
    tree = ast.parse("from .engine import parareal_update\n"
                     "from ..compat import tree\n")
    aliases = collect_aliases(tree, package="repro.core")
    assert aliases["parareal_update"] == "repro.core.engine.parareal_update"
    assert aliases["tree"] == "repro.compat.tree"


def test_hot_loop_marker_is_a_noop():
    def f():
        return 7
    g = hot_loop(f)
    assert g is f and f.__reprolint_hot_loop__ is True and f() == 7


def test_rule_registry_is_complete_and_ordered():
    codes = [c for c, _, _ in rule_table()]
    assert codes == [f"RL00{i}" for i in range(1, 10)] + ["RL010"]


def test_analysis_package_is_stdlib_only():
    """The whole point of the jax-free CI leg: no heavy import may creep in."""
    pkg = os.path.join(REPO, "src", "repro", "analysis")
    heavy = {"jax", "jaxlib", "numpy", "np", "scipy", "torch"}
    for fn in sorted(os.listdir(pkg)):
        if not fn.endswith(".py"):
            continue
        with open(os.path.join(pkg, fn), encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=fn)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                roots = [a.name.split(".")[0] for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                roots = [node.module.split(".")[0]]
            else:
                continue
            assert not (set(roots) & heavy), \
                f"{fn} imports a heavy dependency: {roots}"


# ------------------------------------------------------------------ CLI

def test_cli_json_output_exit_code_and_artifact(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "rl001_bad.py")
    out_file = tmp_path / "reprolint.json"
    rc = cli_main([bad, "--root", REPO, "--format", "json",
                   "--output", str(out_file)])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["version"] == 1
    assert payload["files_scanned"] == 1
    assert {f["code"] for f in payload["findings"]} == {"RL001"}
    assert {r["code"] for r in payload["rules"]} == \
        {f"RL00{i}" for i in range(1, 10)} | {"RL010"}
    assert json.loads(out_file.read_text())["findings"] == payload["findings"]


def test_cli_clean_fixture_exits_zero(capsys):
    rc = cli_main([os.path.join(FIXTURES, "rl001_good.py"), "--root", REPO])
    assert rc == 0
    assert "reprolint: clean" in capsys.readouterr().out


def test_cli_select_restricts_rules(capsys):
    bad = os.path.join(FIXTURES, "rl002_bad.py")
    rc = cli_main([bad, "--root", REPO, "--select", "RL005",
                   "--format", "json"])
    assert rc == 0
    assert json.loads(capsys.readouterr().out)["findings"] == []


def test_cli_unparseable_input_is_exit_2_not_a_pass(tmp_path, capsys):
    f = tmp_path / "broken.py"
    f.write_text("def (:\n")
    rc = cli_main([str(f), "--root", str(tmp_path)])
    capsys.readouterr()
    assert rc == 2


# reprolint: disable=RL006
def test_missing_linter_module_fails_loudly(tmp_path):
    """check.sh pipes any nonzero exit into a hard failure; a box where
    repro.analysis cannot import must not silently pass the gate."""
    env = dict(os.environ, PYTHONPATH=str(tmp_path))
    proc = subprocess.run([sys.executable, "-m", "repro.analysis"],
                          capture_output=True, text=True, env=env,
                          cwd=str(tmp_path))
    assert proc.returncode != 0


def test_check_sh_wired_to_reprolint_not_grep():
    with open(os.path.join(REPO, "scripts", "check.sh"),
              encoding="utf-8") as fh:
        text = fh.read()
    assert "python -m repro.analysis" in text
    assert "--lint-only" in text
    assert "grep -rnE" not in text            # old compat-policy grep gone
    assert "git ls-files | grep" not in text  # old artifact grep gone


# ------------------------------------------------------------- meta-gate

def test_live_tree_is_finding_free_modulo_recorded_suppressions():
    report = lint_paths(list(DEFAULT_PATHS), root=REPO)
    assert report.errors == []
    assert report.findings == [], "\n".join(
        f"{f.location()}: {f.code} {f.message}" for f in report.findings)
    # the suppressions that do exist are deliberate and stay visible
    for f in report.suppressed:
        assert isinstance(f, Finding)
