"""Core SRDS behaviour: exactness (Prop 1), prefix-exactness, convergence,
eval accounting, solvers, ParaDiGMS baseline."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DiffusionSchedule, ParaDiGMSConfig, SolverConfig,
                        SRDSConfig, make_schedule, paradigms_sample,
                        resolve_blocks, sample_sequential, solve,
                        solver_names, srds_sample, srds_stats)
from conftest import to_f64


def _model():
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8), dtype=jnp.float64) * 0.3

    def model_fn(x, t):
        return jnp.tanh(x @ w) * (0.5 + 0.001 * t)

    return model_fn


def _x0(batch=3):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, 8), dtype=jnp.float64)


@pytest.mark.parametrize("solver", ["ddim", "euler", "heun", "dpm2", "ddpm"])
@pytest.mark.parametrize("n", [16, 25, 36])
def test_srds_exact_equals_sequential(solver, n):
    """Prop 1: SRDS run to the iteration cap reproduces the sequential solve
    to machine precision, for every solver and grid size."""
    model = _model()
    sched = to_f64(make_schedule("ddpm_linear", n))
    cfg = SolverConfig(solver, noise_key=jax.random.PRNGKey(7))
    ref = sample_sequential(model, sched, cfg, _x0())
    res = srds_sample(model, sched, cfg, _x0(), SRDSConfig(tol=0.0))
    np.testing.assert_allclose(np.asarray(res.sample), np.asarray(ref),
                               rtol=0, atol=1e-10)
    b, _ = resolve_blocks(n, None)
    assert int(res.iterations) <= b


def test_prefix_exactness():
    """Prop 1's inductive core: after p refinements the first p block
    boundaries equal the sequential trajectory exactly."""
    model = _model()
    n, B = 32, 8
    sched = to_f64(make_schedule("ddpm_linear", n))
    cfg = SolverConfig("ddim")
    x0 = _x0()
    _, S = resolve_blocks(n, B)
    # sequential boundary values x_i = fine-solve up to grid i*S
    seq_bounds = [x0]
    x = x0
    for i in range(B):
        x = solve(model, sched, cfg, x, i * S, S, 1)
        seq_bounds.append(x)
    for p in range(1, B + 1):
        res = srds_sample(model, sched, cfg, x0,
                          SRDSConfig(tol=0.0, num_blocks=B, max_iters=p),
                          return_trajectory=True)
        for i in range(0, p + 1):
            np.testing.assert_allclose(np.asarray(res.trajectory[i]),
                                       np.asarray(seq_bounds[i]),
                                       rtol=0, atol=1e-10,
                                       err_msg=f"block {i} after {p} iters")


def test_early_convergence_monotone_history():
    model = _model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    cfg = SolverConfig("ddim")
    res = srds_sample(model, sched, cfg, _x0(), SRDSConfig(tol=1e-5))
    ref = sample_sequential(model, sched, cfg, _x0())
    it = int(res.iterations)
    assert it < 8, "smooth toy ODE should converge early"
    hist = np.asarray(res.delta_history)[:it]
    assert np.all(np.isfinite(hist))
    # residuals should be (weakly) decreasing on a smooth problem
    assert hist[-1] <= hist[0]
    assert float(jnp.mean(jnp.abs(res.sample - ref))) < 1e-4


def test_resolve_blocks_sqrt_and_divisor():
    assert resolve_blocks(1024, None) == (32, 32)
    assert resolve_blocks(25, None) == (5, 5)
    b, s = resolve_blocks(24, None)   # not a perfect square: nearest divisor of 24 to 4.9
    assert b * s == 24
    assert b in (4, 6)
    b, s = resolve_blocks(100, 10)
    assert (b, s) == (10, 10)


def test_resolve_blocks_prime_and_near_prime():
    """Regression: prime N used to snap silently to B=1 (fully serial).
    Auto selection now raises on primes and picks a nontrivial divisor for
    near-primes; an explicit non-divisor B is an error, while explicitly
    degenerate B=1 / B=N stay available."""
    with pytest.raises(ValueError, match="prime"):
        resolve_blocks(13, None)
    with pytest.raises(ValueError, match="prime"):
        resolve_blocks(37, None)
    # near-primes keep a genuinely parallel split
    assert resolve_blocks(14, None) == (2, 7)
    assert resolve_blocks(26, None) == (2, 13)
    b, s = resolve_blocks(15, None)
    assert b * s == 15 and 1 < b < 15
    # explicit non-divisors raise instead of silently snapping
    with pytest.raises(ValueError, match="does not divide"):
        resolve_blocks(13, 4)
    with pytest.raises(ValueError, match="does not divide"):
        resolve_blocks(100, 7)
    # explicitly-requested degenerate splits are honored
    assert resolve_blocks(13, 13) == (13, 1)
    assert resolve_blocks(13, 1) == (1, 13)


def test_eval_accounting_matches_paper_models():
    """Table-3 arithmetic: N=25 -> vanilla eff 15 (B + k(S+B), k=1),
    pipelined eff 9 (~B + k(S+1)-ish, paper reports 9)."""
    sched = make_schedule("ddpm_linear", 25)
    cfg = SRDSConfig(num_blocks=5)
    st = srds_stats(sched, SolverConfig("ddim"), cfg, iterations=1)
    assert st.serial_evals == 5 + 1 * (5 + 5)  # 15, matches Table 3 SRDS row
    assert st.total_evals == 5 + 1 * (25 + 5)
    stp = srds_stats(sched, SolverConfig("ddim"), cfg, iterations=1, pipelined=True)
    assert stp.serial_evals == 5 + 1 * (5 + 1)  # 11 eval-slots; paper's 9 counts
    # ramp overlap too — our wavefront measures supersteps directly in tests.
    st2 = srds_stats(sched, SolverConfig("heun"), cfg, iterations=2)
    assert st2.serial_evals == 2 * (5 + 2 * (5 + 5))


def test_solver_registry():
    assert set(solver_names()) >= {"ddim", "euler", "heun", "dpm2", "ddpm"}


def test_heun_more_accurate_than_ddim_on_coarse_grid():
    """2nd-order solver should beat 1st-order at equal (coarse) step count,
    measured against a very fine DDIM reference."""
    model = _model()
    fine = to_f64(make_schedule("karras", 512))
    coarse = to_f64(make_schedule("karras", 16))
    x0 = _x0()
    ref = sample_sequential(model, fine, SolverConfig("ddim"), x0)
    e_ddim = float(jnp.mean(jnp.abs(
        sample_sequential(model, coarse, SolverConfig("ddim"), x0) - ref)))
    e_heun = float(jnp.mean(jnp.abs(
        sample_sequential(model, coarse, SolverConfig("heun"), x0) - ref)))
    assert e_heun < e_ddim


def test_paradigms_converges_and_counts():
    model = _model()
    sched = to_f64(make_schedule("ddpm_linear", 32))
    cfg = SolverConfig("ddim")
    x0 = _x0(1)[0]
    ref = sample_sequential(model, sched, cfg, x0)
    res = paradigms_sample(model, sched, cfg, x0,
                           ParaDiGMSConfig(window=32, tol=1e-8))
    np.testing.assert_allclose(np.asarray(res.sample), np.asarray(ref),
                               rtol=0, atol=1e-5)
    assert int(res.iterations) <= 32  # never worse than sequential sweeps
    assert int(res.total_evals) >= 32


def test_ddpm_requires_key():
    model = _model()
    sched = to_f64(make_schedule("ddpm_linear", 16))
    with pytest.raises(ValueError):
        sample_sequential(model, sched, SolverConfig("ddpm"), _x0())


def test_schedules_shapes_and_monotonicity():
    for kind in ("ddpm_linear", "cosine", "karras"):
        s = make_schedule(kind, 64)
        assert s.num_steps == 64
        ab = np.asarray(s.ab)
        assert ab.shape == (65,)
        assert np.all(np.diff(ab) > 0), kind   # reversed grid: noise -> data
        assert ab[0] < 0.1 and ab[-1] > 0.9
