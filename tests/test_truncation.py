"""Converged-prefix truncation: the sliding-window hot loop must be
bit-identical to the untruncated engine while provably doing less work,
and the serve hot path must honor its host-traffic contract (one device
sync per refinement, completed-lane-only fetches, truncated accounting).

Bitwise tests use an elementwise denoiser (the repo's standard trick: lane
math is then identical across fine-solve batch widths, so any mismatch is
a real truncation bug, not an XLA gemm-kernel shape effect)."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolverConfig, SRDSConfig, iteration_cost,
                        make_schedule, predicted_evals, sample_sequential,
                        srds_sample, srds_stats, truncated_evals)
from repro.core.engine import prefix_frontier, run_parareal
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest
import repro.serve.diffusion as serve_diffusion
from conftest import to_f64

TOLS = [1e-2, 1e-4, 1e-6, 1e-3, 1e-5]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _x0(batch=3, dim=8):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, dim),
                             dtype=jnp.float64)


# --------------------------------------------------------------------------
# engine / srds_sample
# --------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["ddim", "heun"])
@pytest.mark.parametrize("tol", [0.0, 1e-4])
def test_truncated_bit_identical_to_untruncated(solver, tol):
    """The tentpole guarantee: same sample, iterations and delta_history as
    the while_loop engine, for every solver/tolerance combination."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    cfg = SolverConfig(solver)
    a = srds_sample(model, sched, cfg, _x0(), SRDSConfig(tol=tol))
    b = srds_sample(model, sched, cfg, _x0(), SRDSConfig(tol=tol,
                                                         truncate=True))
    assert bool(jnp.all(a.sample == b.sample))
    assert int(a.iterations) == int(b.iterations)
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(b.delta_history))
    assert float(a.final_delta) == float(b.final_delta)


def test_truncated_exact_to_cap_equals_sequential():
    """Prop 1 survives truncation: tol=0 run to the cap reproduces the
    sequential solve."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 36))
    ref = sample_sequential(model, sched, SolverConfig("ddim"), _x0())
    res = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                      SRDSConfig(tol=0.0, truncate=True))
    np.testing.assert_allclose(np.asarray(res.sample), np.asarray(ref),
                               rtol=0, atol=1e-12)


def test_truncated_per_sample_gating_bit_identical():
    """Truncation composes with per-sample convergence gating under a
    mixed-tolerance vector."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    X = _x0(len(TOLS)) * jnp.linspace(0.3, 2.5, len(TOLS))[:, None]
    tols = jnp.asarray(TOLS, jnp.float32)
    a = srds_sample(model, sched, SolverConfig("ddim"), X,
                    SRDSConfig(per_sample=True), tol=tols)
    b = srds_sample(model, sched, SolverConfig("ddim"), X,
                    SRDSConfig(per_sample=True, truncate=True), tol=tols)
    assert len(set(int(i) for i in a.iterations)) > 1
    assert bool(jnp.all(a.sample == b.sample))
    np.testing.assert_array_equal(np.asarray(a.iterations),
                                  np.asarray(b.iterations))
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(b.delta_history))


def test_truncated_fixed_iters_bit_identical():
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    a = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                    SRDSConfig(fixed_iters=True, max_iters=5))
    b = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                    SRDSConfig(fixed_iters=True, max_iters=5, truncate=True))
    assert bool(jnp.all(a.sample == b.sample))
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(b.delta_history))


def test_truncate_rejects_incompatible_modes():
    """block_sharding (GSPMD constraint) and straggler reuse keep the
    while_loop path — truncating them must fail loudly."""
    fine = lambda h, p, y: h
    G = lambda x, i0: x
    starts = jnp.arange(4, dtype=jnp.int32)
    x0 = jnp.ones((2,))
    with pytest.raises(ValueError, match="block-sharding"):
        run_parareal(G, fine, x0, starts, tol=0.0, max_iters=2,
                     constrain=lambda t: t, truncate=True)
    with pytest.raises(ValueError, match="carry_fine_results"):
        run_parareal(G, fine, x0, starts, tol=0.0, max_iters=2,
                     carry_fine_results=True, truncate=True)


# --------------------------------------------------------------------------
# accounting
# --------------------------------------------------------------------------

def test_frontier_schedule_and_truncated_accounting():
    """The frontier advances one block per refinement, one refinement
    behind exactness (bitwise stability needs the second recomputation);
    truncated totals are strictly below untruncated ones from the third
    refinement on and floor at one live block per refinement."""
    assert [prefix_frontier(p) for p in range(5)] == [0, 0, 1, 2, 3]
    cost = iteration_cost(100, None, 1)          # B=10, S=10
    assert cost.num_blocks == 10 and cost.fine_steps == 10
    assert cost.refine_evals_at(0) == cost.refine_evals == 110
    assert cost.refine_evals_at(3) == 7 * 11
    assert cost.refine_evals_at(99) == 1 * 11    # floor: last block lives
    assert truncated_evals(cost, 0) == cost.init_evals
    assert truncated_evals(cost, 2) == predicted_evals(cost, 2)
    for k in range(3, 11):
        assert truncated_evals(cost, k) < predicted_evals(cost, k)
    # the headline: >= 25% fewer physical evals at N=100 run to the cap
    assert truncated_evals(cost, 10) <= 0.75 * predicted_evals(cost, 10)
    # continuous extension for EMA estimates
    assert truncated_evals(cost, 2.5) == \
        truncated_evals(cost, 2) + 0.5 * cost.refine_evals_at(1)
    # srds_stats rides the same arithmetic
    sched = make_schedule("ddpm_linear", 100)
    st = srds_stats(sched, SolverConfig("ddim"), SRDSConfig(truncate=True), 10)
    assert st.total_evals == truncated_evals(cost, 10)
    st_u = srds_stats(sched, SolverConfig("ddim"), SRDSConfig(), 10)
    assert st.serial_evals < st_u.serial_evals


# --------------------------------------------------------------------------
# the serve hot path
# --------------------------------------------------------------------------

class _FetchCounter:
    """Monkeypatch hook for repro.serve.diffusion._host_fetch: records one
    entry (the fetched array's shape) per device->host sync."""

    def __init__(self, real):
        self.real = real
        self.shapes = []

    def __call__(self, x):
        out = self.real(x)
        self.shapes.append(out.shape)
        return out


def _engine(model, **kw):
    kw.setdefault("batch_size", 3)
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, dtype=jnp.float64, **kw)


def test_step_once_single_host_sync_per_iteration(monkeypatch):
    """The serve hot loop performs exactly ONE device sync (the batched
    (K,) residual) per refinement, plus one per completed request — and
    the completion fetch is the lane's final state only, never a
    trajectory- or batch-shaped tensor."""
    model = _elementwise_model()
    counter = _FetchCounter(serve_diffusion._host_fetch)
    monkeypatch.setattr(serve_diffusion, "_host_fetch", counter)
    eng = _engine(model)
    rids = [eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
            for i in range(5)]
    queue = eng.pull_queue()
    for rid, req in queue[:eng.batch_size]:
        eng.admit(rid, req)
    queue = queue[eng.batch_size:]
    done = {}
    while eng.busy() or queue:
        while queue and eng.free_slots(queue[0][1]) > 0:
            rid, req = queue.pop(0)
            eng.admit(rid, req)
        before = len(counter.shapes)
        completions = eng.step_once()
        done.update(dict(completions))
        fetched = counter.shapes[before:]
        # exactly 1 residual sync + 1 lane fetch per completion
        assert len(fetched) == 1 + len(completions), fetched
        assert fetched[0] == (eng.batch_size,)           # (K,) residuals
        for shp in fetched[1:]:
            assert shp == (8,), shp                      # one lane's sample
    assert set(done) == set(rids)
    for rid in rids:
        assert done[rid].sample.shape == (8,)


def test_serve_truncated_engine_bit_identical_and_cheaper():
    """truncate=True (the default) vs truncate=False: identical responses
    (samples, iterations, history), strictly fewer physical evals on a
    drain whose tail advances the group frontier."""
    model = _elementwise_model()
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]) for i in range(6)]

    def run(**kw):
        eng = _engine(model, truncate_quantum=1, **kw)
        rids = [eng.submit(r) for r in reqs]
        out = eng.drain()
        return [out[r] for r in rids], eng.stats()

    trunc, st_t = run()
    plain, st_p = run(truncate=False)
    for a, b in zip(trunc, plain):
        assert np.array_equal(a.sample, b.sample)
        assert a.iterations == b.iterations
        np.testing.assert_array_equal(a.delta_history, b.delta_history)
    assert st_t["physical_evals"] < st_p["physical_evals"]
    # billing follows the engine's mode: truncated schedule for the
    # truncating engine, the flat untruncated rate for truncate=False
    # (whose programs really do run full-width refinements)
    cost = iteration_cost(64, None, 1)
    for r in trunc:
        assert r.model_evals == truncated_evals(cost, r.iterations)
    for r in plain:
        assert r.model_evals == predicted_evals(cost, r.iterations)
    assert st_p["effective_evals"] == sum(r.model_evals for r in plain)


def test_serve_truncation_quantum_bounds_program_cache():
    """The quantized frontier compiles at most ~B/quantum step variants
    (all of them multiples of the quantum)."""
    model = _elementwise_model()
    eng = _engine(model, truncate_quantum=4)    # B=8 -> minf in {0, 4}
    for i in range(4):
        eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
    eng.drain()
    (_, step_for, B, _) = eng._programs[next(iter(eng._programs))]
    assert B == 8
    assert set(step_for.cache) <= {0, 4}
    # the default quantum is B//4 -> at most 4 variants
    eng2 = _engine(model)
    for i in range(3):
        eng2.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
    eng2.drain()
    (_, step_for2, _, _) = eng2._programs[next(iter(eng2._programs))]
    assert set(step_for2.cache) <= {0, 2, 4, 6}


def test_serve_block_axis_disables_truncation():
    """Block-parallel fine solves slice the full block dim per device, so
    the engine must force truncation off rather than mis-shard."""
    model = _elementwise_model()
    eng = DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64, mesh=None, axis=None)
    assert eng.truncate
    # axis set (mesh checked lazily at program build) -> truncation off
    eng2 = DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, batch_size=2,
                                   dtype=jnp.float64, mesh=object(),
                                   axis="time")
    assert not eng2.truncate
