"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import pytest

# tests/test_reprolint.py (and the CI lint leg) must collect and run on a
# box with no JAX at all — the heavy imports are optional at conftest level
# and every JAX-dependent test module fails loudly on its own import.
try:
    import jax
    import jax.numpy as jnp
except ImportError:      # pragma: no cover - exercised on the lint-only leg
    jax = jnp = None

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the linter's seeded-violation corpus is data, not tests
collect_ignore = ["lint_fixtures"]


def pytest_configure(config):
    """CI tiers (see scripts/check.sh): ``--fast`` runs
    ``-m "not slow and not distributed"``; the full leg runs everything."""
    config.addinivalue_line(
        "markers", "slow: long-running test (excluded by check.sh --fast)")
    config.addinivalue_line(
        "markers", "distributed: spawns subprocesses with fake multi-device "
        "meshes (excluded by check.sh --fast)")


def run_subprocess(code: str, devices: int = 8, timeout: int = 900, env_extra=None):
    """Run a python snippet with N fake devices; return CompletedProcess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture(scope="session")
def toy_model():
    """A smooth nonlinear eps-predictor for solver/SRDS math tests (f32)."""
    if jax is None:
        pytest.skip("jax not installed")
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 8)) * 0.3

    def model_fn(x, t):
        return jnp.tanh(x @ w) * (0.5 + 0.001 * t)

    return model_fn


def to_f64(sched):
    from repro.core.schedules import DiffusionSchedule
    return DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                             t_model=sched.t_model.astype(jnp.float64),
                             kind=sched.kind)
