"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting shapes and no NaNs (the FULL configs are
exercised only via the dry-run)."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops

kops.FORCE_REF = True   # pure-jnp attention on CPU smoke paths

from repro.configs import arch_names, get_arch, shape_cells, SHAPES
from repro.data import DataConfig, make_stream
from repro.models import (decode_step, forward_train, init_dit, init_params,
                          prefill)
from repro.models.dit import dit_forward
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step

ASSIGNED = ["stablelm-3b", "qwen1.5-32b", "qwen3-8b", "qwen3-14b",
            "phi-3-vision-4.2b", "rwkv6-1.6b", "hymba-1.5b", "arctic-480b",
            "kimi-k2-1t-a32b", "hubert-xlarge"]

KEY = jax.random.PRNGKey(0)


def _reduced_with_prefix(cfg):
    red = cfg.reduced()
    return red


def _batch(cfg, b=2, s=32):
    stream = make_stream(cfg, DataConfig(global_batch=b, seq_len=s, seed=3))
    return stream.batch(0)


def test_all_assigned_archs_registered():
    names = arch_names()
    for a in ASSIGNED:
        assert a in names, a


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_dimensions(arch):
    """The registered config carries the exact published dimensions."""
    cfg = get_arch(arch)
    expected = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    if arch == "arctic-480b":
        assert (cfg.moe_experts, cfg.moe_top_k) == (128, 2)
        assert cfg.moe_dense_residual
    if arch == "kimi-k2-1t-a32b":
        assert (cfg.moe_experts, cfg.moe_top_k) == (384, 8)
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16 and cfg.block == "hymba"
    if arch == "hubert-xlarge":
        assert cfg.is_encoder_only


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    cfg = _reduced_with_prefix(get_arch(arch))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux, _ = forward_train(cfg, params, batch)
    b, s = (batch.get("tokens", batch.get("features"))).shape[:2]
    assert logits.shape == (b, s, cfg.padded_vocab(1))
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    """One optimizer step: finite loss, params actually change, no NaNs."""
    cfg = _reduced_with_prefix(get_arch(arch))
    params = init_params(cfg, KEY)
    opt = init_opt_state(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), use_kernel=False)
    batch = _batch(cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch, KEY)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_opt["step"]) == 1
    # at least one leaf moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if not get_arch(a).is_encoder_only])
def test_smoke_prefill_decode(arch):
    """prefill + decode_step reproduce the full-sequence last-token logits."""
    cfg = _reduced_with_prefix(get_arch(arch))
    params = init_params(cfg, KEY)
    s = 48
    batch = _batch(cfg, s=s)
    logits, _, _ = forward_train(cfg, params, batch)
    b2 = {k: (v[:, :s - 1] if k in ("tokens", "features") else v)
          for k, v in batch.items()}
    _, cache = prefill(cfg, params, b2)
    if cfg.block == "attn_mlp":
        k_c, v_c = cache
        cache = (jnp.pad(k_c, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
                 jnp.pad(v_c, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))))
    tok = {"tokens": batch["tokens"][:, s - 1:s]} if "tokens" in batch else \
        {"features": batch["features"][:, s - 1:s]}
    lg, _ = decode_step(cfg, params, tok, cache, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_shape_cell_skip_rules():
    """Assignment skip rules: long_500k only for sub-quadratic archs;
    no decode for encoder-only."""
    cells = {a: [s.name for s in shape_cells(get_arch(a))] for a in ASSIGNED}
    for a in ("rwkv6-1.6b", "hymba-1.5b"):
        assert "long_500k" in cells[a]
    for a in ("stablelm-3b", "qwen3-8b", "arctic-480b", "kimi-k2-1t-a32b"):
        assert "long_500k" not in cells[a]
    assert cells["hubert-xlarge"] == ["train_4k", "prefill_32k"]
    # 31 runnable assigned cells total
    assert sum(len(v) for v in cells.values()) == 31


def test_head_padding_rules():
    assert get_arch("hymba-1.5b").padded_heads(16) == (32, 8)
    assert get_arch("qwen1.5-32b").padded_heads(16) == (48, 48)
    assert get_arch("arctic-480b").padded_heads(16) == (64, 8)
    assert get_arch("qwen3-8b").padded_heads(16) == (32, 8)
    assert get_arch("stablelm-3b").padded_heads(16) == (32, 32)
    # no padding at TP=1
    assert get_arch("hymba-1.5b").padded_heads(1) == (25, 5)


def test_param_counts_sane():
    """Param-count model used for roofline MODEL_FLOPS is in the right
    ballpark (matching the archs' nameplate sizes)."""
    approx = {
        "stablelm-3b": (2.0e9, 4.5e9),
        "qwen3-8b": (6e9, 10e9),
        "qwen3-14b": (12e9, 17e9),
        "qwen1.5-32b": (28e9, 38e9),
        "arctic-480b": (3.5e11, 5.5e11),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "hymba-1.5b": (1.0e9, 2.1e9),
        "hubert-xlarge": (0.7e9, 1.4e9),
    }
    for a, (lo, hi) in approx.items():
        n = get_arch(a).param_count()
        assert lo < n < hi, (a, n)
    k = get_arch("kimi-k2-1t-a32b")
    assert k.active_param_count() < 0.1 * k.param_count()


def test_dit_smoke_train():
    cfg = dc.replace(get_arch("srds-dit-cifar").reduced(), patch_size=2,
                     in_channels=3)
    params = init_dit(cfg, KEY)
    from repro.train.losses import diffusion_loss
    batch = {"images": jax.random.normal(KEY, (2, 8, 8, 3))}
    (loss, m), grads = jax.value_and_grad(
        lambda p: diffusion_loss(cfg, p, batch, KEY, use_kernel=False),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))
