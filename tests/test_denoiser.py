"""Denoiser seam unit tests: adapter semantics, mesh-requirement errors,
and the spec composition in parallel.sharding (single-device — the
multi-device numerics live in test_distributed_srds.py)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh
from repro.core.denoiser import Denoiser, as_denoiser
from repro.parallel.sharding import denoiser_spec, microbatch_spec


def _fn(x, t):
    return x * t


def _shard_fn(x, t):
    return x * t


MESH3 = lambda: make_mesh((1, 1, 1), ("time", "data", "model"))


# ------------------------------------------------------------ adapter

def test_as_denoiser_adapts_plain_fn_and_is_identity_on_denoisers():
    den = as_denoiser(_fn)
    assert isinstance(den, Denoiser)
    assert not den.is_model_parallel
    assert as_denoiser(den) is den
    x = jnp.arange(4.0)
    assert jnp.array_equal(den(x, 2.0), _fn(x, 2.0))
    # plain denoisers short-circuit every composition mode to fn itself
    assert den.inner_eval() is _fn
    assert den.shard_eval() is _fn


def test_denoiser_with_mesh_axes_requires_shard_fn():
    with pytest.raises(ValueError, match="needs a shard_fn"):
        Denoiser(fn=_fn, mesh_axes={"model": 2})


def test_standalone_model_parallel_call_requires_bound_mesh():
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 1})
    with pytest.raises(ValueError, match="bound"):
        den(jnp.ones((2, 2)), 0.5)


# ------------------------------------------------------- mesh validation

def test_check_mesh_names_the_missing_axis():
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 1})
    mesh = make_mesh((1,), ("time",))
    with pytest.raises(ValueError, match=r"mesh axis 'model'.*\('time',\)"):
        den.check_mesh(mesh)


def test_check_mesh_enforces_min_size():
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 2})
    with pytest.raises(ValueError, match="size >= 2"):
        den.check_mesh(MESH3())
    with pytest.raises(ValueError, match="size >= 2"):
        den.bind(MESH3())       # binding validates too


# ------------------------------------------- spec composition + validation

def test_microbatch_spec_validates_axis_is_bound():
    assert microbatch_spec("data", mesh=MESH3()) == P(None, "data")
    with pytest.raises(ValueError, match=r"'dp' is not bound.*'time', "
                                         r"'data', 'model'"):
        microbatch_spec("dp", mesh=MESH3())


def test_denoiser_spec_composes_data_and_model_axes():
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 1})
    # sample layout (K, H, W, C): in_spec's K entry drops, H shifts onto
    # the heads tensor's dim 2 -> (B, K, H, ...) = (None, data, model)
    assert denoiser_spec("data", den, mesh=MESH3()) == P(None, "data",
                                                         "model")
    # degraded forms: plain fn / no denoiser == microbatch_spec
    assert denoiser_spec("data", _fn) == P(None, "data")
    assert denoiser_spec("data") == P(None, "data")
    assert denoiser_spec(None, den) == P(None, None, "model")


def test_denoiser_spec_rejects_sample_batch_sharding_and_unbound_axes():
    den_bad = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P("model",),
                       out_spec=P("model",), mesh_axes={"model": 1})
    with pytest.raises(ValueError, match="owns that dim via data_axis"):
        denoiser_spec("data", den_bad)
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "mp"),
                   out_spec=P(None, "mp"), mesh_axes={"mp": 1})
    with pytest.raises(ValueError, match="mesh axis 'mp'"):
        denoiser_spec("data", den, mesh=MESH3())


# -------------------------------------------------- engine entry validation

def test_serving_engine_rejects_unbound_data_axis_and_meshless_mp():
    from repro.serve.diffusion import DiffusionSamplingEngine
    with pytest.raises(ValueError, match="'dp' is not bound"):
        DiffusionSamplingEngine(_fn, (4,), num_steps=8, batch_size=1,
                                mesh=MESH3(), data_axis="dp")
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 1})
    with pytest.raises(ValueError, match="needs a mesh"):
        DiffusionSamplingEngine(den, (2, 2), num_steps=8, batch_size=1)


def test_sharded_driver_rejects_mesh_missing_model_axis():
    from repro.core import SRDSConfig, SolverConfig, make_schedule
    from repro.core.pipelined import make_sharded_sampler
    den = Denoiser(fn=_fn, shard_fn=_shard_fn, in_spec=P(None, "model"),
                   out_spec=P(None, "model"), mesh_axes={"model": 1})
    sched = make_schedule("ddpm_linear", 8)
    with pytest.raises(ValueError, match="mesh axis 'model'"):
        make_sharded_sampler(make_mesh((1,), ("time",)), "time", den, sched,
                             SolverConfig("ddim"), SRDSConfig(num_blocks=4))
