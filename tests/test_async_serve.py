"""The asynchronous serving loop and the pluggable clock.

Covers the wall-clock edge cases the async tentpole introduces:

* the Clock seam — swapping an explicit ``VirtualClock`` in leaves
  ``simulate()`` bit-identical to the default-constructed engine (the
  PR 7 baseline behaviour), and ``simulate()`` refuses wall clocks;
* the pipelined dispatch/resolve path is bit-exact vs the synchronous
  engine (samples, iterations, eval totals) on a virtual clock, where
  the comparison is deterministic;
* one host sync per refinement still holds under pipelining — counted
  through the ``_host_fetch`` seam exactly like the synchronous test;
* ``deadline_wall`` resolution (``request_deadline``), rejection of a
  request already hopeless at admission, and eviction firing on a wall
  deadline that passes mid-refinement.

Wall-clock *numbers* are noisy by nature, so the MonotonicClock tests
assert structure (who completed, who was rejected/evicted, monotone
time) — never absolute seconds; ordering-level latency claims live in
``benchmarks/table10_wallclock.py``.
"""
import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverConfig
from repro.serve import (EDF, FIFO, AsyncServeLoop, CostAware,
                         DiffusionSamplingEngine, MonotonicClock,
                         SampleRequest, Tier, VirtualClock, poisson_trace,
                         simulate)
from repro.serve import diffusion as serve_diffusion
from repro.core.window import ResidualWindow

TIERS = [Tier(tol=1e-2, slo_ms=25, iters_hint=2, weight=0.9),
         Tier(tol=1e-6, slo_ms=400, iters_hint=7, weight=0.1)]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _engine(model, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("sec_per_eval", 1e-5)
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, dtype=jnp.float64, **kw)


def _trace(n=12, rate=300.0, seed=0):
    return poisson_trace(n, rate=rate, tiers=TIERS, seed=seed)


# --------------------------------------------------------------------------
# the Clock seam
# --------------------------------------------------------------------------

def test_virtual_clock_swap_simulate_bit_identical():
    """An engine built with an explicit VirtualClock() reproduces the
    default engine's simulate() output bit for bit — the clock refactor
    must not perturb the PR 7 discrete-event baseline (same latencies,
    same samples, same eval counters)."""
    model = _elementwise_model()
    trace = _trace()
    rep_default = simulate(_engine(model), trace, EDF())
    rep_explicit = simulate(_engine(model, clock=VirtualClock()), trace, EDF())
    assert sorted(rep_default.responses) == sorted(rep_explicit.responses)
    assert rep_default.latency_p50 == rep_explicit.latency_p50
    assert rep_default.latency_p95 == rep_explicit.latency_p95
    assert rep_default.makespan == rep_explicit.makespan
    assert rep_default.effective_evals == rep_explicit.effective_evals
    assert rep_default.physical_evals == rep_explicit.physical_evals
    for rid in rep_default.responses:
        a, b = rep_default.responses[rid], rep_explicit.responses[rid]
        assert a.latency == b.latency
        assert a.iterations == b.iterations
        assert np.array_equal(np.asarray(a.sample), np.asarray(b.sample))


def test_simulate_refuses_wall_clock():
    model = _elementwise_model()
    eng = _engine(model, clock=MonotonicClock())
    with pytest.raises(ValueError, match="VirtualClock"):
        simulate(eng, _trace(n=2))


def test_request_deadline_resolution_per_clock():
    """deadline is virtual-clock absolute, deadline_wall is wall-clock
    absolute; each engine resolves its own regime and both fall back to
    arrival-relative slo_ms."""
    model = _elementwise_model()
    virt = _engine(model)
    wall = _engine(model, clock=MonotonicClock())
    req = SampleRequest(seed=0, arrival_time=1.0, slo_ms=100.0,
                        deadline=5.0, deadline_wall=9.0)
    assert virt.request_deadline(req) == 5.0
    assert wall.request_deadline(req) == 9.0
    # slo fallback when the matching absolute deadline is absent
    req2 = SampleRequest(seed=0, arrival_time=1.0, slo_ms=100.0)
    assert virt.request_deadline(req2) == pytest.approx(1.1)
    assert wall.request_deadline(req2) == pytest.approx(1.1)
    # a virtual deadline does not leak into the wall regime
    req3 = SampleRequest(seed=0, deadline=5.0)
    assert wall.request_deadline(req3) == math.inf
    assert virt.request_deadline(req3) == 5.0


# --------------------------------------------------------------------------
# pipelined dispatch/resolve == synchronous engine (deterministic, virtual)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy_cls", [FIFO, EDF])
def test_async_loop_bit_exact_vs_simulate(policy_cls):
    """On a virtual clock the async loop must reproduce simulate()'s
    samples and iteration counts bit-exactly: speculative refinements of
    already-converged lanes are never observable.  (Latencies may differ
    — completions are discovered one dispatch later — but the math may
    not.)"""
    model = _elementwise_model()
    trace = _trace(n=10)
    sync = simulate(_engine(model), trace, policy_cls())
    rep = AsyncServeLoop(_engine(model), policy_cls()).run(trace)
    assert sorted(rep.responses) == sorted(sync.responses)
    for rid in sync.responses:
        a, b = sync.responses[rid], rep.responses[rid]
        assert a.iterations == b.iterations
        assert a.final_delta == b.final_delta
        assert np.array_equal(np.asarray(a.sample), np.asarray(b.sample))


def test_async_loop_bit_exact_under_residual_window():
    """The shared residual window survives pipelining: the epoch guard
    keeps an in-flight resolve from clobbering an admission's window
    re-open, and responses still match the synchronous engine."""
    model = _elementwise_model()
    trace = _trace(n=8)
    mk = lambda: _engine(model, window=ResidualWindow(1e-8))
    sync = simulate(mk(), trace, FIFO())
    rep = AsyncServeLoop(mk(), FIFO()).run(trace)
    assert sorted(rep.responses) == sorted(sync.responses)
    for rid in sync.responses:
        a, b = sync.responses[rid], rep.responses[rid]
        assert a.iterations == b.iterations
        assert np.array_equal(np.asarray(a.sample), np.asarray(b.sample))


def test_async_loop_deterministic_on_virtual_clock():
    """Two async runs on fresh virtual-clock engines agree exactly —
    the pipelined loop adds no nondeterminism of its own."""
    model = _elementwise_model()
    trace = _trace(n=10)
    r1 = AsyncServeLoop(_engine(model), EDF()).run(trace)
    r2 = AsyncServeLoop(_engine(model), EDF()).run(trace)
    assert r1.latency_p95 == r2.latency_p95
    assert r1.makespan == r2.makespan
    assert r1.physical_evals == r2.physical_evals
    for rid in r1.responses:
        assert np.array_equal(np.asarray(r1.responses[rid].sample),
                              np.asarray(r2.responses[rid].sample))


def test_async_max_inflight_one_degenerates_to_sync_discipline():
    """max_inflight=1 serializes dispatch/resolve — the A/B control for
    the overlap itself — and still completes everything exactly."""
    model = _elementwise_model()
    trace = _trace(n=6)
    sync = simulate(_engine(model), trace, FIFO())
    rep = AsyncServeLoop(_engine(model), FIFO(), max_inflight=1).run(trace)
    assert sorted(rep.responses) == sorted(sync.responses)
    for rid in sync.responses:
        assert np.array_equal(np.asarray(sync.responses[rid].sample),
                              np.asarray(rep.responses[rid].sample))


# --------------------------------------------------------------------------
# one host sync per refinement, under pipelining
# --------------------------------------------------------------------------

class _FetchCounter:
    """Monkeypatch hook for repro.serve.diffusion._host_fetch: records one
    entry (the fetched array's shape) per device->host sync."""

    def __init__(self, real):
        self.real = real
        self.shapes = []

    def __call__(self, x):
        out = self.real(x)
        self.shapes.append(out.shape)
        return out


def test_async_loop_one_sync_per_refinement(monkeypatch):
    """Pipelining must not add syncs: across a whole async run the fetch
    count is exactly one (K,) residual per resolved refinement plus one
    (shape,) final-state fetch per completion — and dispatching performs
    none (every recorded fetch is residual- or lane-shaped)."""
    model = _elementwise_model()
    counter = _FetchCounter(serve_diffusion._host_fetch)
    monkeypatch.setattr(serve_diffusion, "_host_fetch", counter)
    eng = _engine(model)
    K = eng.batch_size
    rep = AsyncServeLoop(eng, FIFO()).run(_trace(n=7))
    n_completions = len(rep.responses)
    residual_fetches = [s for s in counter.shapes if s == (K,)]
    lane_fetches = [s for s in counter.shapes if s == (8,)]
    assert len(lane_fetches) == n_completions
    assert len(residual_fetches) + len(lane_fetches) == len(counter.shapes), \
        f"unexpected fetch shapes: {set(counter.shapes)}"
    # one residual sync per refinement: total refinements resolved equals
    # the physical step count implied by the engine's accounting; at
    # minimum every completed request's iteration count is covered
    assert len(residual_fetches) >= max(r.iterations
                                        for r in rep.responses.values())


# --------------------------------------------------------------------------
# wall-clock edge cases (structure-only assertions; no absolute seconds)
# --------------------------------------------------------------------------

def test_wall_deadline_hopeless_at_admission_rejected():
    """A request whose deadline_wall already passed at admission is shed
    by CostAware admission control before burning a slot."""
    model = _elementwise_model()
    eng = _engine(model, clock=MonotonicClock())
    trace = [SampleRequest(seed=0, tol=1e-2, arrival_time=0.0,
                           deadline_wall=-1.0),       # already hopeless
             SampleRequest(seed=1, tol=1e-2, arrival_time=0.0)]
    rep = AsyncServeLoop(eng, CostAware(slack=1.0)).run(trace)
    assert rep.rejected == [0]
    assert sorted(rep.responses) == [1]
    assert rep.responses[1].status == "ok"


def test_wall_deadline_eviction_mid_refinement():
    """A running request whose wall deadline passes mid-refinement is
    evicted by CostAware(preempt=True) when a feasible same-group waiter
    is starved of slots."""
    model = _elementwise_model()
    # batch_size=1 so the second request genuinely starves
    eng = _engine(model, batch_size=1, clock=MonotonicClock())
    trace = [
        # feasible at admission (cost model predicts ~5 ms of virtual
        # work) but the first refinement's real JIT compile alone takes
        # far longer than 20 ms of wall time, so the deadline is past by
        # the next preemption round
        SampleRequest(seed=0, tol=1e-6, arrival_time=0.0,
                      deadline_wall=0.02),
        # same compat group, no deadline (always feasible), starved while
        # request 0 holds the only slot
        SampleRequest(seed=1, tol=1e-2, arrival_time=0.0),
    ]
    rep = AsyncServeLoop(eng, CostAware(slack=1.0, preempt=True)).run(trace)
    assert rep.preempted == [0]
    assert sorted(rep.responses) == [1]
    assert rep.responses[1].status == "ok"
    # the evicted lane's still-in-flight refinement resolved as
    # speculative waste without corrupting the survivor: its sample is
    # bit-exact vs a fresh single-request run
    solo = simulate(_engine(model, batch_size=1),
                    [SampleRequest(seed=1, tol=1e-2)])
    assert np.array_equal(np.asarray(rep.responses[1].sample),
                          np.asarray(solo.responses[0].sample))


def test_wall_clock_monotone_and_latency_stamps():
    """Wall-clock runs stamp real, monotone, non-negative times: finish
    >= arrival for every completion and the engine clock only moves
    forward."""
    model = _elementwise_model()
    eng = _engine(model, clock=MonotonicClock())
    t0 = eng.clock
    rep = AsyncServeLoop(eng, EDF()).run(_trace(n=5))
    assert eng.clock >= t0
    for resp in rep.responses.values():
        assert resp.finish_time >= resp.arrival_time
        assert resp.latency >= 0.0
        assert resp.latency == resp.finish_time - resp.arrival_time
