"""The Accelerator seam (repro.core.accel): NoAccel bit-identity against
the pre-seam engine on every driver and the serving engine, Anderson
iteration cuts with a CI-asserted max-vs-serial error bound, the
prefix-exact TriangularAccel contract (bitwise serial at the iteration
cap, composition with truncating frontier policies), the pairing rules
(joint mixing refuses truncating policies, the wavefront refuses
accelerating accelerators, straggler reuse refuses mixing), serving-side
state lifecycle (per-lane reset on slot recycling, one host sync per
refinement, EMA pricing of the reduced schedule) and simulate()/
AsyncServeLoop bit-identity under a shared accelerator.

Two toy models, chosen deliberately: the repo's standard elementwise
tanh model for bitwise claims (fast-converging — lane math identical
across batch widths), and a slowly-converging time-varying linear model
(the benchmarks/table13_accel.py config) for iteration-cut claims —
Parareal on the tanh toy converges too fast to leave mixing any headroom.
"""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AndersonAccel, ExactPrefix, FixedBudget, NoAccel,
                        ResidualWindow, SolverConfig, SRDSConfig,
                        TriangularAccel, make_schedule, resolve_accel,
                        sample_sequential, srds_sample)
from repro.core.accel import Accelerator
from repro.core.engine import run_parareal
from repro.serve import (AsyncServeLoop, DiffusionSamplingEngine, FIFO,
                         SampleRequest, Tier, poisson_trace, simulate)
from repro.serve import diffusion as serve_diffusion
from conftest import run_subprocess, to_f64

TOLS = [1e-2, 1e-4, 1e-6, 1e-3, 1e-5]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _slow_model(amp=2.0, freq=2.0, dim=16):
    """Time-varying linear model with slow Parareal convergence (the
    table13 bench toy): per-dim oscillating contraction rates keep the
    refinement map in its near-linear tail for many iterations — the
    regime Anderson mixing is for."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    f32 = jnp.float32                  # the bench runs f32 (x64 is module-
    w = freq * (1 + jax.random.uniform(k1, (dim,), f32))    # wide here)
    ph = 2 * jnp.pi * jax.random.uniform(k2, (dim,), f32)
    a = amp * (0.5 + jax.random.uniform(k3, (dim,), f32))

    def model_fn(x, t):
        return (a * jnp.sin(w * t[..., None] * 0.06 + ph) * x).astype(f32)

    return model_fn


def _x0(batch=3, dim=8):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, dim),
                             dtype=jnp.float64)


def _slow_setup():
    model = _slow_model()
    sched = make_schedule("cosine", 100)
    sched = dataclasses.replace(sched, ab=sched.ab.astype(jnp.float32),
                                t_model=sched.t_model.astype(jnp.float32))
    solver = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(1), (16,), jnp.float32)
    return model, sched, solver, x0


# --------------------------------------------------------------------------
# seam resolution + flags
# --------------------------------------------------------------------------

def test_resolve_accel_mapping():
    """accel=None maps onto NoAccel in exactly one place; non-accelerators
    are rejected loudly; the driver-dispatch flags match the contract."""
    assert isinstance(resolve_accel(None), NoAccel)
    aa = AndersonAccel(depth=3)
    assert resolve_accel(aa) is aa
    with pytest.raises(TypeError, match="Accelerator"):
        resolve_accel("anderson")
    # the flags drivers dispatch on
    assert not NoAccel().accelerates and NoAccel().exact
    assert NoAccel().prefix_exact
    assert aa.accelerates and not aa.exact and not aa.prefix_exact
    tri = TriangularAccel()
    assert tri.accelerates and not tri.exact and tri.prefix_exact
    # NoAccel carries no state: compiled carries stay byte-identical
    z = jnp.zeros((2, 4, 3))
    assert NoAccel().init_state(z, 8) is None
    assert NoAccel().reset_lanes(None, jnp.ones((3,), bool)) is None
    zm, st = NoAccel().apply(None, z, z + 1.0)
    assert st is None and bool(jnp.all(zm == z + 1.0))


def test_accel_pairing_rules():
    """Joint mixing refuses truncating frontier policies (their provable
    serial-prefix schedule is a theorem about the plain iteration);
    prefix-exact mixing is accepted; straggler reuse refuses any mixing."""
    model, sched, solver, x0 = _slow_setup()
    for kw in ({"truncate": True}, {"window": ResidualWindow(1e-3)}):
        with pytest.raises(ValueError, match="serial-prefix"):
            srds_sample(model, sched, solver, x0,
                        SRDSConfig(tol=1.0, accel=AndersonAccel(), **kw))
    # carry_fine_results (straggler reuse) is incompatible with mixing
    fine = lambda h, p, y: h
    G = lambda x, i0: x
    with pytest.raises(ValueError, match="carry_fine_results"):
        run_parareal(G, fine, jnp.ones((2,)),
                     jnp.arange(4, dtype=jnp.int32), tol=0.0, max_iters=2,
                     carry_fine_results=True, accel=AndersonAccel())


def test_wavefront_rejects_accelerating():
    """One block per device, no central iterate history: the wavefront
    refuses accelerating accelerators loudly instead of silently not
    mixing (single-device mesh is enough to hit the trace-time check)."""
    from repro.compat import make_mesh
    from repro.core.pipelined import make_pipelined_sampler
    model = _elementwise_model(6)
    sched = to_f64(make_schedule("ddpm_linear", 8))
    mesh = make_mesh((1,), ("time",))
    cfg = SRDSConfig(tol=1e-4, accel=AndersonAccel())
    samp = make_pipelined_sampler(mesh, "time", model, sched,
                                  SolverConfig("ddim"), cfg)
    with pytest.raises(ValueError, match="wavefront"):
        samp(jnp.ones((2, 6), jnp.float64))


# --------------------------------------------------------------------------
# state lifecycle units
# --------------------------------------------------------------------------

def test_init_state_shapes_and_reset_lanes():
    """The ring carry matches the joint iterate; reset_lanes zeroes exactly
    the re-admitted lanes' history (rings, last iterate/residual, count)."""
    acc = AndersonAccel(depth=3)
    z = jnp.ones((2, 4, 5, 7))                     # (2, B, K, dim)
    s = acc.init_state(z, 8, batched=True)
    assert s.dz.shape == (3, 2, 4, 5, 7) and s.df.shape == s.dz.shape
    assert s.z_last.shape == z.shape and s.count.shape == (5,)
    # depth is clamped to the iteration budget
    assert AndersonAccel(depth=9).init_state(z, 4).dz.shape[0] == 4
    junk = s._replace(
        dz=s.dz + 1, df=s.df + 2, z_last=s.z_last + 3, f_last=s.f_last + 4,
        count=s.count + 5)
    new = jnp.asarray([True, False, True, False, False])
    r = acc.reset_lanes(junk, new)
    for lane in range(5):
        for ring in (r.dz, r.df):
            got = ring[:, :, :, lane]
            assert bool(jnp.all(got == 0)) == bool(new[lane])
        assert bool(jnp.all(r.z_last[:, :, lane] == 0)) == bool(new[lane])
        assert bool(jnp.all(r.f_last[:, :, lane] == 0)) == bool(new[lane])
        assert (int(r.count[lane]) == 0) == bool(new[lane])


def test_apply_frozen_blocks_bitwise_and_warmup_raw():
    """Blocks outside the live mask commit exactly z_prev (bitwise, not
    just f==0); during warmup the raw iterate is committed while the
    rings record."""
    acc = AndersonAccel(depth=2, warmup=2)
    key = jax.random.PRNGKey(0)
    z_prev = jax.random.normal(key, (2, 4, 3))
    z_new = z_prev + jax.random.normal(jax.random.PRNGKey(1), (2, 4, 3))
    s = acc.init_state(z_prev, 8)
    live = jnp.asarray([True, True, False, False])
    zm, s1 = acc.apply(s, z_prev, z_new, live=live)
    # warmup commit is the raw iterate on live blocks ...
    np.testing.assert_array_equal(np.asarray(zm[:, :2]),
                                  np.asarray(z_new[:, :2]))
    # ... and bitwise z_prev on frozen ones
    np.testing.assert_array_equal(np.asarray(zm[:, 2:]),
                                  np.asarray(z_prev[:, 2:]))
    assert int(s1.count) == 1


# --------------------------------------------------------------------------
# NoAccel bit-identity vs the pre-seam engine (driver by driver)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_kw", [
    {}, {"truncate": True}, {"window": ResidualWindow(1e-3)},
    {"per_sample": True},
])
def test_noaccel_bit_identical_srds_sample(cfg_kw):
    """accel=NoAccel() reproduces the default engine bit for bit in every
    frontier/gating mode — the exactness guarantee is untouched when
    acceleration is off."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    solver = SolverConfig("ddim")
    x = _x0(len(TOLS)) if cfg_kw.get("per_sample") else _x0()
    tol = jnp.asarray(TOLS, jnp.float32) if cfg_kw.get("per_sample") else None
    a = srds_sample(model, sched, solver, x,
                    SRDSConfig(tol=1e-4, **cfg_kw), tol=tol)
    b = srds_sample(model, sched, solver, x,
                    SRDSConfig(tol=1e-4, accel=NoAccel(), **cfg_kw), tol=tol)
    assert bool(jnp.all(a.sample == b.sample))
    np.testing.assert_array_equal(np.asarray(a.iterations),
                                  np.asarray(b.iterations))
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(b.delta_history))


@pytest.mark.slow
@pytest.mark.distributed
def test_noaccel_and_anderson_sharded_match_single_program():
    """The sharded driver behind the seam: NoAccel is bit-identical to the
    default, and Anderson mixing — deterministic elementwise math over
    replicated carries — matches the single-program accelerated run
    iteration for iteration."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_sharded_sampler
from repro.compat import make_mesh

assert len(jax.devices()) == 8
w = jax.random.normal(jax.random.PRNGKey(0), (6, 6), dtype=jnp.float64) * 0.3
def model_fn(x, t):
    return jnp.tanh(x @ w) * (0.5 + 0.001 * t)
mesh = make_mesh((8,), ("time",))
sched = make_schedule("ddpm_linear", 64)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64),
                          kind=sched.kind)
x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 6), dtype=jnp.float64)
solver = SolverConfig("ddim")

plain = SRDSConfig(tol=1e-6, num_blocks=8)
noacc = SRDSConfig(tol=1e-6, num_blocks=8, accel=NoAccel())
r_p = make_sharded_sampler(mesh, "time", model_fn, sched, solver, plain)(x0)
r_n = make_sharded_sampler(mesh, "time", model_fn, sched, solver, noacc)(x0)
assert bool(jnp.all(r_p.sample == r_n.sample))
assert int(r_p.iterations) == int(r_n.iterations)

acfg = SRDSConfig(tol=1e-6, num_blocks=8,
                  accel=AndersonAccel(depth=3, warmup=2))
r_d = make_sharded_sampler(mesh, "time", model_fn, sched, solver, acfg)(x0)
r_s = srds_sample(model_fn, sched, solver, x0, acfg)
assert int(r_d.iterations) == int(r_s.iterations)
assert float(jnp.max(jnp.abs(r_d.sample - r_s.sample))) < 1e-10
"""
    r = run_subprocess(code, devices=8)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"


# --------------------------------------------------------------------------
# acceleration: iteration cut at equal tolerance, bounded error
# --------------------------------------------------------------------------

def test_anderson_cuts_iterations_at_equal_tolerance():
    """The headline claim on the bench toy (N=100): Anderson reaches the
    same convergence tolerance in >= 25% fewer refinements, and the
    converged sample stays within a small multiple of the tolerance of
    the serial solve."""
    model, sched, solver, x0 = _slow_setup()
    ref = sample_sequential(model, sched, solver, x0)
    acc = AndersonAccel(depth=5, warmup=2)
    tol = 3.0
    plain = srds_sample(model, sched, solver, x0, SRDSConfig(tol=tol))
    mixed = srds_sample(model, sched, solver, x0,
                        SRDSConfig(tol=tol, accel=acc))
    ip, ia = int(plain.iterations), int(mixed.iterations)
    assert ia <= 0.75 * ip, (ip, ia)
    assert float(mixed.final_delta) < tol
    # the mixed fixed point is the same: vs-serial error within a small
    # multiple of the (loose) tolerance, same order as the plain run's
    err = float(jnp.max(jnp.abs(mixed.sample - ref)))
    assert err <= 5.0 * tol, err
    # and at a tight tolerance it still never costs MORE iterations
    p2 = srds_sample(model, sched, solver, x0, SRDSConfig(tol=0.1))
    a2 = srds_sample(model, sched, solver, x0,
                     SRDSConfig(tol=0.1, accel=acc))
    assert int(a2.iterations) <= int(p2.iterations)
    assert float(jnp.max(jnp.abs(a2.sample - ref))) <= 1.0 * 0.1


def test_anderson_per_sample_gating():
    """Per-sample gating composes with mixing: every sample converges to
    its own tolerance and frozen lanes stay frozen (iterations differ)."""
    model, sched, solver, _ = _slow_setup()
    xb = jax.random.normal(jax.random.PRNGKey(2), (3, 16))
    tols = jnp.asarray([3.0, 0.3, 1.0], jnp.float32)
    res = srds_sample(model, sched, solver, xb,
                      SRDSConfig(per_sample=True,
                                 accel=AndersonAccel(depth=3, warmup=2)),
                      tol=tols)
    for s in range(3):
        assert float(res.final_delta[s]) < float(tols[s])
    assert len(set(np.asarray(res.iterations).tolist())) > 1


def test_triangular_bitwise_serial_at_cap():
    """The prefix-exact contract: a TriangularAccel run driven to the
    iteration cap returns the bitwise-identical result of the plain
    truncated engine (Parareal's finite convergence survives mixing),
    and composing with ExactPrefix truncation is accepted."""
    model, sched, solver, x0 = _slow_setup()
    tri = TriangularAccel(depth=3, warmup=2)
    plain = srds_sample(model, sched, solver, x0,
                        SRDSConfig(tol=0.0, truncate=True))
    mixed = srds_sample(model, sched, solver, x0,
                        SRDSConfig(tol=0.0, truncate=True, accel=tri))
    assert bool(jnp.all(plain.sample == mixed.sample))
    # and under a residual window it converges to the same answer
    win = srds_sample(model, sched, solver, x0,
                      SRDSConfig(tol=0.1, window=ResidualWindow(1e-2),
                                 accel=tri))
    assert float(jnp.max(jnp.abs(win.sample - plain.sample))) < 0.1


# --------------------------------------------------------------------------
# the serving engine behind the same seam
# --------------------------------------------------------------------------

def _engine(model, **kw):
    kw.setdefault("batch_size", 3)
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=36, dtype=jnp.float64, **kw)


def _drain(model, reqs, **kw):
    eng = _engine(model, **kw)
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    return eng, [out[r] for r in rids]


def test_serve_noaccel_bit_identical():
    """An engine built with accel=NoAccel() reproduces the default
    engine's responses bit for bit (samples, iterations, eval billing)."""
    model = _elementwise_model()
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]) for i in range(5)]
    _, a = _drain(model, reqs)
    _, b = _drain(model, reqs, accel=NoAccel())
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x.sample), np.asarray(y.sample))
        assert x.iterations == y.iterations
        assert x.model_evals == y.model_evals


def test_serve_engine_pairing_rule():
    """The engine's default ExactPrefix policy refuses joint mixing at
    build time; TriangularAccel and untruncated Anderson are accepted."""
    model = _elementwise_model()
    with pytest.raises(ValueError, match="serial-prefix"):
        _engine(model, accel=AndersonAccel())
    assert _engine(model, accel=TriangularAccel()).accel.accelerates
    eng = _engine(model, truncate=False, accel=AndersonAccel())
    assert isinstance(eng.window, FixedBudget)


def test_serve_anderson_reduces_iterations_and_prices_honestly():
    """Anderson behind the serving engine on the slow toy: fewer
    refinements per completion at the same tolerance, responses within a
    small multiple of the tolerance of the plain engine's, and the
    iteration EMA (which predict_completion consults) learns the reduced
    schedule from completions."""
    model = _slow_model()

    def run(accel=None):
        eng = DiffusionSamplingEngine(model, (16,), SolverConfig("ddim"),
                                      schedule="cosine", num_steps=100,
                                      batch_size=2, truncate=False,
                                      accel=accel)
        rids = [eng.submit(SampleRequest(seed=i, tol=3.0)) for i in range(2)]
        out = eng.drain()
        return eng, [out[r] for r in rids]

    ep, plain = run()
    ea, mixed = run(AndersonAccel(depth=5, warmup=2))
    assert sum(r.iterations for r in mixed) < sum(r.iterations
                                                  for r in plain)
    for p, m in zip(plain, mixed):
        assert m.iterations <= p.iterations
        assert float(np.max(np.abs(np.asarray(p.sample)
                                   - np.asarray(m.sample)))) <= 10.0 * 3.0
    (k_p,) = set(ep.iters_ema._mean)
    assert ea.iters_ema._mean[k_p] < ep.iters_ema._mean[k_p]


class _FetchCounter:
    def __init__(self, real):
        self.real = real
        self.shapes = []

    def __call__(self, x):
        out = self.real(x)
        self.shapes.append(out.shape)
        return out


def test_serve_accel_one_sync_per_refinement(monkeypatch):
    """Mixing adds no host syncs: the accelerated hot loop still fetches
    exactly one (K,) residual per refinement plus one lane fetch per
    completion — for both the triangular/truncated and the
    Anderson/untruncated pairings."""
    model = _elementwise_model()
    for kw in ({"accel": TriangularAccel(depth=2, warmup=2)},
               {"accel": AndersonAccel(depth=2, warmup=2),
                "truncate": False}):
        counter = _FetchCounter(serve_diffusion._host_fetch)
        monkeypatch.setattr(serve_diffusion, "_host_fetch", counter)
        eng = _engine(model, **kw)
        rids = [eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
                for i in range(5)]
        queue = eng.pull_queue()
        done = {}
        while eng.busy() or queue:
            while queue and eng.free_slots(queue[0][1]) > 0:
                rid, req = queue.pop(0)
                eng.admit(rid, req)
            before = len(counter.shapes)
            completions = eng.step_once()
            done.update(dict(completions))
            fetched = counter.shapes[before:]
            assert len(fetched) == 1 + len(completions), (kw, fetched)
            assert fetched[0] == (eng.batch_size,)
            for shp in fetched[1:]:
                assert shp == (8,), shp
        assert set(done) == set(rids)


def test_serve_slot_recycling_resets_accel_state():
    """Slot recycling under mixing: a recycled lane's response is
    bit-identical to the same request served on a fresh engine — the old
    occupant's ring history was zeroed on admission, so it cannot leak
    into the newcomer's mixing."""
    model = _elementwise_model()
    acc = TriangularAccel(depth=2, warmup=1)
    # mixed tolerances force staggered completion and slot reuse
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)])
            for i in range(7)]
    _, busy = _drain(model, reqs, batch_size=2, accel=acc)
    for i, resp in enumerate(busy):
        _, solo = _drain(model, [reqs[i]], batch_size=2, accel=acc)
        assert np.array_equal(np.asarray(resp.sample),
                              np.asarray(solo[0].sample)), i
        assert resp.iterations == solo[0].iterations


# --------------------------------------------------------------------------
# simulate() / AsyncServeLoop bit-identity under a shared accelerator
# --------------------------------------------------------------------------

TIERS = [Tier(tol=1e-2, slo_ms=25, iters_hint=2, weight=0.9),
         Tier(tol=1e-6, slo_ms=400, iters_hint=7, weight=0.1)]


@pytest.mark.parametrize("max_inflight", [1, 2])
def test_async_loop_bit_exact_vs_simulate_with_accel(max_inflight):
    """Pipelined dispatch/resolve stays bit-exact vs the synchronous
    engine when both share an accelerating Accelerator: mixing is
    per-lane (vmapped), so speculative refinements of converged lanes
    and batch-mate churn remain unobservable."""
    model = _elementwise_model()
    trace = poisson_trace(10, rate=300.0, tiers=TIERS, seed=0)
    mk = lambda: _engine(model, truncate=False, sec_per_eval=1e-5,
                         accel=AndersonAccel(depth=3, warmup=2))
    sync = simulate(mk(), trace, FIFO())
    rep = AsyncServeLoop(mk(), FIFO(), max_inflight=max_inflight).run(trace)
    assert sorted(rep.responses) == sorted(sync.responses)
    for rid in sync.responses:
        a, b = sync.responses[rid], rep.responses[rid]
        assert a.iterations == b.iterations
        assert np.array_equal(np.asarray(a.sample), np.asarray(b.sample))
