"""Substrate tests: optimizer, data pipeline, checkpointing (atomic/async/
elastic), fault tolerance (restart-resume bitwise, retry, preemption),
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops

kops.FORCE_REF = True

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.data import DataConfig, LMStream, make_stream
from repro.models import init_params
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         global_norm, init_opt_state, warmup_cosine)
from repro.runtime import LoopConfig, Preempted, PreemptionSignal, train_loop
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05
    assert int(state["step"]) == 200


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 100


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.int32(5))) == pytest.approx(0.5)
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_bf16_params_updated_via_fp32():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(params)
    p2, _, _ = adamw_update(params, {"w": jnp.ones((4,), jnp.bfloat16)},
                            state, AdamWConfig(lr=0.1))
    assert p2["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(p2["w"] != params["w"]))


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_step_dependent():
    s = LMStream(DataConfig(global_batch=4, seq_len=16, seed=1), vocab=100)
    b1 = s.batch(3)
    b2 = s.batch(3)
    b3 = s.batch(4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert bool(jnp.any(b1["tokens"] != b3["tokens"]))
    assert b1["tokens"].shape == (4, 16)
    assert int(jnp.max(b1["tokens"])) < 100


def test_streams_for_all_families():
    for arch in ("stablelm-3b", "hubert-xlarge", "phi-3-vision-4.2b",
                 "srds-dit-cifar"):
        cfg = get_arch(arch).reduced()
        st = make_stream(cfg, DataConfig(global_batch=2, seq_len=8))
        b = st.batch(0)
        assert all(np.all(np.isfinite(np.asarray(v, np.float32)))
                   for v in b.values())


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(10, t, {"note": "x"})
    restored, step, meta = ck.restore(jax.eval_shape(lambda: t))
    assert step == 10 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree())
    ck.wait()
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir (simulated mid-save preemption) is never visible."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_6.tmp"))
    assert ck.latest_step() == 5
    _, step, _ = ck.restore(jax.eval_shape(_tree))
    assert step == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    bad = dict(_tree(), a=jnp.zeros((5, 5)))
    with pytest.raises(ValueError):
        ck.restore(jax.eval_shape(lambda: bad))


@pytest.mark.slow
@pytest.mark.distributed
def test_checkpoint_elastic_reshard_subprocess():
    """Save on a 4-device mesh, restore onto a 2-device mesh (scale-down) —
    values identical, shardings follow the new mesh."""
    from conftest import run_subprocess
    code = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.checkpoint import Checkpointer

d = tempfile.mkdtemp()
mesh4 = make_mesh((4,), ("data",))
x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh4, P("data", None)))
ck = Checkpointer(d)
ck.save(1, {"x": x})
mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])
sh2 = {"x": NamedSharding(mesh2, P("data", None))}
restored, step, _ = ck.restore({"x": jax.eval_shape(lambda: x)}, shardings=sh2)
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert restored["x"].sharding.mesh.devices.size == 2
print("ELASTIC OK")
"""
    r = run_subprocess(code, devices=4)
    assert r.returncode == 0 and "ELASTIC OK" in r.stdout, r.stderr


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------

def _setup_training(tmp_path, total=12):
    cfg = get_arch("stablelm-3b").reduced()
    params = init_params(cfg, KEY)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), use_kernel=False))
    stream = make_stream(cfg, DataConfig(global_batch=2, seq_len=16))
    ck = Checkpointer(str(tmp_path))
    return cfg, params, opt, step, stream, ck, LoopConfig(
        total_steps=total, ckpt_every=4, log_every=100)


def test_restart_resume_bitwise_identical(tmp_path):
    """Preempt mid-run; restart; final params == uninterrupted run."""
    cfg, params, opt, step, stream, ck, lc = _setup_training(tmp_path)

    # uninterrupted reference
    ck_ref = Checkpointer(str(tmp_path) + "_ref")
    p_ref, o_ref, _ = train_loop(step, params, opt, stream, KEY, ck_ref, lc)

    # interrupted: preempt after step 6 (via fault injector setting the flag)
    sig = PreemptionSignal()

    def inject(s):
        if s == 6:
            sig.set()   # flag raised while step 6 is in flight

    with pytest.raises(Preempted):
        train_loop(step, params, opt, stream, KEY, ck, lc,
                   preemption=sig, fault_injector=inject)
    # loop finishes the in-flight step, saves, THEN exits -> saved at 7
    assert ck.latest_step() == 7
    # restart (fresh templates, resumes from ckpt)
    p_fin, o_fin, s_fin = train_loop(step, params, opt, stream, KEY, ck, lc)
    assert s_fin == lc.total_steps
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fin)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_transient_fault_retry(tmp_path):
    """A step that fails once (flaky infra) is retried with the same batch
    and the run completes with the same result as a clean run."""
    cfg, params, opt, step, stream, ck, lc = _setup_training(tmp_path, total=6)
    fails = {"left": 2}

    def flaky(s):
        if s == 3 and fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("simulated transient interconnect failure")

    p1, _, _ = train_loop(step, params, opt, stream, KEY, ck, lc,
                          fault_injector=flaky)
    ck2 = Checkpointer(str(tmp_path) + "_clean")
    p2, _, _ = train_loop(step, params, opt, stream, KEY, ck2, lc)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_permanent_fault_saves_state(tmp_path):
    cfg, params, opt, step, stream, ck, lc = _setup_training(tmp_path, total=8)

    def dead(s):
        if s == 5:
            raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        train_loop(step, params, opt, stream, KEY, ck, lc, fault_injector=dead)
    assert ck.latest_step() == 5  # state persisted before giving up


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_compressed_allreduce_subprocess():
    """int8 error-feedback DP training tracks uncompressed DP closely."""
    from conftest import run_subprocess
    code = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.kernels import ops as kops
kops.FORCE_REF = True
from repro.configs import get_arch
from repro.models import init_params
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step, make_dp_train_step_compressed
from repro.train.steps import init_error_feedback
from repro.data import DataConfig, make_stream

cfg = get_arch("stablelm-3b").reduced()
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
opt = init_opt_state(params)
stream = make_stream(cfg, DataConfig(global_batch=4, seq_len=16))
from repro.compat import make_mesh
mesh = make_mesh((4,), ("data",))

step_c = make_dp_train_step_compressed(cfg, AdamWConfig(lr=1e-3), mesh,
                                       use_kernel=False)
step_u = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), use_kernel=False))
ef = init_error_feedback(params)
copy = lambda t: jax.tree.map(jnp.copy, t)
pc, oc = copy(params), copy(opt)   # step_c donates its inputs
pu, ou = params, opt
losses_c, losses_u = [], []
for s in range(24):
    batch = stream.batch(s)
    k = jax.random.fold_in(key, s)
    pc, oc, ef, mc = step_c(pc, oc, ef, batch, k)
    pu, ou, mu = step_u(pu, ou, batch, k)
    losses_c.append(float(mc["loss"])); losses_u.append(float(mu["loss"]))
# training progresses: compare batch-averaged endpoints (each step sees a
# fresh batch, so single-batch endpoints are noise-dominated)
assert np.mean(losses_c[-4:]) < np.mean(losses_c[:4]), losses_c
# compressed tracks uncompressed step-for-step, small quantization deviation
assert max(abs(a - b) for a, b in zip(losses_c, losses_u)) \
    < 0.15 * abs(losses_u[0]), (losses_c, losses_u)
print("COMPRESS OK", losses_c[-1], losses_u[-1])
"""
    r = run_subprocess(code, devices=4, timeout=1200)
    assert r.returncode == 0 and "COMPRESS OK" in r.stdout, \
        f"{r.stdout}\n{r.stderr}"
