"""Distributed SRDS (shard_map + wavefront) equivalence — 8 fake devices in
subprocesses so the main test session keeps a single device."""
import pytest

from conftest import run_subprocess

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

COMMON = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_sharded_sampler, make_pipelined_sampler

assert len(jax.devices()) == 8
w = jax.random.normal(jax.random.PRNGKey(0), (6, 6), dtype=jnp.float64) * 0.3
def model_fn(x, t):
    return jnp.tanh(x @ w) * (0.5 + 0.001 * t)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("time",))
N = 64
sched = make_schedule("ddpm_linear", N)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64))
x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 6), dtype=jnp.float64)
solver = SolverConfig("ddim")
ref = sample_sequential(model_fn, sched, solver, x0)
"""


def _run(body):
    r = run_subprocess(COMMON + body, devices=8)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    return r.stdout


def test_sharded_exact():
    _run(r"""
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=8))
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
assert int(res.iterations) <= 8
""")


def test_sharded_multiple_blocks_per_device():
    _run(r"""
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=16))
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
""")


def test_sharded_matches_single_program():
    """Distributed == single-program SRDS, iteration for iteration."""
    _run(r"""
for tol in (0.0, 1e-4):
    cfg = SRDSConfig(tol=tol, num_blocks=8)
    res_d = make_sharded_sampler(mesh, "time", model_fn, sched, solver, cfg)(x0)
    res_s = srds_sample(model_fn, sched, solver, x0, cfg)
    assert int(res_d.iterations) == int(res_s.iterations), (tol,)
    assert float(jnp.max(jnp.abs(res_d.sample - res_s.sample))) < 1e-10
""")


def test_wavefront_exact_and_superstep_model():
    """Wavefront == sequential; supersteps == k*S + B - 1 (paper Fig. 4)."""
    _run(r"""
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=0.0))
res, steps, evals = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
k = int(res.iterations); S = N // 8
assert int(steps) <= k * S + 8 + 2, (int(steps), k)
# retirement: device i stops evaluating after refinement i+1, so physical
# evals stay strictly below all-devices-every-superstep
assert 0 < int(evals) < int(steps) * 8 * 2, (int(evals), int(steps))
""")


def test_wavefront_early_convergence():
    _run(r"""
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=1e-4))
res, steps, evals = samp(x0)
k = int(res.iterations)
assert k < 8, k
assert float(jnp.mean(jnp.abs(res.sample - ref))) < 1e-3
# latency model: supersteps ~ k*S + B - 1 << sequential N (=64 evals) for
# converged k; each superstep is ONE lockstep batched model eval.
assert int(steps) < N, (int(steps), N)
""")


def test_delta_history_contract_parity_across_samplers():
    """All three samplers return the same delta_history contract:
    (max_iters,) f32, real residuals up to `iterations`, +inf beyond —
    the wavefront used to return a dummy (1,) +inf placeholder."""
    _run(r"""
import numpy as np
cfg = SRDSConfig(tol=1e-4, num_blocks=8)
res_seq = srds_sample(model_fn, sched, solver, x0, cfg)
res_sh = make_sharded_sampler(mesh, "time", model_fn, sched, solver, cfg)(x0)
res_wf, steps, _ = make_pipelined_sampler(mesh, "time", model_fn, sched,
                                          solver, SRDSConfig(tol=1e-4))(x0)
assert res_wf.delta_history.shape == res_sh.delta_history.shape \
    == res_seq.delta_history.shape == (8,), res_wf.delta_history.shape
for res in (res_seq, res_sh, res_wf):
    k = int(res.iterations)
    h = np.asarray(res.delta_history)
    assert np.all(np.isfinite(h[:k])), h
    assert np.all(np.isinf(h[k:])), h
    assert float(res.final_delta) == float(h[k - 1])
# the wavefront residuals are the same quantity the engine computes
# (||x_B^p - x_B^{p-1}||), just wavefront-scheduled
k = min(int(res_wf.iterations), int(res_seq.iterations))
np.testing.assert_allclose(np.asarray(res_wf.delta_history[:k]),
                           np.asarray(res_seq.delta_history[:k]),
                           rtol=1e-4, atol=1e-9)
""")


def test_sharded_batched_per_sample_gating():
    """Distributed batched sampler: per-sample gating with a mixed-tol
    vector is bit-identical to the single-program batched run, lane for
    lane, and each lane stops at its own tolerance."""
    _run(r"""
import numpy as np
xb = jax.random.normal(jax.random.PRNGKey(3), (4, 6), dtype=jnp.float64) \
    * jnp.linspace(0.4, 2.0, 4)[:, None]
tols = jnp.array([1e-2, 1e-4, 1e-6, 1e-3], jnp.float32)
cfg = SRDSConfig(per_sample=True, num_blocks=8)
res_s = srds_sample(model_fn, sched, solver, xb, cfg, tol=tols)
res_d = make_sharded_sampler(mesh, "time", model_fn, sched, solver, cfg)(xb, tols)
assert res_d.iterations.shape == (4,) and res_d.delta_history.shape == (8, 4)
assert np.array_equal(np.asarray(res_d.iterations), np.asarray(res_s.iterations))
assert len(set(np.asarray(res_d.iterations).tolist())) > 1
assert bool(jnp.all(res_d.sample == res_s.sample))
assert np.array_equal(np.asarray(res_d.delta_history),
                      np.asarray(res_s.delta_history))
# per-lane: converged lanes are below their own tolerance
for k in range(4):
    if int(res_d.iterations[k]) < 8:
        assert float(res_d.final_delta[k]) < float(tols[k])
""")


def test_wavefront_per_sample_done_flag():
    """Per-sample wavefront: the psum'd done-flag fires only when EVERY
    sample converged; per-sample iterations/history ride the carry and
    early-converged lanes freeze at their convergence value."""
    _run(r"""
import numpy as np
xb = jax.random.normal(jax.random.PRNGKey(3), (2, 6), dtype=jnp.float64) \
    * jnp.array([[0.4], [2.0]])
refb = sample_sequential(model_fn, sched, solver, xb)
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=1e-4, per_sample=True))
res, steps, _ = samp(xb)
assert res.iterations.shape == (2,) and res.delta_history.shape == (8, 2)
it = np.asarray(res.iterations)
assert it.min() >= 1 and it.max() <= 8
# the loop ran to the SLOWEST lane: supersteps cover max(it) refinements
S = N // 8
assert int(steps) >= (int(it.max()) - 1) * S + 8
for k in range(2):
    h = np.asarray(res.delta_history[:, k])
    assert np.all(np.isfinite(h[:it[k]])) and np.all(np.isinf(h[it[k]:]))
    if it[k] < 8:
        assert float(res.final_delta[k]) < 1e-4
assert float(jnp.mean(jnp.abs(res.sample - refb))) < 1e-3
# lanes match the single-program per-sample run, iteration for iteration
res_s = srds_sample(model_fn, sched, solver, xb,
                    SRDSConfig(per_sample=True, num_blocks=8, tol=1e-4))
assert np.array_equal(it, np.asarray(res_s.iterations))
""")


def test_wavefront_short_blocks_respect_iteration_budget():
    """Regression: with s_steps <= 3 the superstep budget's ramp slack let
    the tail complete an uncounted extra refinement — iterations could
    report max_iters+1 with a final_delta never recorded in the history."""
    _run(r"""
import numpy as np
sched16 = make_schedule("ddpm_linear", 16)
sched16 = DiffusionSchedule(ab=sched16.ab.astype(jnp.float64),
                            t_model=sched16.t_model.astype(jnp.float64))
ref16 = sample_sequential(model_fn, sched16, solver, x0)
samp = make_pipelined_sampler(mesh, "time", model_fn, sched16, solver,
                              SRDSConfig(tol=0.0))   # s_steps = 2
res, steps, _ = samp(x0)
k = int(res.iterations)
assert k <= 8, k
h = np.asarray(res.delta_history)
assert h.shape == (8,)
assert float(res.final_delta) == float(h[k - 1]), (res.final_delta, h)
assert float(jnp.max(jnp.abs(res.sample - ref16))) < 1e-10
""")


def test_serving_engine_sharded_fine_solves():
    """DiffusionSamplingEngine's mesh path (shard_map fine solves +
    all_gather) returns the same results as the single-program path."""
    _run(r"""
import numpy as np
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest

scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
reqs = [SampleRequest(seed=i, tol=[1e-2, 1e-4, 1e-5][i % 3]) for i in range(5)]

def run(**kw):
    eng = DiffusionSamplingEngine(emodel, (6,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64, **kw)
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    return [out[r] for r in rids]

plain = run()
sharded = run(mesh=mesh, axis="time")
for a, b in zip(plain, sharded):
    assert a.iterations == b.iterations
    assert np.array_equal(a.sample, b.sample)
    assert np.array_equal(a.delta_history, b.delta_history)
# B=8 not divisible by a 3-wide axis must fail loudly at program build
from repro.compat import make_mesh
mesh3 = make_mesh((3,), ("t3",), devices=jax.devices()[:3])
eng = DiffusionSamplingEngine(emodel, (6,), SolverConfig("ddim"),
                              num_steps=64, batch_size=2, mesh=mesh3,
                              axis="t3")
eng.submit(SampleRequest(seed=0))
try:
    eng.drain()
    raise SystemExit("expected ValueError for indivisible block split")
except ValueError as e:
    assert "not divisible" in str(e), e
""")


def test_serving_engine_data_sharded_slot_batch():
    """The slot batch itself shards over a ``data`` mesh axis (the 2-device
    CPU mesh): lanes are independent, so results stay bit-identical to the
    unsharded engine — and an indivisible batch_size fails at construction."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh
from repro.core import SolverConfig
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest

assert len(jax.devices()) == 2
mesh = make_mesh((2,), ("data",))
scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
reqs = [SampleRequest(seed=i, tol=[1e-2, 1e-4, 1e-5][i % 3]) for i in range(5)]

def run(**kw):
    eng = DiffusionSamplingEngine(emodel, (6,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64, **kw)
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    return [out[r] for r in rids]

plain = run()
sharded = run(mesh=mesh, data_axis="data")
for a, b in zip(plain, sharded):
    assert a.iterations == b.iterations
    assert np.array_equal(a.sample, b.sample)
    assert np.array_equal(a.delta_history, b.delta_history)
# batch_size=3 doesn't split over a 2-wide data axis: loud, at construction
try:
    DiffusionSamplingEngine(emodel, (6,), SolverConfig("ddim"), num_steps=64,
                            batch_size=3, mesh=mesh, data_axis="data")
    raise SystemExit("expected ValueError for indivisible batch_size")
except ValueError as e:
    assert "not divisible" in str(e), e
print("DATA SHARD OK")
"""
    r = run_subprocess(code, devices=2)
    assert r.returncode == 0 and "DATA SHARD OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr}"


def test_serving_engine_block_and_data_axes_compose():
    """Block-parallel fine solves and a sharded slot batch compose on one
    2D mesh — still bit-identical to the unsharded engine."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh
from repro.core import SolverConfig
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest

assert len(jax.devices()) == 8
mesh = make_mesh((4, 2), ("time", "data"))
scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
reqs = [SampleRequest(seed=i, tol=[1e-2, 1e-4, 1e-5][i % 3]) for i in range(6)]

def run(**kw):
    eng = DiffusionSamplingEngine(emodel, (6,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64, **kw)
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    return [out[r] for r in rids]

plain = run()
both = run(mesh=mesh, axis="time", data_axis="data")
for a, b in zip(plain, both):
    assert a.iterations == b.iterations
    assert np.array_equal(a.sample, b.sample)
    assert np.array_equal(a.delta_history, b.delta_history)
print("2D SHARD OK")
"""
    r = run_subprocess(code, devices=8)
    assert r.returncode == 0 and "2D SHARD OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr}"


def test_dit_denoiser_composes_time_data_model_mesh():
    """Real DiT fine solves over a (2 time, 2 data, 2 model) mesh through
    the one Denoiser seam: the patch-sharded backbone (K/V all-gather over
    ``model``) matches the single-device driver within the documented
    shape-dependent-gemm carve-out, in all three drivers — ``srds_sample``
    (vmap-of-shard_map), the sharded driver (``inner_eval`` glue inside the
    time/data shard_map), and the serving engine (``shard_eval`` under
    ``denoiser_spec``)."""
    code = r"""
import dataclasses as dc
import jax
import jax.numpy as jnp
from repro.configs.base import get_arch
from repro.configs.srds_dit import dit_denoiser
from repro.core import SRDSConfig, SolverConfig, make_schedule, srds_sample
from repro.core.pipelined import make_sharded_sampler
from repro.launch.mesh import make_srds_mesh
from repro.models.dit import init_dit
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest

assert len(jax.devices()) == 8
cfg = dc.replace(get_arch("srds-dit-cifar"), num_layers=2, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 patch_size=2, dtype="float32")
params = init_dit(cfg, jax.random.PRNGKey(0))
mesh = make_srds_mesh(2, 2, 2)
assert dict(mesh.shape) == {"time": 2, "data": 2, "model": 2}
# H=8 rows over model=2 -> 4 local rows, /patch_size=2 -> 2 patch rows each
den = dit_denoiser(cfg, params, shard_axis="model", mesh=mesh,
                   use_kernel=False)
ref_fn = dit_denoiser(cfg, params, use_kernel=False)
sched = make_schedule("ddpm_linear", 8)
solver = SolverConfig("ddim")
x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
cfg_s = SRDSConfig(num_blocks=4, per_sample=True)
TOL = 5e-5   # documented shape-dependent-gemm carve-out (f32)

r_ref = srds_sample(ref_fn, sched, solver, x0, cfg_s)
r_mp = srds_sample(den, sched, solver, x0, cfg_s)
d1 = float(jnp.max(jnp.abs(r_ref.sample - r_mp.sample)))
assert d1 <= TOL, d1

samp = make_sharded_sampler(mesh, "time", den, sched, solver, cfg_s,
                            data_axis="data")
d2 = float(jnp.max(jnp.abs(r_ref.sample - samp(x0).sample)))
assert d2 <= TOL, d2

eng = DiffusionSamplingEngine(den, (8, 8, 3), solver=solver, num_steps=8,
                              batch_size=4, num_blocks=4, mesh=mesh,
                              data_axis="data")
eng_ref = DiffusionSamplingEngine(ref_fn, (8, 8, 3), solver=solver,
                                  num_steps=8, batch_size=4, num_blocks=4)
for e in (eng, eng_ref):
    for i in range(4):
        e.submit(SampleRequest(seed=i, tol=1e-3))
out, out_ref = eng.drain(), eng_ref.drain()
d3 = max(float(jnp.max(jnp.abs(out[k].sample - out_ref[k].sample)))
         for k in out)
assert d3 <= TOL, d3

# one flash-kernel eval (Pallas interpret mode on CPU) through the seam
den_k = dit_denoiser(cfg, params, shard_axis="model", mesh=mesh)
d4 = float(jnp.max(jnp.abs(den_k(x0, 0.5)
                           - dit_denoiser(cfg, params)(x0, 0.5))))
assert d4 <= TOL, d4
print("DIT TDM MESH OK", d1, d2, d3, d4)
"""
    r = run_subprocess(code, devices=8, timeout=900)
    assert r.returncode == 0 and "DIT TDM MESH OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr}"


def test_straggler_mitigation_preserves_exactness():
    """Transient stragglers (stale fine results) cost iterations, never
    correctness."""
    _run(r"""
def strag(p):
    m = jnp.zeros((8,), bool).at[3].set(True).at[5].set(True)
    return jnp.where(p % 2 == 1, m, jnp.zeros((8,), bool))
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=8, max_iters=24),
                            straggler_fn=strag)
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
base = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=1e-6, num_blocks=8, max_iters=24))(x0)
withs = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                             SRDSConfig(tol=1e-6, num_blocks=8, max_iters=24),
                             straggler_fn=strag)(x0)
assert int(withs.iterations) >= int(base.iterations)
""")


def test_sharded_truncation_matches_untruncated():
    """Converged-prefix truncation under shard_map: the suffix is
    redistributed over the axis (retired prefix blocks free whole
    devices).  Iterations and (f32) delta_history match the untruncated
    distributed run bitwise; samples match to a few f64 ulps — under
    shard_map the while_loop -> unrolled-cond swap perturbs XLA's loop
    codegen in the last bits even for identical math (the same effect
    makes while vs scan differ here), so the single-program driver's
    bitwise guarantee relaxes to ulp-level for the sharded one."""
    _run(r"""
import numpy as np
scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
eref = sample_sequential(emodel, sched, solver, x0)
for tol in (0.0, 1e-4):
    cfg_p = SRDSConfig(tol=tol, num_blocks=8)
    cfg_t = SRDSConfig(tol=tol, num_blocks=8, truncate=True)
    res_p = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_p)(x0)
    res_t = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_t)(x0)
    assert int(res_p.iterations) == int(res_t.iterations), tol
    assert np.array_equal(np.asarray(res_p.delta_history),
                          np.asarray(res_t.delta_history)), tol
    np.testing.assert_allclose(np.asarray(res_t.sample),
                               np.asarray(res_p.sample),
                               rtol=0, atol=1e-12, err_msg=str(tol))
    res_s = srds_sample(emodel, sched, solver, x0, cfg_t)
    np.testing.assert_allclose(np.asarray(res_t.sample),
                               np.asarray(res_s.sample),
                               rtol=0, atol=1e-12, err_msg=str(tol))
    if tol == 0.0 and \
            float(jnp.max(jnp.abs(res_t.sample - eref))) > 1e-10:
        raise SystemExit("truncated sharded run lost exactness")
# 16 blocks on 8 devices: truncation shrinks per-device chunks too
cfg16 = SRDSConfig(tol=0.0, num_blocks=16, truncate=True)
res16 = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg16)(x0)
ref16 = make_sharded_sampler(mesh, "time", emodel, sched, solver,
                             SRDSConfig(tol=0.0, num_blocks=16))(x0)
assert int(res16.iterations) == int(ref16.iterations)
np.testing.assert_allclose(np.asarray(res16.sample),
                           np.asarray(ref16.sample), rtol=0, atol=1e-12)
""")


def test_sharded_truncation_rejects_stragglers():
    _run(r"""
try:
    make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                         SRDSConfig(tol=0.0, num_blocks=8, truncate=True),
                         straggler_fn=lambda p: jnp.zeros((8,), bool))(x0)
    raise SystemExit("expected ValueError for truncate + straggler_fn")
except ValueError as e:
    assert "straggler" in str(e), e
""")


def test_sharded_sampler_data_axis_runtime_tol():
    """make_sharded_sampler's runtime-tol path shards the K sample batch
    over a data mesh axis (2D (time, data) mesh): bit-identical to the
    unsharded per-sample run, lane for lane — and non-per-sample configs
    are rejected."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import *
from repro.core.pipelined import make_sharded_sampler
from repro.compat import make_mesh

assert len(jax.devices()) == 8
mesh = make_mesh((4, 2), ("time", "data"))
scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
N = 64
sched = make_schedule("ddpm_linear", N)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64))
solver = SolverConfig("ddim")
xb = jax.random.normal(jax.random.PRNGKey(3), (4, 6), dtype=jnp.float64) \
    * jnp.linspace(0.4, 2.0, 4)[:, None]
tols = jnp.array([1e-2, 1e-4, 1e-6, 1e-3], jnp.float32)
cfg = SRDSConfig(per_sample=True, num_blocks=8)
res_s = srds_sample(emodel, sched, solver, xb, cfg, tol=tols)
samp = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg,
                            data_axis="data")
res_d = samp(xb, tols)
assert np.array_equal(np.asarray(res_d.iterations), np.asarray(res_s.iterations))
assert bool(jnp.all(res_d.sample == res_s.sample))
assert np.array_equal(np.asarray(res_d.delta_history),
                      np.asarray(res_s.delta_history))
# scalar runtime tol broadcasts over the sharded batch
res_sc = samp(xb, 1e-4)
res_sc_ref = srds_sample(emodel, sched, solver, xb, cfg,
                         tol=jnp.full((4,), 1e-4, jnp.float32))
assert bool(jnp.all(res_sc.sample == res_sc_ref.sample))
# joint-norm gating cannot shard the batch: loud error
try:
    make_sharded_sampler(mesh, "time", emodel, sched, solver,
                         SRDSConfig(num_blocks=8), data_axis="data")
    raise SystemExit("expected ValueError without per_sample")
except ValueError as e:
    assert "per_sample" in str(e) or "per-sample" in str(e), e
# K=3 does not divide the 2-wide data axis: loud error at call time
try:
    samp(xb[:3], tols[:3])
    raise SystemExit("expected ValueError for indivisible K")
except ValueError as e:
    assert "not divisible" in str(e), e
# truncation composes with the data-sharded batch (samples to a few f64
# ulps: under shard_map the unrolled-cond loop codegen shifts last bits)
cfg_t = SRDSConfig(per_sample=True, num_blocks=8, truncate=True)
res_t = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_t,
                             data_axis="data")(xb, tols)
assert np.array_equal(np.asarray(res_t.iterations),
                      np.asarray(res_s.iterations))
np.testing.assert_allclose(np.asarray(res_t.sample),
                           np.asarray(res_s.sample), rtol=0, atol=1e-12)
print("DATA AXIS OK")
"""
    r = run_subprocess(code, devices=8)
    assert r.returncode == 0 and "DATA AXIS OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr}"


def test_sharded_window_policies():
    """The FrontierPolicy seam on the sharded driver: window=ExactPrefix()
    is the same compiled program as truncate=True (bitwise — the
    documented ulp caveat is vs the UNtruncated engine, not between these
    two), and the residual window stays serial-close at its tol."""
    _run(r"""
import numpy as np
from repro.core import ExactPrefix, ResidualWindow
scale = jnp.linspace(0.5, 1.5, 6)
emodel = lambda x, t: jnp.tanh(x * scale) * (0.5 + 0.001 * t)
eref = sample_sequential(emodel, sched, solver, x0)
cfg_t = SRDSConfig(tol=1e-4, num_blocks=8, truncate=True)
cfg_w = SRDSConfig(tol=1e-4, num_blocks=8, window=ExactPrefix())
rt = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_t)(x0)
rw = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_w)(x0)
assert int(rt.iterations) == int(rw.iterations)
assert bool(jnp.all(rt.sample == rw.sample))
assert np.array_equal(np.asarray(rt.delta_history),
                      np.asarray(rw.delta_history))
cfg_r = SRDSConfig(tol=1e-4, num_blocks=8, window=ResidualWindow(1e-3))
rr = make_sharded_sampler(mesh, "time", emodel, sched, solver, cfg_r)(x0)
assert float(jnp.max(jnp.abs(rr.sample - eref))) < 5e-2
""")


def test_wavefront_retirement_consults_policy():
    """Per-device retirement now rides FrontierPolicy.retire_at: the
    default (ExactPrefix rule) skips retired devices' evals; an explicit
    FixedBudget window disables retirement — same results, strictly more
    physical evals."""
    _run(r"""
from repro.core import FixedBudget
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=0.0))
samp_nb = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                                 SRDSConfig(tol=0.0, window=FixedBudget()))
res, steps, evals = samp(x0)
res2, steps2, evals2 = samp_nb(x0)
assert float(jnp.max(jnp.abs(res.sample - res2.sample))) < 1e-12
assert int(res.iterations) == int(res2.iterations)
assert int(steps) == int(steps2)
# retirement is the only difference: disabling it must cost strictly more
assert int(evals2) > int(evals), (int(evals2), int(evals))
""")
