"""Distributed SRDS (shard_map + wavefront) equivalence — 8 fake devices in
subprocesses so the main test session keeps a single device."""
import pytest

from conftest import run_subprocess

COMMON = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_sharded_sampler, make_pipelined_sampler

assert len(jax.devices()) == 8
w = jax.random.normal(jax.random.PRNGKey(0), (6, 6), dtype=jnp.float64) * 0.3
def model_fn(x, t):
    return jnp.tanh(x @ w) * (0.5 + 0.001 * t)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("time",))
N = 64
sched = make_schedule("ddpm_linear", N)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64))
x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 6), dtype=jnp.float64)
solver = SolverConfig("ddim")
ref = sample_sequential(model_fn, sched, solver, x0)
"""


def _run(body):
    r = run_subprocess(COMMON + body, devices=8)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    return r.stdout


def test_sharded_exact():
    _run(r"""
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=8))
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
assert int(res.iterations) <= 8
""")


def test_sharded_multiple_blocks_per_device():
    _run(r"""
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=16))
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
""")


def test_sharded_matches_single_program():
    """Distributed == single-program SRDS, iteration for iteration."""
    _run(r"""
for tol in (0.0, 1e-4):
    cfg = SRDSConfig(tol=tol, num_blocks=8)
    res_d = make_sharded_sampler(mesh, "time", model_fn, sched, solver, cfg)(x0)
    res_s = srds_sample(model_fn, sched, solver, x0, cfg)
    assert int(res_d.iterations) == int(res_s.iterations), (tol,)
    assert float(jnp.max(jnp.abs(res_d.sample - res_s.sample))) < 1e-10
""")


def test_wavefront_exact_and_superstep_model():
    """Wavefront == sequential; supersteps == k*S + B - 1 (paper Fig. 4)."""
    _run(r"""
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=0.0))
res, steps = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
k = int(res.iterations); S = N // 8
assert int(steps) <= k * S + 8 + 2, (int(steps), k)
""")


def test_wavefront_early_convergence():
    _run(r"""
samp = make_pipelined_sampler(mesh, "time", model_fn, sched, solver,
                              SRDSConfig(tol=1e-4))
res, steps = samp(x0)
k = int(res.iterations)
assert k < 8, k
assert float(jnp.mean(jnp.abs(res.sample - ref))) < 1e-3
# latency model: supersteps ~ k*S + B - 1 << sequential N (=64 evals) for
# converged k; each superstep is ONE lockstep batched model eval.
assert int(steps) < N, (int(steps), N)
""")


def test_straggler_mitigation_preserves_exactness():
    """Transient stragglers (stale fine results) cost iterations, never
    correctness."""
    _run(r"""
def strag(p):
    m = jnp.zeros((8,), bool).at[3].set(True).at[5].set(True)
    return jnp.where(p % 2 == 1, m, jnp.zeros((8,), bool))
samp = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=0.0, num_blocks=8, max_iters=24),
                            straggler_fn=strag)
res = samp(x0)
assert float(jnp.max(jnp.abs(res.sample - ref))) < 1e-10
base = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                            SRDSConfig(tol=1e-6, num_blocks=8, max_iters=24))(x0)
withs = make_sharded_sampler(mesh, "time", model_fn, sched, solver,
                             SRDSConfig(tol=1e-6, num_blocks=8, max_iters=24),
                             straggler_fn=strag)(x0)
assert int(withs.iterations) >= int(base.iterations)
""")
