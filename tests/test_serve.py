"""Serving engine + sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops

kops.FORCE_REF = True

from repro.configs import get_arch
from repro.models import forward_train, init_params
from repro.serve import Request, ServingEngine


KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-1.6b"])
def test_engine_greedy_matches_full_forward(arch):
    """Engine greedy decode == argmax over the full-sequence forward run
    on the concatenated prompt+generation (teacher-forced check)."""
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, KEY)
    engine = ServingEngine(cfg, params, batch_size=2, max_seq=64)
    prompt = jax.random.randint(KEY, (12,), 0, cfg.vocab_size)
    outs = engine.generate([Request(prompt=prompt, max_new_tokens=6)])
    gen = outs[0]
    # teacher-forced verification of the first generated token
    logits, _, _ = forward_train(cfg, params, {"tokens": prompt[None]})
    first = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    assert gen[0] == first
    # and of the second (condition on the first generated token)
    seq2 = jnp.concatenate([prompt, jnp.array([gen[0]], jnp.int32)])
    logits2, _, _ = forward_train(cfg, params, {"tokens": seq2[None]})
    second = int(jnp.argmax(logits2[0, -1, :cfg.vocab_size]))
    assert gen[1] == second


def test_engine_ragged_batch():
    cfg = get_arch("stablelm-3b").reduced()
    params = init_params(cfg, KEY)
    engine = ServingEngine(cfg, params, batch_size=3, max_seq=64)
    reqs = [Request(prompt=jax.random.randint(jax.random.fold_in(KEY, i),
                                              (4 + 3 * i,), 0, cfg.vocab_size),
                    max_new_tokens=4) for i in range(3)]
    outs = engine.generate(reqs)
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


# --------------------------------------------------------------------------
# sharding rules (pure unit tests on PartitionSpecs — no devices needed)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_param_sharding_rules_subprocess():
    from conftest import run_subprocess
    code = r"""
import jax
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh
from repro.configs import get_arch
from repro.launch.specs import param_specs
from repro.models.transformer import ParallelCtx
from repro.parallel.sharding import param_shardings

mesh = make_mesh((2, 4), ("data", "model"))
par = ParallelCtx(mesh=mesh, model_parallel=4)

# dense arch: TP rules
cfg = get_arch("stablelm-3b")
ps = param_specs(cfg, par)
sh = param_shardings(cfg, mesh, ps, par)
assert sh["embed"]["table"].spec == P("model", None)
assert sh["unembed"]["w"].spec == P(None, "model")
assert sh["blocks"]["attn"]["wq"].spec == P(None, None, "model")
assert sh["blocks"]["attn"]["wo"].spec == P(None, "model", None)
assert sh["blocks"]["mlp"]["w_up"].spec == P(None, None, "model")
assert sh["blocks"]["mlp"]["w_down"].spec == P(None, "model", None)
# FSDP adds the data dim
shf = param_shardings(cfg, mesh, ps, par, fsdp=True)
assert shf["blocks"]["mlp"]["w_up"].spec == P(None, "data", "model")

# MoE arch: EP rules
cfg = get_arch("arctic-480b")
ps = param_specs(cfg, par)
sh = param_shardings(cfg, mesh, ps, par)
assert sh["blocks"]["moe"]["w_up"].spec == P(None, "data", None, "model")
assert sh["blocks"]["moe"]["w_down"].spec == P(None, "data", "model", None)
assert sh["blocks"]["moe"]["router"].spec == P(None, None, None)
# kv heads (8) not divisible by wider TP stay replicated
mesh16 = make_mesh((2, 16), ("data", "model"))
par16 = ParallelCtx(mesh=mesh16, model_parallel=16)
cfgq = get_arch("qwen3-8b")
sh = param_shardings(cfgq, mesh16, param_specs(cfgq, par16), par16)
assert sh["blocks"]["attn"]["wk"].spec == P(None, None, None)
assert sh["blocks"]["attn"]["wq"].spec == P(None, None, "model")
print("SHARDING RULES OK")
"""
    r = run_subprocess(code, devices=32, timeout=600)
    assert r.returncode == 0 and "SHARDING RULES OK" in r.stdout, \
        f"{r.stdout}\n{r.stderr[-3000:]}"


@pytest.mark.slow
@pytest.mark.distributed
def test_cache_sharding_rules_subprocess():
    from conftest import run_subprocess
    code = r"""
import jax
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh
from repro.configs import get_arch, SHAPES
import dataclasses as dc
from repro.launch.specs import cache_specs
from repro.models.transformer import ParallelCtx
from repro.parallel.sharding import cache_shardings

mesh = make_mesh((2, 4), ("data", "model"))
par = ParallelCtx(mesh=mesh, model_parallel=4)
cfg = get_arch("qwen3-8b")
shape = dc.replace(SHAPES["decode_32k"], seq_len=128, global_batch=8)
cs = cache_specs(cfg, shape, par)
sh = cache_shardings(cfg, mesh, cs, par)
k_sh, v_sh = sh
# flash-decoding layout: KV sequence over model, batch over data
assert k_sh.spec == P(None, ("data",), "model", None, None), k_sh.spec
print("CACHE RULES OK")
"""
    r = run_subprocess(code, devices=32, timeout=600)
    assert r.returncode == 0 and "CACHE RULES OK" in r.stdout, \
        f"{r.stdout}\n{r.stderr[-3000:]}"
