"""Arrival-aware SLO scheduler: policies, determinism, exactness, cost
model, compat-key grouping, and the ddpm lane-exactness guard.

Everything here rides the engine's virtual clock (physical model evals x
sec_per_eval), so every latency number is a discrete-event quantity —
bit-reproducible across runs — and the per-request samples must stay
bit-exact vs single-request ``srds_sample`` under EVERY policy (policies
reorder admission; they never touch running-lane math)."""
import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolverConfig, SRDSConfig, iteration_cost,
                        make_schedule, predicted_evals, srds_sample,
                        srds_stats, truncated_evals)
from repro.serve import (EDF, FIFO, CostAware, DiffusionSamplingEngine,
                         SampleRequest, Tier, bursty_trace, poisson_trace,
                         simulate)
from conftest import to_f64

TIERS = [Tier(tol=1e-2, slo_ms=25, iters_hint=2, weight=0.96),
         Tier(tol=1e-6, slo_ms=400, iters_hint=7, weight=0.04)]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _engine(model, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("sec_per_eval", 1e-5)
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, dtype=jnp.float64, **kw)


# --------------------------------------------------------------------------
# traces + simulate determinism
# --------------------------------------------------------------------------

def test_trace_generators_deterministic():
    a = poisson_trace(20, rate=100.0, tiers=TIERS, seed=7)
    b = poisson_trace(20, rate=100.0, tiers=TIERS, seed=7)
    assert [(r.arrival_time, r.tol, r.slo_ms) for r in a] == \
           [(r.arrival_time, r.tol, r.slo_ms) for r in b]
    c = bursty_trace(3, 5, period=0.5, tiers=TIERS, seed=7, jitter=0.01)
    d = bursty_trace(3, 5, period=0.5, tiers=TIERS, seed=7, jitter=0.01)
    assert [(r.arrival_time, r.tol) for r in c] == \
           [(r.arrival_time, r.tol) for r in d]
    # different seeds genuinely differ
    e = poisson_trace(20, rate=100.0, tiers=TIERS, seed=8)
    assert [r.arrival_time for r in a] != [r.arrival_time for r in e]


@pytest.mark.parametrize("policy_cls", [FIFO, EDF, CostAware])
def test_simulate_bit_deterministic(policy_cls):
    """Same trace + policy + engine config -> identical SimReport, down to
    sample bits, on a fresh AND on a warm (program-cached) engine — with a
    trace spanning TWO compatibility groups, so the round-robin cursor's
    reset is exercised too."""
    model = _elementwise_model()
    trace = poisson_trace(12, rate=300.0, tiers=TIERS, seed=0)
    for r in trace[::3]:
        r.num_steps = 36      # second compat group
    eng = _engine(model)
    r1 = simulate(eng, trace, policy_cls())
    r2 = simulate(eng, trace, policy_cls())          # warm engine, reset clock
    r3 = simulate(_engine(model), trace, policy_cls())  # fresh engine
    for other in (r2, r3):
        assert sorted(r1.responses) == sorted(other.responses)
        for rid in r1.responses:
            assert r1.responses[rid].latency == other.responses[rid].latency
            assert r1.responses[rid].finish_time == \
                other.responses[rid].finish_time
            np.testing.assert_array_equal(r1.responses[rid].sample,
                                          other.responses[rid].sample)
        assert (r1.latency_p50, r1.latency_p95, r1.latency_p99) == \
               (other.latency_p50, other.latency_p95, other.latency_p99)
        assert r1.physical_evals == other.physical_evals


# --------------------------------------------------------------------------
# per-request exactness under every policy
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy_cls", [FIFO, EDF, CostAware])
def test_policies_preserve_bit_exactness(policy_cls):
    """Admission order must never perturb a sample: every completed request
    equals the single-request srds_sample result bit for bit."""
    model = _elementwise_model()
    trace = poisson_trace(10, rate=300.0, tiers=TIERS, seed=1)
    rep = simulate(_engine(model), trace, policy_cls())
    assert not rep.rejected and not rep.preempted
    assert len(rep.responses) == len(trace)
    sched = to_f64(make_schedule("ddpm_linear", 64))
    # simulate() submits in arrival order -> rid i is the i-th of the
    # arrival-sorted trace
    ordered = sorted(trace, key=lambda r: r.arrival_time)
    for rid, req in enumerate(ordered):
        x0 = jax.random.normal(jax.random.PRNGKey(req.seed), (8,),
                               jnp.float64)
        ind = srds_sample(model, sched, SolverConfig("ddim"), x0[None],
                          SRDSConfig(tol=req.tol))
        r = rep.responses[rid]
        assert bool(np.all(r.sample == np.asarray(ind.sample[0]))), rid
        assert r.iterations == int(ind.iterations), rid


# --------------------------------------------------------------------------
# EDF vs FIFO, cost-model admission, preemption
# --------------------------------------------------------------------------

def test_edf_beats_fifo_p95_on_fixed_trace():
    """The tentpole's latency claim, pinned to a fixed Poisson trace: under
    load, FIFO's head-of-line blocking (a rare heavy request stalls the
    herd of light ones behind it) inflates p95; EDF's deadline order is
    effectively shortest-job-first here and dodges it."""
    model = _elementwise_model()
    trace = poisson_trace(100, rate=380.0, tiers=TIERS, seed=0)
    eng = _engine(model)
    fifo = simulate(eng, trace, FIFO())
    edf = simulate(eng, trace, EDF())
    assert len(fifo.responses) == len(edf.responses) == len(trace)
    assert edf.latency_p95 < fifo.latency_p95, \
        (edf.latency_p95, fifo.latency_p95)
    assert edf.slo_attainment >= fifo.slo_attainment


def test_cost_model_matches_engine_accounting():
    """predict_completion must be the engine's own iteration_cost arithmetic
    (truncated, matching the frontier schedule the step programs execute)
    — admission decisions and billing can never disagree."""
    model = _elementwise_model()
    eng = _engine(model)
    req = SampleRequest(seed=0, tol=1e-3, iters_hint=3)
    cost = iteration_cost(64, None, 1)
    expect = eng.clock + eng.batch_size * truncated_evals(cost, 3) \
        * eng.sec_per_eval
    assert eng.predict_completion(req) == expect
    # no hint -> worst case max_iters (= B)
    req2 = SampleRequest(seed=0, tol=1e-3)
    expect2 = eng.clock + eng.batch_size * truncated_evals(cost, 8) \
        * eng.sec_per_eval
    assert eng.predict_completion(req2) == expect2
    # a truncation-disabled engine predicts with the untruncated unit cost
    eng_u = _engine(model, truncate=False)
    expect_u = eng_u.clock + eng_u.batch_size * predicted_evals(cost, 3) \
        * eng_u.sec_per_eval
    assert eng_u.predict_completion(req) == expect_u
    # and srds_stats' totals ride the same exports
    sched = make_schedule("ddpm_linear", 64)
    st = srds_stats(sched, SolverConfig("ddim"), SRDSConfig(), 3)
    assert st.total_evals == predicted_evals(cost, 3)
    st_t = srds_stats(sched, SolverConfig("ddim"), SRDSConfig(truncate=True), 3)
    assert st_t.total_evals == truncated_evals(cost, 3)


def test_predict_completion_accounts_cross_group_contention():
    """Busy micro-batches step round-robin on the one device, so a
    request's completion estimate charges every OTHER busy group one step
    at its current frontier cost per refinement round — an idle engine
    and same-group requests see no contention term."""
    model = _elementwise_model()
    eng = _engine(model)
    req36 = SampleRequest(seed=5, tol=1e-3, num_steps=36, iters_hint=3)
    cost36 = iteration_cost(36, None, 1)
    own36 = eng.batch_size * truncated_evals(cost36, 3)
    # idle engine: the pre-contention arithmetic, unchanged
    assert eng.predict_completion(req36) == \
        eng.clock + own36 * eng.sec_per_eval
    # occupy the 64-grid group -> its per-step cost contends
    rid, req = eng.submit(SampleRequest(seed=0, tol=1e-6)), None
    [(rid, req)] = eng.pull_queue()
    eng.admit(rid, req)
    cost64 = iteration_cost(64, None, 1)
    step64 = eng.batch_size * cost64.refine_evals_at(0)  # group frontier 0
    assert eng.predict_completion(req36) == \
        eng.clock + (own36 + 3 * step64) * eng.sec_per_eval
    # a SAME-group request is co-batched, not contended against
    req64 = SampleRequest(seed=9, tol=1e-3, iters_hint=3)
    own64 = eng.batch_size * truncated_evals(cost64, 3)
    assert eng.predict_completion(req64) == \
        eng.clock + own64 * eng.sec_per_eval
    eng.drain()
    # drained: the contention term disappears again
    assert eng.predict_completion(req36) == \
        eng.clock + own36 * eng.sec_per_eval


def test_online_iters_predictor_learns_from_completions():
    """The EMA predictor replaces iters_hint once the tier has completions:
    predictions converge toward observed iteration counts, reset with
    engine metrics, and never exceed the worst-case cap."""
    model = _elementwise_model()
    eng = _engine(model)
    req = SampleRequest(seed=0, tol=1e-2, iters_hint=7)
    # before any completion: falls back to the (bad) static hint
    assert eng.predict_iterations(req) == 7.0
    for i in range(4):
        eng.submit(SampleRequest(seed=i, tol=1e-2))
    out = eng.drain()
    observed = {out[r].iterations for r in out}
    est = eng.predict_iterations(req)
    assert min(observed) <= est <= max(observed)
    # learned estimate now beats the static hint in predict_completion
    cost = iteration_cost(64, None, 1)
    expect = eng.clock + eng.batch_size * truncated_evals(cost, est) \
        * eng.sec_per_eval
    assert eng.predict_completion(req) == pytest.approx(expect)
    # other tiers (different tol) are unaffected: hint fallback
    assert eng.predict_iterations(SampleRequest(seed=9, tol=1e-6,
                                                iters_hint=5)) == 5.0
    # the estimate is the MOST OPTIMISTIC of EMA and hint (an EMA is a
    # mean, so alone it could over-reject an easier-than-average request)
    low_hint = SampleRequest(seed=9, tol=1e-2, iters_hint=1)
    assert eng.predict_iterations(low_hint) == 1.0
    # reset_metrics clears the learned state (warm-run determinism)
    eng.reset_metrics()
    assert eng.predict_iterations(req) == 7.0


def test_cost_aware_rejects_hopeless_requests():
    """A request whose optimistic predicted completion already misses its
    deadline is shed at admission; feasible batch-mates are unaffected."""
    model = _elementwise_model()
    eng = _engine(model)
    # worst case for a truncated 64-grid run: ~790 K-lane evals * 1e-5
    # s/eval = 7.9 ms -> a 1 ms SLO is hopeless, a 1 s SLO is comfortable
    trace = [SampleRequest(seed=0, tol=1e-6, arrival_time=0.0, slo_ms=1.0),
             SampleRequest(seed=1, tol=1e-2, arrival_time=0.0, slo_ms=1000.0,
                           iters_hint=2)]
    rep = simulate(eng, trace, CostAware())
    assert rep.rejected == [0]
    assert sorted(rep.responses) == [1]
    assert rep.responses[1].slo_met
    # FIFO happily runs it (and the ledger shows the SLO miss)
    rep_fifo = simulate(eng, trace, FIFO())
    assert not rep_fifo.rejected
    assert not rep_fifo.responses[0].slo_met
    assert rep_fifo.slo_attainment < 1.0


def test_cost_aware_preempts_blown_deadline():
    """With preempt=True a runner whose deadline already passed is evicted
    when a still-feasible request waits — and the survivor's sample is
    STILL bit-exact (frozen-lane masking shields batch-mates)."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=1)   # single slot forces the conflict
    # iters_hint=1 lies optimistically: the request passes admission control
    # (predicted 0.8 ms < 3 ms SLO) but actually refines for ~7 iterations,
    # blowing its deadline mid-flight
    trace = [SampleRequest(seed=0, tol=1e-6, arrival_time=0.0, slo_ms=3.0,
                           iters_hint=1),
             SampleRequest(seed=1, tol=1e-2, arrival_time=0.004,
                           slo_ms=1000.0, iters_hint=2)]
    rep = simulate(eng, trace, CostAware(preempt=True))
    assert rep.preempted == [0]
    assert sorted(rep.responses) == [1]
    r = rep.responses[1]
    assert r.slo_met
    sched = to_f64(make_schedule("ddpm_linear", 64))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8,), jnp.float64)
    ind = srds_sample(model, sched, SolverConfig("ddim"), x0[None],
                      SRDSConfig(tol=1e-2))
    assert bool(np.all(r.sample == np.asarray(ind.sample[0])))
    # without preemption the late runner hogs the only slot to convergence
    rep2 = simulate(eng, trace, CostAware(preempt=False))
    assert not rep2.preempted and sorted(rep2.responses) == [0, 1]


# --------------------------------------------------------------------------
# compatibility key: (grid, solver, schedule, shape)
# --------------------------------------------------------------------------

def test_compat_key_splits_solver_schedule_shape():
    """Mixed solver/schedule/shape workloads must not share one compiled
    program — and every request still matches its own single-request run
    bit for bit."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=2)
    reqs = [SampleRequest(seed=0, tol=1e-3),
            SampleRequest(seed=1, tol=1e-3, solver=SolverConfig("heun")),
            SampleRequest(seed=2, tol=1e-3, schedule="cosine"),
            SampleRequest(seed=3, tol=1e-3, num_steps=36)]
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    assert len(eng._batches) == 4          # four distinct compat groups
    for rid, req in zip(rids, reqs):
        n = req.num_steps or 64
        sched = to_f64(make_schedule(req.schedule or "ddpm_linear", n))
        solver = req.solver or SolverConfig("ddim")
        x0 = jax.random.normal(jax.random.PRNGKey(req.seed), (8,),
                               jnp.float64)
        ind = srds_sample(model, sched, solver, x0[None],
                          SRDSConfig(tol=req.tol))
        assert bool(np.all(out[rid].sample == np.asarray(ind.sample[0]))), rid
        assert out[rid].iterations == int(ind.iterations), rid


def test_compat_key_shape_override():
    model = _elementwise_model(dim=4)

    def model_any(x, t):     # elementwise model independent of trailing dim
        return jnp.tanh(x) * (0.5 + 0.001 * t)

    eng = DiffusionSamplingEngine(model_any, (8,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64)
    r1 = eng.submit(SampleRequest(seed=0, tol=1e-3))
    r2 = eng.submit(SampleRequest(seed=1, tol=1e-3, shape=(4,)))
    out = eng.drain()
    assert out[r1].sample.shape == (8,)
    assert out[r2].sample.shape == (4,)
    assert len(eng._batches) == 2


# --------------------------------------------------------------------------
# submit-time validation (incl. the ddpm lane-exactness guard)
# --------------------------------------------------------------------------

def test_submit_rejects_ddpm_without_optin():
    model = _elementwise_model()
    eng = _engine(model)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="lane-exactness"):
        eng.submit(SampleRequest(seed=0,
                                 solver=SolverConfig("ddpm", noise_key=key)))
    # engine-default ddpm is guarded too
    eng2 = DiffusionSamplingEngine(model, (8,),
                                   SolverConfig("ddpm", noise_key=key),
                                   num_steps=64, dtype=jnp.float64)
    with pytest.raises(ValueError, match="lane-exactness"):
        eng2.submit(SampleRequest(seed=0))
    # the queue stays clean: nothing to drain
    assert eng.drain() == {}


def test_submit_accepts_ddpm_with_optin():
    model = _elementwise_model()
    eng = _engine(model, allow_inexact=True)
    key = jax.random.PRNGKey(0)
    rid = eng.submit(SampleRequest(seed=0, tol=1e-3,
                                   solver=SolverConfig("ddpm",
                                                       noise_key=key)))
    out = eng.drain()
    assert out[rid].iterations >= 1
    assert np.all(np.isfinite(out[rid].sample))


def test_submit_rejects_unknown_solver_and_schedule():
    model = _elementwise_model()
    eng = _engine(model)
    with pytest.raises(KeyError):
        SampleRequest(seed=0, solver=SolverConfig("rk9")).solver.evals_per_step
    with pytest.raises(ValueError, match="unknown solver"):
        eng.submit(SampleRequest(seed=0, solver=SolverConfig("rk9")))
    with pytest.raises(ValueError, match="unknown schedule"):
        eng.submit(SampleRequest(seed=0, schedule="not_a_schedule"))
    assert eng.drain() == {}


# --------------------------------------------------------------------------
# stats surface
# --------------------------------------------------------------------------

def test_stats_latency_and_goodput_counters():
    model = _elementwise_model()
    eng = _engine(model)
    trace = poisson_trace(8, rate=300.0, tiers=TIERS, seed=3)
    rep = simulate(eng, trace, EDF())
    st = eng.stats()
    assert st["requests_served"] == 8
    assert 0.0 < st["latency_p50"] <= st["latency_p95"] <= st["latency_p99"]
    assert st["latency_p95"] == rep.latency_p95
    assert 0.0 <= st["slo_attainment"] <= 1.0
    # engine goodput == report goodput: both span first-arrival -> idle
    assert st["goodput_rps"] == rep.goodput_rps > 0
    assert st["virtual_time"] > 0
    # deadline-free requests never count against attainment
    eng2 = _engine(model)
    for i in range(3):
        eng2.submit(SampleRequest(seed=i, tol=1e-3))
    eng2.drain()
    assert eng2.stats()["slo_attainment"] == 1.0
    # a REJECTED first arrival (no completion record) still anchors the
    # goodput span, so engine stats and SimReport agree even then
    eng3 = _engine(model)
    trace = [SampleRequest(seed=0, tol=1e-6, arrival_time=0.0, slo_ms=1.0),
             SampleRequest(seed=1, tol=1e-2, arrival_time=0.5,
                           slo_ms=1000.0, iters_hint=2)]
    rep3 = simulate(eng3, trace, CostAware())
    assert rep3.rejected == [0]
    assert eng3.stats()["goodput_rps"] == pytest.approx(rep3.goodput_rps)


def test_hold_back_policy_waits_for_next_arrival():
    """A policy may legally return None from select() to hold requests back
    (e.g. waiting to co-batch); simulate() must jump the clock to the next
    arrival instead of declaring the engine wedged — and must still raise
    when nothing can ever unblock the policy."""
    model = _elementwise_model()

    class CoBatch(FIFO):
        name = "cobatch"

        def select(self, now, queue, engine):
            if len(queue) < 2 and not engine.busy():
                return None          # wait for a batch-mate before starting
            return super().select(now, queue, engine)

    trace = [SampleRequest(seed=0, tol=1e-2, arrival_time=0.0),
             SampleRequest(seed=1, tol=1e-2, arrival_time=0.05)]
    rep = simulate(_engine(model), trace, CoBatch())
    assert sorted(rep.responses) == [0, 1]
    # request 0 was held until request 1 arrived at t=0.05
    assert rep.responses[0].latency >= 0.05
    with pytest.raises(RuntimeError, match="admitted nothing"):
        simulate(_engine(model), trace[:1], CoBatch())


def test_drain_clock_catches_up_to_arrival():
    """drain() ignores deadlines but must keep the ledger honest for
    future-stamped arrivals: no negative latencies, and admitting a
    far-future request must not warp the clock past co-batched work."""
    model = _elementwise_model()
    eng = _engine(model)
    eng.submit(SampleRequest(seed=0, tol=1e-2, arrival_time=10.0))
    out = eng.drain()
    assert out[0].latency >= 0.0
    assert eng.stats()["latency_p50"] >= 0.0
    assert eng.clock >= 10.0
    # a present request batched alongside a far-future one keeps its own
    # (small) latency and meets its SLO
    eng2 = _engine(model, batch_size=4)
    ra = eng2.submit(SampleRequest(seed=0, tol=1e-2, arrival_time=0.0,
                                   slo_ms=100.0))
    rb = eng2.submit(SampleRequest(seed=1, tol=1e-2, arrival_time=1000.0))
    out2 = eng2.drain()
    assert out2[ra].slo_met and out2[ra].latency < 0.1
    assert out2[rb].latency >= 0.0
    assert eng2.stats()["slo_attainment"] == 1.0


def test_data_axis_requires_divisible_batch():
    model = _elementwise_model()
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    # divisible: fine
    _engine(model, batch_size=2, mesh=mesh, data_axis="data")
    with pytest.raises(ValueError, match="data_axis requires a mesh"):
        _engine(model, batch_size=2, data_axis="data")