"""Dry-run path integration: lower+compile cells on an 8-fake-device mesh
(reduced configs, shrunk shapes) — covers sharding rules, EP shard_map,
cache layouts, SRDS sample cell and the analysis extrapolation machinery."""
import pytest

from conftest import run_subprocess

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

CODE_TEMPLATE = r"""
import jax, dataclasses as dc
from repro.compat import make_mesh
from repro.configs import get_arch, SHAPES
from repro.launch.dryrun import lower_cell, analyze

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_arch("{arch}").reduced()
if cfg.frontend == "vision":
    cfg = dc.replace(cfg, num_prefix_embeds=4)
shape = SHAPES["{shape}"]
shape = dc.replace(shape, seq_len=min(shape.seq_len, 128),
                   global_batch=min(shape.global_batch, 8))
lowered, compiled, meta = lower_cell(cfg, shape, mesh)
r = analyze(cfg, shape.name, mesh, lowered, compiled, meta)
assert r["flops_per_device"] > 0
assert compiled.memory_analysis() is not None
# with the perf knobs on
lowered, compiled, meta = lower_cell(
    cfg, shape, mesh,
    overrides=dict(ce_masksum=True, attn_chunk_kv=64, fsdp=True))
print("CELL OK", r["roofline"]["dominant"])
"""

CASES = [
    ("stablelm-3b", "train_4k"),
    ("qwen3-8b", "decode_32k"),
    ("arctic-480b", "train_4k"),      # EP a2a path
    ("rwkv6-1.6b", "prefill_32k"),
    ("hymba-1.5b", "long_500k"),
    ("hubert-xlarge", "train_4k"),
]


@pytest.mark.parametrize("arch,shape", CASES, ids=lambda v: str(v))
def test_dryrun_cell(arch, shape):
    r = run_subprocess(CODE_TEMPLATE.format(arch=arch, shape=shape),
                       devices=8, timeout=900)
    assert r.returncode == 0 and "CELL OK" in r.stdout, \
        f"{r.stdout[-2000:]}\n{r.stderr[-4000:]}"


def test_dryrun_srds_sample_cell():
    code = r"""
import jax, dataclasses as dc
from repro.compat import make_mesh
from repro.configs import get_arch
from repro.launch.dryrun import lower_cell, analyze
mesh = make_mesh((2, 4), ("data", "model"))
cfg = dc.replace(get_arch("srds-dit-cifar").reduced(), patch_size=4,
                 in_channels=3)
lowered, compiled, meta = lower_cell(cfg, None, mesh, sample_blocks=4)
r = analyze(cfg, "sample", mesh, lowered, compiled, meta)
# time-parallelism must produce ring traffic between block owners
assert r["collectives"]["collective-permute"]["count"] > 0 or \
       r["collectives"]["all-gather"]["count"] > 0
print("SRDS CELL OK")
"""
    r = run_subprocess(code, devices=8, timeout=900)
    assert r.returncode == 0 and "SRDS CELL OK" in r.stdout, \
        f"{r.stdout[-2000:]}\n{r.stderr[-4000:]}"


def test_production_mesh_shapes():
    code = r"""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
assert m2.axis_names == ("pod", "data", "model")
print("MESH OK")
"""
    r = run_subprocess(code, devices=512, timeout=300)
    assert r.returncode == 0 and "MESH OK" in r.stdout, r.stderr
