"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, dtypes, masks and GQA groupings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref, tuning

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)

# tile sizes route through the tuning seam (RL010): one override tuner
# instead of raw block integers at every dispatch call site
TUNER32 = tuning.KernelTuner(overrides={"flash": {"block_q": 32,
                                                  "block_k": 32}})


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATTN_CASES = [
    # B, Hq, Hkv, Sq, Sk, D, causal, window
    (2, 4, 4, 64, 64, 32, True, None),
    (1, 8, 2, 128, 128, 64, True, None),      # GQA 4x
    (2, 4, 1, 32, 32, 16, False, None),       # MQA, bidirectional (encoder)
    (1, 4, 4, 64, 64, 32, True, 16),          # sliding window
    (1, 2, 2, 1, 128, 32, True, None),        # decode: 1 query vs cache
    (1, 4, 2, 48, 48, 24, True, None),        # ragged tiles
    (1, 4, 4, 80, 80, 40, True, 8),           # ragged + window
    (2, 2, 2, 100, 100, 32, False, None),
    (1, 4, 2, 40, 104, 32, True, None),       # chunked prefill (Sq < Sk)
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=lambda c: f"B{c[0]}H{c[1]}-{c[2]}S{c[3]}x{c[4]}D{c[5]}c{int(c[6])}w{c[7]}")
def test_flash_attention_fwd(case):
    b, hq, hkv, sq, sk, d, causal, win = case
    q = jax.random.normal(KEYS[0], (b, hq, sq, d))
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d))
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d))
    out = ops.attention(q, k, v, causal=causal, window=win,
                        tuner=TUNER32, use_kernel=True)
    exp = ref.attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", [c for c in ATTN_CASES if c[3] > 1],
                         ids=lambda c: f"S{c[3]}x{c[4]}w{c[7]}g{c[1]//c[2]}")
def test_flash_attention_grads(case):
    b, hq, hkv, sq, sk, d, causal, win = case
    q = jax.random.normal(KEYS[0], (b, hq, sq, d))
    k = jax.random.normal(KEYS[1], (b, hkv, sk, d))
    v = jax.random.normal(KEYS[2], (b, hkv, sk, d))

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(
            fn(q, k, v)))

    gk = jax.grad(loss(lambda q, k, v: ops.attention(
        q, k, v, causal=causal, window=win, tuner=TUNER32,
        use_kernel=True)), (0, 1, 2))(q, k, v)
    gr = jax.grad(loss(lambda q, k, v: ref.attention(
        q, k, v, causal=causal, window=win)), (0, 1, 2))(q, k, v)
    for a, b_, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-5, atol=5e-5, err_msg=f"d{name}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEYS[0], (1, 4, 64, 32), dtype)
    k = jax.random.normal(KEYS[1], (1, 2, 64, 32), dtype)
    v = jax.random.normal(KEYS[2], (1, 2, 64, 32), dtype)
    out = ops.attention(q, k, v, tuner=TUNER32, use_kernel=True)
    exp = ref.attention(q, k, v)
    assert out.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(2, 96), dk=st.sampled_from([8, 16, 24, 64]),
       hq=st.sampled_from([1, 2, 4]), group=st.sampled_from([1, 2]),
       causal=st.booleans())
def test_flash_attention_property(sq, dk, hq, group, causal):
    hkv = max(1, hq // group)
    q = jax.random.normal(KEYS[3], (1, hkv * group, sq, dk))
    k = jax.random.normal(KEYS[4], (1, hkv, sq, dk))
    v = jax.random.normal(KEYS[5], (1, hkv, sq, dk))
    out = ops.attention(q, k, v, causal=causal, tuner=TUNER32,
                        use_kernel=True)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# rwkv6 wkv
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1, 16, 8, 8), (2, 3, 40, 16, 16),
                                   (1, 2, 64, 32, 32), (1, 1, 7, 8, 8)])
def test_rwkv6_wkv(shape):
    b, h, t, dk, dv = shape
    r = jax.random.normal(KEYS[0], (b, h, t, dk)) * 0.5
    k = jax.random.normal(KEYS[1], (b, h, t, dk)) * 0.5
    v = jax.random.normal(KEYS[2], (b, h, t, dv)) * 0.5
    w = jax.random.normal(KEYS[3], (b, h, t, dk)) * 0.5 - 1.0
    u = jax.random.normal(KEYS[4], (h, dk)) * 0.3
    out_k, s_k = ops.rwkv6_wkv(r, k, v, w, u, use_kernel=True)
    out_r, s_r = ref.rwkv6_wkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)


def test_rwkv6_state_chaining():
    """Processing [T1 | T2] in two kernel calls with state carry == one call."""
    b, h, t, d = 1, 2, 32, 8
    r, k, v, w = (jax.random.normal(KEYS[i], (b, h, t, d)) * 0.5 for i in range(4))
    u = jax.random.normal(KEYS[4], (h, d)) * 0.3
    full, s_full = ops.rwkv6_wkv(r, k, v, w, u, use_kernel=True)
    o1, s1 = ops.rwkv6_wkv(r[:, :, :16], k[:, :, :16], v[:, :, :16],
                           w[:, :, :16], u, use_kernel=True)
    o2, s2 = ops.rwkv6_wkv(r[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                           w[:, :, 16:], u, state=s1, use_kernel=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], axis=2)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# fused elementwise
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5000), a=st.floats(0.01, 0.98), db=st.floats(0.01, 0.3),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_ddim_fused_property(n, a, db, dtype):
    dt = jnp.dtype(dtype)
    x = jax.random.normal(KEYS[0], (n,), dt)
    e = jax.random.normal(KEYS[1], (n,), dt)
    b = min(a + db, 0.999)
    out = ops.ddim_fused(x, e, a, b, use_kernel=True)
    exp = ref.ddim_fused(x, e, a, b)
    assert out.shape == x.shape and out.dtype == dt
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=1e-2 if dtype == "bfloat16" else 1e-6,
                               atol=1e-2 if dtype == "bfloat16" else 1e-6)


@settings(max_examples=15, deadline=None)
@given(shape=st.sampled_from([(7,), (33, 5), (4, 129), (2, 3, 64), (1000,)]))
def test_parareal_update_property(shape):
    y = jax.random.normal(KEYS[0], shape)
    c = jax.random.normal(KEYS[1], shape)
    p = jax.random.normal(KEYS[2], shape)
    out_k, r_k = ops.parareal_update(y, c, p, use_kernel=True)
    out_r, r_r = ref.parareal_update(y, c, p)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(r_k), float(r_r), rtol=1e-4)


def test_srds_with_fused_kernels_end_to_end():
    """SRDS with the fused Pallas update == SRDS with plain jnp update."""
    from repro.core import (SolverConfig, SRDSConfig, make_schedule,
                            sample_sequential, srds_sample)
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.3

    def model_fn(x, t):
        return jnp.tanh(x @ w) * (0.5 + 0.001 * t)

    sched = make_schedule("ddpm_linear", 16)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    res_f = srds_sample(model_fn, sched,
                        SolverConfig("ddim", use_fused_kernel=True), x0,
                        SRDSConfig(tol=0.0, use_fused_update=True))
    res_p = srds_sample(model_fn, sched, SolverConfig("ddim"), x0,
                        SRDSConfig(tol=0.0))
    np.testing.assert_allclose(np.asarray(res_f.sample),
                               np.asarray(res_p.sample), rtol=1e-5, atol=1e-5)
