"""FrontierPolicy window state machine: the policy seam must reproduce
PR 4 truncation bit-identically under ExactPrefix, realize the documented
approximate-mode contract under ResidualWindow (fewer evals, window_tol-
bounded drift, monotone window), and keep the serve hot loop's one-sync
contract with the per-block residual piggybacked on the existing fetch.

Bitwise tests use an elementwise denoiser (the repo's standard trick: lane
math is then identical across fine-solve batch widths, so any mismatch is
a real frontier bug, not an XLA gemm-kernel shape effect)."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExactPrefix, FixedBudget, FrontierPolicy,
                        ResidualWindow, SolverConfig, SRDSConfig,
                        iteration_cost, make_schedule, predicted_evals,
                        resolve_policy, sample_sequential, srds_sample,
                        truncated_evals, windowed_evals)
from repro.core.engine import blockwise_norm, prefix_frontier
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest
import repro.serve.diffusion as serve_diffusion
from conftest import to_f64

TOLS = [1e-2, 1e-4, 1e-6, 1e-3, 1e-5]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _x0(batch=3, dim=8):
    return jax.random.normal(jax.random.PRNGKey(1), (batch, dim),
                             dtype=jnp.float64)


# --------------------------------------------------------------------------
# policy unit semantics
# --------------------------------------------------------------------------

def test_resolve_policy_mapping():
    """The legacy truncate bool maps onto the seam in exactly one place;
    non-policies are rejected loudly."""
    assert isinstance(resolve_policy(None, True), ExactPrefix)
    assert isinstance(resolve_policy(None, False), FixedBudget)
    rw = ResidualWindow(1e-2)
    assert resolve_policy(rw, False) is rw
    assert resolve_policy(rw, True) is rw      # explicit policy wins
    with pytest.raises(TypeError, match="FrontierPolicy"):
        resolve_policy("exact", False)
    # the flags drivers dispatch on
    assert ExactPrefix().truncates and ExactPrefix().exact
    assert not ExactPrefix().needs_block_residuals
    assert rw.truncates and not rw.exact and rw.needs_block_residuals
    assert not FixedBudget().truncates and FixedBudget().exact


def test_static_frontier_schedules():
    """ExactPrefix's static frontier is the PR 4 prefix_frontier schedule
    (capped at B-1: the final block never retires); ResidualWindow shares
    it as its compile-time floor; FixedBudget never truncates."""
    B = 6
    exact = [ExactPrefix().static_frontier(p, B) for p in range(9)]
    assert exact == [min(prefix_frontier(p), B - 1) for p in range(9)]
    assert exact[:4] == [0, 0, 1, 2] and exact[-1] == B - 1
    assert [ResidualWindow(1e-3).static_frontier(p, B) for p in range(9)] \
        == exact
    assert all(FixedBudget().static_frontier(p, B) == 0 for p in range(9))


@pytest.mark.parametrize("xp", ["numpy", "jax"])
def test_residual_window_advance_contiguous_run(xp):
    """advance() slides past the longest contiguous under-tolerance run
    starting at lo — never past a still-moving block, never backward,
    never onto the final block — on host numpy (the serving loop) and
    traced jnp (the engine carry) alike."""
    conv = np if xp == "numpy" else jnp
    pol = ResidualWindow(window_tol=1e-3)
    r = conv.asarray([1e-5, 1e-4, 5e-1, 1e-6, 1e-6, 1e-6], np.float32)
    # blocks 0-1 pass, block 2 blocks the run despite 3-5 passing
    assert int(pol.advance(0, r, 6)) == 2
    # blocks below lo count as passed even if their entry is stale-large
    assert int(pol.advance(3, r, 6)) == 5          # capped at B-1
    assert int(pol.advance(2, r, 6)) == 2          # stuck on block 2
    # monotone: never retreats even when everything is over tolerance
    hot = conv.ones((6,), np.float32)
    assert int(pol.advance(4, hot, 6)) == 4
    # all-pass jumps to the cap, not past it
    cold = conv.zeros((6,), np.float32)
    assert int(pol.advance(0, cold, 6)) == 5


def test_residual_window_advance_per_sample():
    """A trailing sample axis rides through advance(): each sample's
    window advances on its own residual column."""
    pol = ResidualWindow(window_tol=1e-3)
    r = np.asarray([[1e-5, 1e-1], [1e-5, 1e-5], [1e-1, 1e-5]], np.float32)
    lo = pol.advance(np.zeros((2,), np.int32), r, 3)
    assert lo.shape == (2,)
    assert list(lo) == [2, 0]
    # and respects per-sample starting bounds
    lo2 = pol.advance(np.asarray([0, 1], np.int32), r, 3)
    assert list(lo2) == [2, 2]


def test_fixed_budget_never_retires():
    pol = FixedBudget()
    assert int(pol.retire_at(2, 8, 5)) == 5
    assert int(pol.retire_at(7, 8, 5)) == 5
    r = np.ones((4,), np.float32)
    assert int(pol.advance(0, r, 4)) == 0
    cost = iteration_cost(100, None, 1)
    assert pol.predict_evals(cost, 4) == predicted_evals(cost, 4)


def test_exact_prefix_retire_at_matches_wavefront_rule():
    """The wavefront's per-device retirement rule, now policy-owned: block
    i retires after min(i+1, max_iters) refinements, the tail never early."""
    pol = ExactPrefix()
    d, max_iters = 8, 5
    got = [int(pol.retire_at(i, d, max_iters)) for i in range(d)]
    assert got == [1, 2, 3, 4, 5, 5, 5, max_iters]
    assert int(ResidualWindow(1e-3).retire_at(3, d, max_iters)) == got[3]


# --------------------------------------------------------------------------
# windowed accounting
# --------------------------------------------------------------------------

def test_refine_evals_window_and_windowed_evals():
    """(lo, hi) window costs generalize the suffix frontier costs, and
    windowed_evals prices a realized lo-schedule (skipping -1 fill)."""
    cost = iteration_cost(100, None, 1)          # B=10, S=10
    assert cost.refine_evals_window(0) == cost.refine_evals == 110
    assert cost.refine_evals_window(3) == cost.refine_evals_at(3) == 7 * 11
    assert cost.refine_evals_window(0, 4) == 4 * 11
    assert cost.refine_evals_window(2, 6) == 4 * 11
    # the final in-window block never retires: floors at one live block
    assert cost.refine_evals_window(99) == 11
    assert cost.refine_evals_window(6, 6) == 11
    ws = windowed_evals(cost, [0, 0, 3, 7, -1, -1])
    assert ws == cost.init_evals + 110 + 110 + 7 * 11 + 3 * 11
    # a per-sample (max_iters, K) history prices each sample's own column
    hist2 = np.asarray([[0, 0], [0, 3], [3, -1], [-1, -1]])
    per = windowed_evals(cost, hist2)
    assert per.shape == (2,)
    assert list(per) == [windowed_evals(cost, hist2[:, 0]),
                         windowed_evals(cost, hist2[:, 1])]
    # a window at least as advanced as the prefix schedule costs no more
    assert windowed_evals(cost, [prefix_frontier(p) for p in range(5)]) \
        == truncated_evals(cost, 5)
    ahead = [max(prefix_frontier(p), min(2 * p, 9)) for p in range(5)]
    assert windowed_evals(cost, ahead) < truncated_evals(cost, 5)


def test_blockwise_norm_matches_per_block_reduction():
    d = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 5))
    for kind in ("l1_mean", "l2_mean", "linf"):
        bn = blockwise_norm(d, kind, batched=True)
        assert bn.shape == (4, 3)
        from repro.core.engine import convergence_norm
        np.testing.assert_allclose(
            np.asarray(bn[2]), np.asarray(convergence_norm(d[2], kind,
                                                           batched=True)),
            rtol=1e-6)
    with pytest.raises(ValueError, match="unknown norm"):
        blockwise_norm(d, "l7")


# --------------------------------------------------------------------------
# engine: ExactPrefix == PR 4 truncation, ResidualWindow contract
# --------------------------------------------------------------------------

@pytest.mark.parametrize("per_sample", [False, True])
def test_exact_prefix_policy_bit_identical_to_truncate(per_sample):
    """The acceptance bar: window=ExactPrefix() reproduces the PR 4
    truncate=True engine bit for bit (sample, iterations, delta_history),
    joint and per-sample gated."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    if per_sample:
        x = _x0(len(TOLS)) * jnp.linspace(0.3, 2.5, len(TOLS))[:, None]
        tol = jnp.asarray(TOLS, jnp.float32)
    else:
        x, tol = _x0(), None
    a = srds_sample(model, sched, SolverConfig("ddim"), x,
                    SRDSConfig(tol=1e-4, per_sample=per_sample,
                               truncate=True), tol=tol)
    b = srds_sample(model, sched, SolverConfig("ddim"), x,
                    SRDSConfig(tol=1e-4, per_sample=per_sample,
                               window=ExactPrefix()), tol=tol)
    assert bool(jnp.all(a.sample == b.sample))
    np.testing.assert_array_equal(np.asarray(a.iterations),
                                  np.asarray(b.iterations))
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(b.delta_history))
    # exact policies carry no window history
    assert a.window_history is None and b.window_history is None


def test_residual_window_fewer_evals_bounded_error():
    """The approximate-mode contract on one run: the realized window
    schedule (window_history) prices strictly below the ExactPrefix
    schedule, the window is monotone and floored at the provable prefix,
    and the sample drifts from the serial solve by O(window_tol) only."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    wt = 1e-3
    cfg = SRDSConfig(tol=1e-5, window=ResidualWindow(wt))
    res = srds_sample(model, sched, SolverConfig("ddim"), _x0(), cfg)
    k = int(res.iterations)
    hist = np.asarray(res.window_history)
    assert hist.shape == (8,)                      # (max_iters,) = (B,)
    los = hist[:k]
    assert np.all(los >= 0) and np.all(hist[k:] == -1)
    assert np.all(np.diff(los) >= 0)               # monotone
    for p, lo in enumerate(los):                   # floored at the prefix
        assert lo >= min(prefix_frontier(p), 7)
    assert np.any(los > [prefix_frontier(p) for p in range(k)]), \
        "window never advanced past the provable prefix"
    cost = iteration_cost(64, None, 1)
    assert windowed_evals(cost, hist) < truncated_evals(cost, k)
    ref = sample_sequential(model, sched, SolverConfig("ddim"), _x0())
    exact = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                        SRDSConfig(tol=1e-5, truncate=True))
    err_w = float(jnp.max(jnp.abs(res.sample - ref)))
    err_e = float(jnp.max(jnp.abs(exact.sample - ref)))
    assert err_w <= 20.0 * wt + 10.0 * err_e


def test_residual_window_zero_tol_degenerates_to_exact():
    """window_tol=0 freezes nothing beyond the provable prefix: results
    equal the ExactPrefix engine bit for bit, with the history pinned to
    the prefix schedule."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    a = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                    SRDSConfig(tol=1e-4, truncate=True))
    z = srds_sample(model, sched, SolverConfig("ddim"), _x0(),
                    SRDSConfig(tol=1e-4, window=ResidualWindow(0.0)))
    assert bool(jnp.all(a.sample == z.sample))
    assert int(a.iterations) == int(z.iterations)
    np.testing.assert_array_equal(np.asarray(a.delta_history),
                                  np.asarray(z.delta_history))
    k = int(z.iterations)
    np.testing.assert_array_equal(
        np.asarray(z.window_history)[:k],
        [min(prefix_frontier(p), 7) for p in range(k)])


def test_residual_window_per_sample_independent_windows():
    """Per-sample gating composes with the residual window: each sample
    carries its own window column, frozen samples' windows freeze with
    them, and every sample still converges to its own tolerance."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    X = _x0(len(TOLS)) * jnp.linspace(0.3, 2.5, len(TOLS))[:, None]
    tols = jnp.asarray(TOLS, jnp.float32)
    res = srds_sample(model, sched, SolverConfig("ddim"), X,
                      SRDSConfig(per_sample=True,
                                 window=ResidualWindow(1e-3)), tol=tols)
    iters = np.asarray(res.iterations)
    hist = np.asarray(res.window_history)          # (max_iters, K)
    assert hist.shape == (8, len(TOLS))
    assert len(set(iters.tolist())) > 1            # genuinely mixed
    for s in range(len(TOLS)):
        k = int(iters[s])
        assert np.all(hist[:k, s] >= 0)
        assert np.all(hist[k:, s] == -1)           # frozen past convergence
        assert np.all(np.diff(hist[:k, s]) >= 0)
        assert float(res.final_delta[s]) < TOLS[s]
    # windows of different samples actually diverge at some refinement
    live = hist[:int(iters.max())]
    assert any(len(set(row[row >= 0].tolist())) > 1 for row in live)


def test_residual_window_rejects_incompatible_modes():
    """A truncating window policy inherits truncation's incompatibilities
    (GSPMD constraint, straggler reuse)."""
    from repro.core.engine import run_parareal
    fine = lambda h, p, y: h
    G = lambda x, i0: x
    starts = jnp.arange(4, dtype=jnp.int32)
    x0 = jnp.ones((2,))
    with pytest.raises(ValueError, match="block-sharding"):
        run_parareal(G, fine, x0, starts, tol=0.0, max_iters=2,
                     constrain=lambda t: t, window=ResidualWindow(1e-3))
    with pytest.raises(ValueError, match="carry_fine_results"):
        run_parareal(G, fine, x0, starts, tol=0.0, max_iters=2,
                     carry_fine_results=True, window=ResidualWindow(1e-3))


# --------------------------------------------------------------------------
# the serving engine behind the same seam
# --------------------------------------------------------------------------

class _FetchCounter:
    def __init__(self, real):
        self.real = real
        self.shapes = []

    def __call__(self, x):
        out = self.real(x)
        self.shapes.append(out.shape)
        return out


def _engine(model, **kw):
    kw.setdefault("batch_size", 3)
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, dtype=jnp.float64, **kw)


def test_serve_residual_window_one_sync_with_piggyback(monkeypatch):
    """The windowed hot loop still syncs exactly once per refinement — the
    (K,) lane residual and the (B,) per-block residual ride ONE
    concatenated (K+B,) fetch — plus one lane-only fetch per completion."""
    model = _elementwise_model()
    counter = _FetchCounter(serve_diffusion._host_fetch)
    monkeypatch.setattr(serve_diffusion, "_host_fetch", counter)
    eng = _engine(model, window=ResidualWindow(1e-3))
    rids = [eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
            for i in range(5)]
    queue = eng.pull_queue()
    done = {}
    while eng.busy() or queue:
        while queue and eng.free_slots(queue[0][1]) > 0:
            rid, req = queue.pop(0)
            eng.admit(rid, req)
        before = len(counter.shapes)
        completions = eng.step_once()
        done.update(dict(completions))
        fetched = counter.shapes[before:]
        assert len(fetched) == 1 + len(completions), fetched
        assert fetched[0] == (eng.batch_size + 8,)   # (K + B,) piggyback
        for shp in fetched[1:]:
            assert shp == (8,), shp                  # one lane's sample
    assert set(done) == set(rids)


def test_serve_residual_window_close_to_exact_and_billed_by_window():
    """Windowed serving: every response stays within the window_tol drift
    bound of the exact engine's, bills its realized accumulated window
    schedule, and the engine runs no more physical evals than ExactPrefix."""
    model = _elementwise_model()
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]) for i in range(6)]

    def run(**kw):
        eng = _engine(model, truncate_quantum=1, **kw)
        rids = [eng.submit(r) for r in reqs]
        out = eng.drain()
        return [out[r] for r in rids], eng.stats()

    exact, st_e = run()
    win, st_w = run(window=ResidualWindow(1e-3))
    cost = iteration_cost(64, None, 1)
    for a, b in zip(exact, win):
        assert np.max(np.abs(a.sample - b.sample)) < 5e-2
        # billed evals = init + the realized per-step window charges,
        # which never exceed the flat rate and never undercut the floor
        assert cost.init_evals < b.model_evals \
            <= predicted_evals(cost, b.iterations)
    assert st_w["physical_evals"] <= st_e["physical_evals"]
    assert st_w["effective_evals"] == sum(r.model_evals for r in win)


def test_serve_windowed_quantum_bounds_program_cache():
    """Windowed step programs compile per quantized frontier too: the
    cache stays bounded by ~B/quantum variants."""
    model = _elementwise_model()
    eng = _engine(model, truncate_quantum=4, window=ResidualWindow(1e-3))
    for i in range(4):
        eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
    eng.drain()
    (_, step_for, B, _) = eng._programs[next(iter(eng._programs))]
    assert B == 8
    assert set(step_for.windowed.cache) <= {0, 4}
    assert not step_for.cache          # the exact-path cache stayed cold


def test_serve_window_policy_resolution():
    """Engine policy resolution mirrors the core seam: default truncate ->
    ExactPrefix, truncate=False -> FixedBudget, block axis forces
    truncating policies off."""
    model = _elementwise_model()
    assert isinstance(_engine(model).window, ExactPrefix)
    assert isinstance(_engine(model, truncate=False).window, FixedBudget)
    rw = ResidualWindow(1e-3)
    assert _engine(model, window=rw).window is rw
    eng = DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                  num_steps=64, batch_size=2,
                                  dtype=jnp.float64, mesh=object(),
                                  axis="time", window=rw)
    assert isinstance(eng.window, FixedBudget) and not eng.truncate
