"""Compat-substrate tests: both API branches (modern kwargs present vs
absent) are exercised via monkeypatched stand-ins, and the resolved surface
is checked against the really-installed JAX on a single-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


# --------------------------------------------------------------------------
# stand-ins for the two historical shard_map surfaces
# --------------------------------------------------------------------------

def _modern_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return {"api": "modern", "f": f, "mesh": mesh, "in_specs": in_specs,
            "out_specs": out_specs, "check": check_vma}


def _legacy_shard_map(f, *, mesh, in_specs, out_specs, check_rep=True):
    return {"api": "legacy", "f": f, "mesh": mesh, "in_specs": in_specs,
            "out_specs": out_specs, "check": check_rep}


@pytest.mark.parametrize("impl,api", [(_modern_shard_map, "modern"),
                                      (_legacy_shard_map, "legacy")])
def test_shard_map_translates_check_kwarg(monkeypatch, impl, api):
    monkeypatch.setattr(compat, "_raw_shard_map", lambda: impl)
    fn = lambda x: x
    out = compat.shard_map(fn, mesh="MESH", in_specs=P(), out_specs=P(),
                           check_vma=False)
    assert out["api"] == api
    # check_vma=False must reach the impl whichever kwarg it spells
    assert out["check"] is False
    assert out["f"] is fn and out["mesh"] == "MESH"


@pytest.mark.parametrize("impl", [_modern_shard_map, _legacy_shard_map])
def test_shard_map_default_check_left_alone(monkeypatch, impl):
    monkeypatch.setattr(compat, "_raw_shard_map", lambda: impl)
    out = compat.shard_map(lambda x: x, mesh="M", in_specs=P(), out_specs=P())
    assert out["check"] is True   # impl default, untouched


def test_shard_map_branches_identical(monkeypatch):
    """The two branches must produce identical call contents."""
    monkeypatch.setattr(compat, "_raw_shard_map", lambda: _modern_shard_map)
    a = compat.shard_map(abs, mesh="M", in_specs=P("x"), out_specs=P(),
                         check_vma=False)
    monkeypatch.setattr(compat, "_raw_shard_map", lambda: _legacy_shard_map)
    b = compat.shard_map(abs, mesh="M", in_specs=P("x"), out_specs=P(),
                         check_vma=False)
    a.pop("api"), b.pop("api")
    assert a == b


# --------------------------------------------------------------------------
# stand-ins for the two historical make_mesh surfaces
# --------------------------------------------------------------------------

def _modern_make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
    return {"shapes": tuple(axis_shapes), "names": tuple(axis_names),
            "devices": devices, "axis_types": axis_types}


def _legacy_make_mesh(axis_shapes, axis_names, *, devices=None):
    return {"shapes": tuple(axis_shapes), "names": tuple(axis_names),
            "devices": devices, "axis_types": None}


def test_make_mesh_modern_gets_auto_axis_types(monkeypatch):
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda: _modern_make_mesh)
    monkeypatch.setattr(compat, "axis_type_auto", lambda: "AUTO")
    out = compat.make_mesh((2, 4), ("data", "model"))
    assert out["axis_types"] == ("AUTO", "AUTO")
    assert out["shapes"] == (2, 4) and out["names"] == ("data", "model")


def test_make_mesh_legacy_drops_axis_types(monkeypatch):
    """A legacy make_mesh (no axis_types kwarg) must not be passed one —
    even when explicitly requested — instead of raising TypeError."""
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda: _legacy_make_mesh)
    out = compat.make_mesh((2, 4), ("data", "model"),
                           axis_types=("whatever",) * 2)
    assert out["axis_types"] is None
    assert out["shapes"] == (2, 4) and out["names"] == ("data", "model")


def test_make_mesh_branches_identical(monkeypatch):
    """Modulo the axis_types extra, both branches see the same call."""
    monkeypatch.setattr(compat, "axis_type_auto", lambda: None)
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda: _modern_make_mesh)
    a = compat.make_mesh((4,), ("data",), devices="DEVS")
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda: _legacy_make_mesh)
    b = compat.make_mesh((4,), ("data",), devices="DEVS")
    assert a == b


def test_make_mesh_no_impl_fallback(monkeypatch):
    """Pre-make_mesh JAX: the compat layer builds a Mesh by hand."""
    monkeypatch.setattr(compat, "_raw_make_mesh", lambda: None)
    m = compat.make_mesh((1,), ("data",))
    assert m.axis_names == ("data",)
    assert m.devices.shape == (1,)


# --------------------------------------------------------------------------
# against the really-installed JAX
# --------------------------------------------------------------------------

def test_make_mesh_real_jax_single_device():
    m = compat.make_mesh((1,), ("data",))
    assert m.axis_names == ("data",)
    assert m.devices.shape == (1,)


def test_shard_map_real_jax_executes():
    mesh = compat.make_mesh((1,), ("x",))
    fn = compat.shard_map(lambda a: a * 2.0, mesh=mesh, in_specs=P(),
                          out_specs=P(), check_vma=False)
    out = jax.jit(fn)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0) * 2.0)


def test_replication_check_kwarg_detection():
    assert compat._replication_check_kwarg(_modern_shard_map) == "check_vma"
    assert compat._replication_check_kwarg(_legacy_shard_map) == "check_rep"
    assert compat._replication_check_kwarg(
        lambda f, mesh, in_specs, out_specs: None) is None


def test_cost_analysis_normalizes_all_shapes():
    class FakeCompiled:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    # 0.4.x: one-element list of dicts; new JAX: dict; None: unsupported
    assert compat.cost_analysis(FakeCompiled([{"flops": 7.0}])) == {"flops": 7.0}
    assert compat.cost_analysis(FakeCompiled({"flops": 7.0})) == {"flops": 7.0}
    assert compat.cost_analysis(FakeCompiled(None)) == {}
    assert compat.cost_analysis(FakeCompiled([])) == {}


def test_cost_analysis_real_jax():
    compiled = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
    cost = compat.cost_analysis(compiled)
    assert isinstance(cost, dict)
    assert float(cost.get("flops", 0.0)) > 0


def test_axis_size_static_inside_shard_map():
    mesh = compat.make_mesh((1,), ("x",))

    def body(a):
        d = compat.axis_size("x")
        assert int(d) == 1          # must be usable at trace time
        return a * d

    fn = compat.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
    out = jax.jit(fn)(jnp.arange(3.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(3.0))


def test_tree_shim_roundtrip():
    t = {"a": jnp.ones((2,)), "b": [jnp.zeros((1,)), jnp.ones(())]}
    leaves, tdef = compat.tree.flatten(t)
    assert len(leaves) == len(compat.tree.leaves(t)) == 3
    t2 = compat.tree.unflatten(tdef, leaves)
    doubled = compat.tree.map(lambda x: x * 2, t)
    assert float(doubled["a"][0]) == 2.0
    assert compat.tree.structure(t2) == compat.tree.structure(t)


def test_tree_shim_map_with_path():
    t = {"a": jnp.ones((2,)), "b": jnp.zeros(())}
    keyed = compat.tree.map_with_path(
        lambda path, x: float(x.sum()) + len(path), t)
    assert keyed == {"a": 3.0, "b": 1.0}
