"""Batch-aware SRDS: per-sample convergence gating must be *exactly* the
K-independent-runs semantics, and the serving layer must inherit it.

The bitwise tests use an elementwise denoiser: lane math is then identical
for every batch size, so any mismatch is a real cross-sample leak in the
gating/freezing logic (matmul models hit XLA's shape-dependent gemm kernels
— covered separately at 1e-12)."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolverConfig, SRDSConfig, iteration_cost,
                        make_schedule, sample_sequential, srds_sample,
                        truncated_evals)
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest
from conftest import to_f64

TOLS = [1e-2, 1e-4, 1e-6, 1e-3, 1e-5]


def _elementwise_model(dim=8):
    scale = jnp.linspace(0.5, 1.5, dim)

    def model_fn(x, t):
        return jnp.tanh(x * scale) * (0.5 + 0.001 * t)

    return model_fn


def _matmul_model(dim=8):
    w = jax.random.normal(jax.random.PRNGKey(0), (dim, dim),
                          dtype=jnp.float64) * 0.3

    def model_fn(x, t):
        return jnp.tanh(x @ w) * (0.5 + 0.001 * t)

    return model_fn


def _x_batch(k=5, dim=8):
    x = jax.random.normal(jax.random.PRNGKey(1), (k, dim), dtype=jnp.float64)
    # spread the scales so per-sample iteration counts genuinely differ
    return x * jnp.linspace(0.3, 2.5, k)[:, None]


@pytest.mark.parametrize("solver", ["ddim", "heun"])
def test_batched_bit_identical_to_independent_runs(solver):
    """Early-exit path: batched per-sample gating == K independent
    srds_sample calls, bit for bit, including per-sample iterations,
    final_delta and delta_history — under a mixed-tolerance vector."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    cfg = SolverConfig(solver)
    X = _x_batch(len(TOLS))
    res = srds_sample(model, sched, cfg, X, SRDSConfig(per_sample=True),
                      tol=jnp.asarray(TOLS, jnp.float32))
    assert res.iterations.shape == (len(TOLS),)
    assert res.final_delta.shape == (len(TOLS),)
    assert res.delta_history.shape == (8, len(TOLS))
    assert len(set(int(i) for i in res.iterations)) > 1, \
        "test needs genuinely different per-sample iteration counts"
    for k, tol in enumerate(TOLS):
        ind = srds_sample(model, sched, cfg, X[k:k + 1], SRDSConfig(tol=tol))
        assert bool(jnp.all(res.sample[k] == ind.sample[0])), k
        assert int(res.iterations[k]) == int(ind.iterations), k
        assert float(res.final_delta[k]) == float(ind.final_delta), k
        np.testing.assert_array_equal(np.asarray(res.delta_history[:, k]),
                                      np.asarray(ind.delta_history))


def test_batched_bit_identical_fixed_iters():
    """Fixed-budget path: no freezing (matching independent fixed-budget
    runs), but carries stay per-sample."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    cfg = SolverConfig("ddim")
    X = _x_batch(len(TOLS))
    res = srds_sample(model, sched, cfg, X,
                      SRDSConfig(per_sample=True, fixed_iters=True,
                                 max_iters=6),
                      tol=jnp.asarray(TOLS, jnp.float32))
    assert res.delta_history.shape == (6, len(TOLS))
    for k, tol in enumerate(TOLS):
        ind = srds_sample(model, sched, cfg, X[k:k + 1],
                          SRDSConfig(tol=tol, fixed_iters=True, max_iters=6))
        assert bool(jnp.all(res.sample[k] == ind.sample[0])), k
        assert int(res.iterations[k]) == int(ind.iterations) == 6
        np.testing.assert_array_equal(np.asarray(res.delta_history[:, k]),
                                      np.asarray(ind.delta_history))


def test_batched_matmul_model_near_exact():
    """Real (matmul) denoisers hit XLA's shape-dependent gemm kernels, so
    bitwise equality across batch sizes is not guaranteed — but per-sample
    gating must still match independent runs to fp64 roundoff."""
    model = _matmul_model()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    cfg = SolverConfig("ddim")
    X = _x_batch(4)
    tols = TOLS[:4]
    res = srds_sample(model, sched, cfg, X, SRDSConfig(per_sample=True),
                      tol=jnp.asarray(tols, jnp.float32))
    for k, tol in enumerate(tols):
        ind = srds_sample(model, sched, cfg, X[k:k + 1], SRDSConfig(tol=tol))
        assert int(res.iterations[k]) == int(ind.iterations), k
        np.testing.assert_allclose(np.asarray(res.sample[k]),
                                   np.asarray(ind.sample[0]),
                                   rtol=0, atol=1e-12)


def test_batched_exact_to_cap_equals_sequential():
    """tol=0 per-sample batched run must still reproduce the sequential
    solve for every sample (Prop 1 is per-sample too)."""
    model = _elementwise_model()
    sched = to_f64(make_schedule("ddpm_linear", 36))
    cfg = SolverConfig("ddim")
    X = _x_batch(3)
    ref = sample_sequential(model, sched, cfg, X)
    res = srds_sample(model, sched, cfg, X, SRDSConfig(tol=0.0,
                                                       per_sample=True))
    np.testing.assert_allclose(np.asarray(res.sample), np.asarray(ref),
                               rtol=0, atol=1e-12)
    assert np.all(np.asarray(res.iterations) == int(res.iterations[0]))


# --------------------------------------------------------------------------
# the serving layer
# --------------------------------------------------------------------------

def _engine(model, batch_size, **kw):
    return DiffusionSamplingEngine(model, (8,), SolverConfig("ddim"),
                                   num_steps=64, batch_size=batch_size,
                                   dtype=jnp.float64, **kw)


def test_serving_engine_bit_identical_per_request():
    """Draining a mixed-tolerance queue returns, for every request, the
    bit-exact single-request SRDS result — batch-mates, admission order and
    slot recycling must not perturb any sample."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=3)
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]) for i in range(8)]
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    sched = to_f64(make_schedule("ddpm_linear", 64))
    for rid, req in zip(rids, reqs):
        x0 = jax.random.normal(jax.random.PRNGKey(req.seed), (8,),
                               jnp.float64)
        ind = srds_sample(model, sched, SolverConfig("ddim"), x0[None],
                          SRDSConfig(tol=req.tol))
        r = out[rid]
        assert bool(np.all(r.sample == np.asarray(ind.sample[0]))), rid
        assert r.iterations == int(ind.iterations), rid
        np.testing.assert_array_equal(
            r.delta_history,
            np.asarray(ind.delta_history)[:int(ind.iterations)])
    st = eng.stats()
    assert st["requests_served"] == len(reqs)
    assert st["effective_evals"] == sum(out[r].model_evals for r in rids)


def test_serving_engine_beats_lockstep_gating():
    """Slot recycling on a mixed-tolerance queue must cost fewer effective
    model evals than lockstep whole-batch gating (every sample paying for
    the slowest in its batch) — the tentpole's throughput claim."""
    model = _elementwise_model()
    k = 4
    eng = _engine(model, batch_size=k)
    reqs = [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)])
            for i in range(12)]
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    iters = [out[r].iterations for r in rids]
    assert min(iters) < max(iters)  # mixed tolerances actually spread
    b, s, e = 8, 8, 1
    lockstep = sum(len(g) * (b + max(g) * (b * s + b)) * e
                   for g in (iters[i:i + k] for i in range(0, len(iters), k)))
    assert eng.stats()["effective_evals"] < lockstep
    # and the per-sample effective evals equal the truncated
    # independent-run cost (the engine's own frontier schedule)
    cost = iteration_cost(64, None, 1)
    for rid, it in zip(rids, iters):
        assert out[rid].model_evals == truncated_evals(cost, it)


def test_serving_engine_groups_incompatible_grids():
    """Requests on different grids are packed into separate micro-batch
    groups; every request still converges to its own tolerance."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=2)
    reqs = [SampleRequest(seed=0, tol=1e-3, num_steps=64),
            SampleRequest(seed=1, tol=1e-3, num_steps=36),
            SampleRequest(seed=2, tol=1e-4, num_steps=64),
            SampleRequest(seed=3, tol=1e-4)]          # default grid (64)
    rids = [eng.submit(r) for r in reqs]
    out = eng.drain()
    assert set(out) == set(rids)
    for rid, req in zip(rids, reqs):
        n = req.num_steps or 64
        sched = to_f64(make_schedule("ddpm_linear", n))
        x0 = jax.random.normal(jax.random.PRNGKey(req.seed), (8,),
                               jnp.float64)
        ind = srds_sample(model, sched, SolverConfig("ddim"), x0[None],
                          SRDSConfig(tol=req.tol))
        assert out[rid].iterations == int(ind.iterations)
        assert bool(np.all(out[rid].sample == np.asarray(ind.sample[0])))


def test_serving_engine_rejects_bad_requests_at_submit():
    """An unservable request (prime grid: no block decomposition) is
    rejected at submit() and must not poison already-queued requests."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=2)
    good = eng.submit(SampleRequest(seed=0, tol=1e-3))
    with pytest.raises(ValueError, match="prime"):
        eng.submit(SampleRequest(seed=1, tol=1e-3, num_steps=13))
    out = eng.drain()
    assert set(out) == {good}
    assert out[good].iterations >= 1


def test_serving_engine_more_requests_than_slots_recycles():
    """A queue longer than the batch admits into freed slots: all served,
    and the number of refinement steps is bounded by the recycled schedule
    (not requests/batch_size * max_iters)."""
    model = _elementwise_model()
    eng = _engine(model, batch_size=2)
    rids = [eng.submit(SampleRequest(seed=i, tol=TOLS[i % len(TOLS)]))
            for i in range(7)]
    out = eng.drain()
    assert len(out) == 7
    assert all(out[r].iterations >= 1 for r in rids)
