"""Paper Table 3: vanilla vs wavefront-pipelined SRDS.  Supersteps of the
real shard_map wavefront sampler are measured in a fake-device subprocess;
each superstep is ONE lockstep batched model eval (the paper's eff-serial
unit)."""
import json, os, subprocess, sys
import jax
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, toy_denoiser

CODE = r"""
import jax, json
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_pipelined_sampler

N = {n}; B = {b}
w = jax.random.normal(jax.random.PRNGKey(0), (8, 8), dtype=jnp.float64) * 0.4
model_fn = lambda x, t: jnp.tanh(x @ w) * (0.4 + 3e-4 * t)
from repro.compat import make_mesh
mesh = make_mesh((B,), ("time",))
sched = make_schedule("ddpm_linear", N)
sched = DiffusionSchedule(ab=sched.ab.astype(jnp.float64),
                          t_model=sched.t_model.astype(jnp.float64))
x0 = jax.random.normal(jax.random.PRNGKey(1), (1, 8), dtype=jnp.float64)
samp = make_pipelined_sampler(mesh, "time", model_fn, sched,
                              SolverConfig("ddim"), SRDSConfig(tol=1e-4))
res, steps, evals = samp(x0)
ref = sample_sequential(model_fn, sched, SolverConfig("ddim"), x0)
print(json.dumps({{"supersteps": int(steps), "iters": int(res.iterations),
                  "evals": int(evals),
                  "err": float(jnp.mean(jnp.abs(res.sample - ref)))}}))
"""


def main():
    model_fn = toy_denoiser()
    x0 = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
    for n, b in [(961, 31), (196, 14), (25, 5)]:
        sched = make_schedule("ddpm_linear", n)
        r = run_pair(model_fn, sched, SolverConfig("ddim"), x0,
                     SRDSConfig(tol=1e-3, num_blocks=b))
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={b}",
                   PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", CODE.format(n=n, b=b)],
                             capture_output=True, text=True, env=env)
        wf = json.loads(out.stdout.strip().splitlines()[-1]) \
            if out.returncode == 0 else {"supersteps": -1, "iters": -1,
                                         "evals": -1, "err": -1}
        emit(f"table3/ddim{n}", r["t_srds"] * 1e6,
             f"seq_evals={n};vanilla_eff={r['eff_serial']};"
             f"pipelined_supersteps={wf['supersteps']};"
             f"pipelined_iters={wf['iters']};wf_evals={wf['evals']};"
             f"wf_err={wf['err']:.1e}")


if __name__ == "__main__":
    main()
