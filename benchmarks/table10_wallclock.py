"""Table 10w: wall-clock SLO scheduling — the real-time twin of
``table10_slo.py``, replayed through :class:`repro.serve.AsyncServeLoop`
on a :class:`~repro.serve.clock.MonotonicClock` engine.

Same question (does deadline-aware admission beat FIFO's head-of-line
blocking on tail latency?), different ruler: here every latency is real
seconds on a single node, with the pipelined dispatch/resolve loop
overlapping the host-side residual fetch with the next refinement's
device compute.  The shape is a single-node latency sweep:

* a **calibration** pass replays the pinned herd once under FIFO to
  compile every step program the measured runs will hit and to measure
  ``sec_per_eval`` (wall seconds per physical model eval) — the cost
  model CostAware prices admission with must speak wall time;
* a **pinned herd** (every request at t=0, two tight-tolerance heavies
  submitted ahead of the loose-tolerance majority) — the structural
  head-of-line worst case.  FIFO buries the herd behind the heavies;
  EDF/CostAware serve the tight-SLO majority first.  This is where the
  ordering invariant is gated;
* a **Poisson load sweep** at fractions of the calibrated service
  capacity — the latency-vs-load curve a single-node deployment would
  publish.

Wall-clock numbers are noisy where virtual ones were bit-exact, so the
gate is deliberately shaped like the virtual leg's but tolerant: it
asserts *ordering* invariants (EDF and CostAware p95 below FIFO p95 on
the pinned herd, SLO attainment no worse) — never absolute seconds.

Usage (what the CI wall-clock bench leg runs):

    PYTHONPATH=src python -m benchmarks.table10_wallclock --out BENCH_serve.json

The artifact carries a ``table10_wallclock`` key next to the virtual
tables' keys; ``docs/benchmarks.md`` documents the row schema.
"""
import argparse
import json
import math
import os
import platform

import jax
import numpy as np

from repro.core import SolverConfig
from repro.serve import (EDF, FIFO, AsyncServeLoop, CostAware,
                         DiffusionSamplingEngine, MonotonicClock, SampleRequest,
                         Tier, poisson_trace)

from .common import emit, toy_denoiser

N = 64                    # grid -> B=8 blocks of S=8 fine steps
BATCH = 2
# Heavy enough on heavies that FIFO's head-of-line blocking is a
# structural multiple of the light drain time (~24 heavy refinement waves
# before the first light vs ~18 light waves total), not a wall-noise-sized
# perturbation; the gated percentile is computed over the *light tier*
# (see below) so the mix ratio never moves the percentile onto a heavy.
N_HEAVY = 6
N_LIGHT = 18
LIGHT = dict(tol=1e-2, iters_hint=2)
HEAVY = dict(tol=1e-6, iters_hint=8)


def herd_trace(light_slo_ms=None, heavy_slo_ms=None):
    """The pinned herd: everyone arrives at t=0, heavies submitted first
    (deterministic seeds), so FIFO's admission order is the head-of-line
    worst case while EDF's deadline order is shortest-job-first."""
    reqs = [SampleRequest(seed=1000 + i, arrival_time=0.0,
                          slo_ms=heavy_slo_ms, **HEAVY)
            for i in range(N_HEAVY)]
    reqs += [SampleRequest(seed=i, arrival_time=0.0,
                           slo_ms=light_slo_ms, **LIGHT)
             for i in range(N_LIGHT)]
    return reqs


def main(loads=(0.6, 1.5, 3.0), sweep_requests=36):
    model_fn = toy_denoiser(dim=16)
    eng = DiffusionSamplingEngine(model_fn, (16,), SolverConfig("ddim"),
                                  num_steps=N, batch_size=BATCH,
                                  clock=MonotonicClock())
    rows = []

    # ---- calibration: a cold pass compiles everything the measured runs
    # will execute (same seeds/tols -> same step programs), then a second,
    # warm pass measures wall-clock eval throughput without the one-time
    # compile cost polluting it; SLO values play no role under FIFO so
    # placeholders are fine here
    cold = AsyncServeLoop(eng, FIFO()).run(herd_trace())
    assert len(cold.responses) == N_HEAVY + N_LIGHT
    warm = AsyncServeLoop(eng, FIFO()).run(herd_trace())
    assert len(warm.responses) == N_HEAVY + N_LIGHT
    sec_per_eval = warm.makespan / max(warm.physical_evals, 1)
    eng.sec_per_eval = sec_per_eval          # wall-calibrated cost model
    per_req_s = warm.makespan / len(warm.responses)
    capacity_rps = 1.0 / per_req_s
    rows.append(dict(trace="calibration", policy="fifo",
                     sec_per_eval=sec_per_eval,
                     capacity_rps=capacity_rps,
                     makespan_s=warm.makespan,
                     physical_evals=warm.physical_evals))
    emit("table10w/calibration", sec_per_eval * 1e6,
         f"capacity={capacity_rps:.0f}rps;makespan={warm.makespan:.3f}s;"
         f"phys_evals={warm.physical_evals}")

    # SLOs scaled off the calibrated warm herd drain time: the light SLO
    # sits inside the herd's makespan (so admission order decides who
    # makes it), the heavy SLO comfortably outside it
    light_slo_ms = 0.7 * warm.makespan * 1e3
    heavy_slo_ms = 3.0 * warm.makespan * 1e3

    def measure(tname, trace, policy):
        rep = AsyncServeLoop(eng, policy).run(trace)
        row = dict(trace=tname, policy=policy.name,
                   completed=len(rep.responses),
                   rejected=len(rep.rejected),
                   preempted=len(rep.preempted),
                   latency_p50_ms=rep.latency_p50 * 1e3,
                   latency_p95_ms=rep.latency_p95 * 1e3,
                   latency_p99_ms=rep.latency_p99 * 1e3,
                   slo_attainment=rep.slo_attainment,
                   goodput_rps=rep.goodput_rps,
                   makespan_s=rep.makespan,
                   wall_clock=True)
        rows.append(row)
        emit(f"table10w/{tname}/{policy.name}", rep.latency_p95 * 1e3,
             f"p50={row['latency_p50_ms']:.1f}ms;"
             f"p95={row['latency_p95_ms']:.1f}ms;"
             f"slo_att={rep.slo_attainment:.2f};"
             f"goodput={rep.goodput_rps:.1f}rps;"
             f"rejected={len(rep.rejected)}")
        return rep

    # ---- the pinned herd: the gated leg ----
    # The gated percentile is the *light tier's* p95 — the tail of the
    # latency-sensitive majority, which is exactly what head-of-line
    # blocking punishes.  rids are assigned in submission order and the
    # heavies are submitted first, so the N_HEAVY smallest rids of a herd
    # run are the heavy requests.
    herd = herd_trace(light_slo_ms, heavy_slo_ms)
    p95, att, gput = {}, {}, {}
    for policy in (FIFO(), EDF(), CostAware(slack=1.0)):
        rep = measure("herd", herd, policy)
        all_rids = sorted(set(rep.responses) | set(rep.rejected)
                          | set(rep.preempted))
        heavy_rids = set(all_rids[:N_HEAVY])
        lights = [r.latency for rid, r in rep.responses.items()
                  if rid not in heavy_rids]
        light_p95 = float(np.percentile(lights, 95)) if lights else math.inf
        rows[-1]["light_p95_ms"] = light_p95 * 1e3
        p95[policy.name] = light_p95
        att[policy.name] = rep.slo_attainment
        gput[policy.name] = rep.goodput_rps

    # ---- overlap A/B: the same herd with the pipeline disabled
    # (max_inflight=1 == the synchronous stepping discipline); reported,
    # not gated — wall noise on a shared CI core can swamp the overlap win
    sync_rep = AsyncServeLoop(eng, FIFO(), max_inflight=1).run(herd)
    rows.append(dict(trace="herd_overlap_ab", policy="fifo",
                     makespan_async_s=rows[1]["makespan_s"],
                     makespan_sync_s=sync_rep.makespan,
                     overlap_speedup=sync_rep.makespan
                     / max(rows[1]["makespan_s"], 1e-12)))
    emit("table10w/herd_overlap_ab", sync_rep.makespan * 1e6,
         f"sync={sync_rep.makespan:.3f}s;async={rows[1]['makespan_s']:.3f}s;"
         f"ratio={rows[-1]['overlap_speedup']:.2f}x")

    # ---- Poisson latency-vs-load sweep ----
    tiers = [Tier(slo_ms=light_slo_ms, weight=0.96, **LIGHT),
             Tier(slo_ms=heavy_slo_ms, weight=0.04, **HEAVY)]
    for load in loads:
        trace = poisson_trace(sweep_requests, load * capacity_rps, tiers,
                              seed=0)
        for policy in (FIFO(), EDF(), CostAware(slack=1.0)):
            measure(f"poisson_load{load:g}", trace, policy)

    # the wall-clock gate: ordering/attainment invariants on the pinned
    # herd, where head-of-line blocking is structural — no absolute
    # seconds anywhere
    assert p95["edf"] < p95["fifo"], \
        f"EDF light-tier p95 ({p95['edf']:.3f}s) must beat FIFO" \
        f" ({p95['fifo']:.3f}s) on the pinned wall-clock herd"
    assert p95["cost"] < p95["fifo"], \
        f"CostAware light-tier p95 ({p95['cost']:.3f}s) must beat FIFO" \
        f" ({p95['fifo']:.3f}s) on the pinned wall-clock herd"
    band = 0.05               # generous: wall attainment jitters per-run
    assert att["edf"] >= att["fifo"] - band, \
        f"EDF attainment {att['edf']:.2f} fell below FIFO {att['fifo']:.2f}"
    # CostAware trades attainment-over-submitted for goodput: it sheds
    # predicted-hopeless requests, so its invariant is SLO-met throughput
    assert gput["cost"] >= 0.9 * gput["fifo"], \
        f"CostAware goodput {gput['cost']:.1f}rps fell >10% below FIFO" \
        f" {gput['fifo']:.1f}rps"
    return rows


def write_artifact(rows, out):
    """Append the wallclock table to ``out`` (merging with an existing
    BENCH_serve.json from the virtual legs if one is present)."""
    payload = {"meta": {"jax_version": jax.__version__,
                        "backend": jax.default_backend(),
                        "python": platform.python_version()}}
    if os.path.exists(out):
        with open(out) as f:
            payload.update(json.load(f))
    payload["table10_wallclock"] = rows
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    write_artifact(main(), args.out)
