"""Table 9 (new): batched serving — effective model evals per sample vs
batch size, per-slot convergence gating + slot recycling against lockstep
whole-batch gating.

A fixed queue of mixed-tolerance requests (the production shape: users ask
for different quality/latency points) is drained by
``repro.serve.diffusion.DiffusionSamplingEngine`` at several micro-batch
sizes.  Per-slot gating means a converged sample frees its slot for the
next request immediately; lockstep gating (the pre-batch-aware behaviour,
one scalar residual for the whole batch) makes every sample pay for the
slowest in its batch: ``K * max_k(iters_k)`` refinements per batch vs
``sum_k(iters_k)``.  Both are reported in the paper's hardware-independent
unit (model evals per sample; DDIM = 1 eval per step).  Since PR 4 the
engine's effective evals are additionally *prefix-truncated* (refinement
``p`` of a lane only pays for its non-frozen block suffix), so the saving
vs the untruncated lockstep baseline compounds recycling + truncation.
"""
import jax
import jax.numpy as jnp

from repro.core import SolverConfig
from repro.serve.diffusion import DiffusionSamplingEngine, SampleRequest

from .common import emit, toy_denoiser

N = 64           # grid size -> B=8 blocks of S=8 fine steps
TOLS = [1e-2, 1e-3, 1e-4, 1e-5, 3e-3, 1e-4, 1e-2, 1e-5]
REQUESTS = 24


def make_queue(requests: int = REQUESTS):
    return [SampleRequest(seed=i, tol=TOLS[i % len(TOLS)])
            for i in range(requests)]


def main(requests: int = REQUESTS, batch_sizes=(1, 2, 4, 8)):
    rows = []
    model_fn = toy_denoiser(dim=16)
    for k in batch_sizes:
        eng = DiffusionSamplingEngine(model_fn, (16,), SolverConfig("ddim"),
                                      num_steps=N, batch_size=k)
        reqs = make_queue(requests)
        rids = [eng.submit(r) for r in reqs]
        out = eng.drain()
        st = eng.stats()
        b, s = 8, 8
        e = 1  # ddim
        iters = [out[r].iterations for r in rids]
        # lockstep whole-batch gating: requests grouped in arrival order,
        # every sample in a batch refines until the slowest one converges
        lockstep = sum(len(grp) * (b + max(grp) * (b * s + b)) * e
                       for grp in (iters[i:i + k]
                                   for i in range(0, len(iters), k)))
        eff = st["effective_evals_per_sample"]
        lock_per = lockstep / len(reqs)
        emit(f"table9/batch{k}", eff,
             f"evals_per_sample={eff:.1f};lockstep={lock_per:.1f};"
             f"saving={100 * (1 - eff / lock_per):.1f}%;"
             f"physical={st['physical_evals_per_sample']:.1f};"
             f"iters_min={min(iters)};iters_max={max(iters)}")
        rows.append(dict(batch=k, evals_per_sample=eff,
                         lockstep_evals_per_sample=lock_per,
                         saving_pct=100 * (1 - eff / lock_per),
                         physical_per_sample=st["physical_evals_per_sample"],
                         iters_min=min(iters), iters_max=max(iters)))
    return rows


if __name__ == "__main__":
    main()
