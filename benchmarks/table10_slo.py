"""Table 10 (new): SLO scheduling — latency percentiles, SLO attainment and
goodput of the arrival-aware sampling service under FIFO / EDF / cost-model
admission.

A fixed, seeded arrival trace (Poisson steady load + a bursty herd) of
two traffic tiers — a 96% majority of loose-tolerance/tight-SLO requests
and a 4% minority of tight-tolerance/loose-SLO ones — is replayed through
``repro.serve.scheduler.simulate`` on the engine's deterministic virtual
clock (physical model evals x sec_per_eval), so every number here is
bit-reproducible.  The headline: FIFO's head-of-line blocking (one rare
heavy request stalls the herd behind it) inflates p95 latency; EDF's
deadline order is effectively shortest-job-first on this mix and dodges
it, and the cost-model policy additionally sheds provably-hopeless
requests under overload, buying SLO attainment.
"""
from repro.core import SolverConfig
from repro.serve import (EDF, FIFO, CostAware, DiffusionSamplingEngine, Tier,
                         bursty_trace, poisson_trace, simulate)

from .common import emit, toy_denoiser

N = 64                    # grid -> B=8 blocks of S=8 fine steps
BATCH = 2
SEC_PER_EVAL = 1e-5
TIERS = [Tier(tol=1e-2, slo_ms=25, iters_hint=2, weight=0.96),
         Tier(tol=1e-6, slo_ms=400, iters_hint=8, weight=0.04)]


def make_traces(n_requests: int, rate: float):
    """Both trace shapes, pinned to seed 0 (bit-deterministic replay)."""
    return {
        "poisson": poisson_trace(n_requests, rate, TIERS, seed=0),
        "burst": bursty_trace(max(n_requests // 20, 1), 20, period=0.08,
                              tiers=TIERS, seed=0, jitter=0.005),
    }


def main(n_requests: int = 100, rate: float = 380.0):
    model_fn = toy_denoiser(dim=16)
    eng = DiffusionSamplingEngine(model_fn, (16,), SolverConfig("ddim"),
                                  num_steps=N, batch_size=BATCH,
                                  sec_per_eval=SEC_PER_EVAL)
    rows = []
    p95 = {}
    for tname, trace in make_traces(n_requests, rate).items():
        for policy in (FIFO(), EDF(), CostAware(slack=1.0)):
            rep = simulate(eng, trace, policy)
            row = dict(trace=tname, policy=policy.name,
                       completed=len(rep.responses),
                       rejected=len(rep.rejected),
                       latency_p50_ms=rep.latency_p50 * 1e3,
                       latency_p95_ms=rep.latency_p95 * 1e3,
                       latency_p99_ms=rep.latency_p99 * 1e3,
                       slo_attainment=rep.slo_attainment,
                       goodput_rps=rep.goodput_rps,
                       makespan_s=rep.makespan)
            rows.append(row)
            p95[(tname, policy.name)] = rep.latency_p95
            emit(f"table10/{tname}/{policy.name}",
                 rep.latency_p95 * 1e3,
                 f"p50={row['latency_p50_ms']:.1f}ms;"
                 f"p95={row['latency_p95_ms']:.1f}ms;"
                 f"p99={row['latency_p99_ms']:.1f}ms;"
                 f"slo_att={rep.slo_attainment:.2f};"
                 f"goodput={rep.goodput_rps:.1f}rps;"
                 f"rejected={len(rep.rejected)}")
    # the tentpole's latency claim, checked where it's measured
    assert p95[("poisson", "edf")] < p95[("poisson", "fifo")], \
        "EDF must beat FIFO on p95 latency on the pinned Poisson trace"
    return rows


if __name__ == "__main__":
    main()
