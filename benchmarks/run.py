"""Run every paper-table benchmark.  One function per paper table.
Prints ``name,us_per_call,derived`` CSV lines."""
import sys
import time

from . import (prop4_blocksize, table1_pixel, table2_sd, table3_pipelined,
               table4_paradigms, table5_solvers, table6_devices,
               table8_tolerance, table9_batched, table10_slo,
               table11_truncation)

TABLES = [
    ("table1 (pixel diffusion, N=1024)", table1_pixel.main),
    ("table2 (SD-like latent, vanilla SRDS)", table2_sd.main),
    ("table3 (pipelined SRDS)", table3_pipelined.main),
    ("table4 (vs ParaDiGMS)", table4_paradigms.main),
    ("table5 (other solvers)", table5_solvers.main),
    ("table6 (device scaling)", table6_devices.main),
    ("table8 (tolerance ablation)", table8_tolerance.main),
    ("table9 (batched serving)", table9_batched.main),
    ("table10 (SLO scheduling)", table10_slo.main),
    ("table11 (prefix truncation)", table11_truncation.main),
    ("prop4 (block-size optimum)", prop4_blocksize.main),
]


def main() -> None:
    print("name,us_per_call,derived")
    for title, fn in TABLES:
        print(f"# --- {title} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the suite going; report the failure
            print(f"{title},-1,FAILED:{type(e).__name__}:{e}", flush=True)
        print(f"# {title} done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
