"""Paper Table 2: StableDiffusion-v2-like latent diffusion, DDIM 100/25,
vanilla SRDS with max-iteration budgets; CLIP score replaced by direct
error-vs-sequential (approximation-free check) + wall-clock on identical
hardware."""
import jax, jax.numpy as jnp
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, small_dit


def main():
    model_fn, cfg, img = small_dit(layers=2, d=64, img=16, seed=3)
    x0 = jax.random.normal(jax.random.PRNGKey(11), (1, img, img, 3))
    for n, max_iter in [(100, None), (25, 1), (25, 3)]:
        sched = make_schedule("ddpm_linear", n)
        cfgS = SRDSConfig(tol=1e-3, max_iters=max_iter)
        r = run_pair(model_fn, sched, SolverConfig("ddim"), x0, cfgS)
        speed = r["t_seq"] / r["t_srds"]
        emit(f"table2/ddim{n}_maxit{max_iter}", r["t_srds"] * 1e6,
             f"iters={r['iters']};eff_serial={r['eff_serial']};"
             f"total={r['total']};err={r['err']:.2e};"
             f"cpu_speedup={speed:.2f}x")


if __name__ == "__main__":
    main()
