"""Paper Table 4: pipelined SRDS vs ParaDiGMS at thresholds 1e-3/1e-2/1e-1 —
eff-serial evals (the hardware-independent latency unit) + CPU wall-clock
on identical hardware."""
import jax, jax.numpy as jnp
from repro.core import (ParaDiGMSConfig, SolverConfig, SRDSConfig,
                        make_schedule, paradigms_sample, sample_sequential,
                        srds_stats)
from .common import emit, run_pair, timeit, toy_denoiser


def main():
    model_fn = toy_denoiser()
    x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 16))
    for n, b in [(961, 31), (196, 14), (25, 5)]:
        sched = make_schedule("ddpm_linear", n)
        solver = SolverConfig("ddim")
        r = run_pair(model_fn, sched, solver, x0,
                     SRDSConfig(tol=1e-3, num_blocks=b))
        pd = {}
        for tol in (1e-3, 1e-2, 1e-1):
            fn = jax.jit(lambda x, tol=tol: paradigms_sample(
                model_fn, sched, solver, x[0],
                ParaDiGMSConfig(window=min(n, 64), tol=tol)))
            t = timeit(fn, x0)
            res = fn(x0)
            pd[tol] = (int(res.iterations), t)
        emit(f"table4/ddim{n}", r["t_srds"] * 1e6,
             f"srds_eff={r['eff_serial_pipelined']};"
             f"srds_proj={r['proj_speedup_pipelined']:.2f}x;"
             + ";".join(f"paradigms@{k:g}:eff={v[0]},proj={n/max(v[0],1):.2f}x"
                        for k, v in pd.items()))


if __name__ == "__main__":
    main()
