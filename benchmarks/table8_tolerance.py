"""Paper Table 8 (Appendix F): tolerance ablation — iterations & effective
serial evals vs tau; KID replaced by direct error against the sequential
solve (the approximation-free metric)."""
import jax, jax.numpy as jnp
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, small_dit


def main():
    model_fn, cfg, img = small_dit(layers=1, d=32, img=16, seed=5)
    x0 = jax.random.normal(jax.random.PRNGKey(4), (1, img, img, 3))
    sched = make_schedule("ddpm_linear", 1024)
    for tau in (1e-2, 1e-3, 1e-4):
        r = run_pair(model_fn, sched, SolverConfig("ddim"), x0,
                     SRDSConfig(tol=tau, num_blocks=32))
        emit(f"table8/tau{tau:g}", r["t_srds"] * 1e6,
             f"iters={r['iters']};eff_serial={r['eff_serial']};"
             f"total={r['total']};err_vs_seq={r['err']:.2e}")


if __name__ == "__main__":
    main()
