"""Paper Table 6 (Appendix D): device scaling of the distributed SRDS
sampler (1/2/4 fake devices, wall-clock per sample) vs ParaDiGMS."""
import json, os, subprocess, sys
from .common import emit

CODE = r"""
import jax, json, time
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_sharded_sampler

D = {d}
w = jax.random.normal(jax.random.PRNGKey(0), (16, 16)) * 0.4
model_fn = lambda x, t: jnp.tanh(x @ w) * (0.4 + 3e-4 * t)
from repro.compat import make_mesh
mesh = make_mesh((D,), ("time",))
sched = make_schedule("ddpm_linear", 100)
x0 = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
samp = make_sharded_sampler(mesh, "time", model_fn, sched,
                            SolverConfig("ddim"),
                            SRDSConfig(tol=1e-4, num_blocks=20))
res = samp(x0); jax.block_until_ready(res.sample)
ts = []
for _ in range(3):
    t0 = time.perf_counter(); res = samp(x0)
    jax.block_until_ready(res.sample); ts.append(time.perf_counter() - t0)
print(json.dumps({{"t": sorted(ts)[1], "iters": int(res.iterations)}}))
"""


def main():
    for d in (1, 2, 4):
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={d}",
                   PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", CODE.format(d=d)],
                             capture_output=True, text=True, env=env)
        r = json.loads(out.stdout.strip().splitlines()[-1]) \
            if out.returncode == 0 else {"t": -1, "iters": -1}
        emit(f"table6/devices{d}", r["t"] * 1e6,
             f"iters={r['iters']};wallclock_s={r['t']:.3f}")


if __name__ == "__main__":
    main()
