"""Paper Table 6 (Appendix D): device scaling of the distributed SRDS
sampler (1/2/4 fake devices, wall-clock per sample) vs ParaDiGMS.

Beyond the single-axis scaling sweep, the ``mesh_t2d2m2`` row exercises
the full (2 time, 2 data, 2 model) composition on 8 fake devices: real
DiT fine solves through the ``repro.core.denoiser`` seam (patch-sharded
attention over ``model``), checked against the single-device driver and
appended into the gated BENCH_core.json artifact (``--out``) — the
``within_tol`` field is a current-run-alone contract in
``check_bench_core``, so a seam that silently loses single-device parity
fails CI even when wall-clock looks fine.
"""
import argparse
import json
import os
import subprocess
import sys

from .common import emit
from .table12_window import merge_out

CODE = r"""
import jax, json, time
import jax.numpy as jnp
from repro.core import *
from repro.core.pipelined import make_sharded_sampler

D = {d}
w = jax.random.normal(jax.random.PRNGKey(0), (16, 16)) * 0.4
model_fn = lambda x, t: jnp.tanh(x @ w) * (0.4 + 3e-4 * t)
from repro.compat import make_mesh
mesh = make_mesh((D,), ("time",))
sched = make_schedule("ddpm_linear", 100)
x0 = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
samp = make_sharded_sampler(mesh, "time", model_fn, sched,
                            SolverConfig("ddim"),
                            SRDSConfig(tol=1e-4, num_blocks=20))
res = samp(x0); jax.block_until_ready(res.sample)
ts = []
for _ in range(3):
    t0 = time.perf_counter(); res = samp(x0)
    jax.block_until_ready(res.sample); ts.append(time.perf_counter() - t0)
print(json.dumps({{"t": sorted(ts)[1], "iters": int(res.iterations)}}))
"""

# the (time, data, model) composition row: a reduced DiT backbone
# patch-sharded over ``model`` (K/V all-gather), batch split over
# ``data``, Parareal blocks over ``time`` — all through the one Denoiser
# seam, compared against the single-device ``srds_sample`` reference
MESH_SHAPE = (2, 2, 2)          # (time, data, model) on 8 fake devices
MESH_TOL = 5e-5                 # documented shape-dependent-gemm carve-out
MESH_CODE = r"""
import dataclasses as dc
import jax, json, time
import jax.numpy as jnp
from repro.configs.base import get_arch
from repro.configs.srds_dit import dit_denoiser
from repro.core import SRDSConfig, SolverConfig, make_schedule, srds_sample
from repro.core.pipelined import make_sharded_sampler
from repro.launch.mesh import make_srds_mesh
from repro.models.dit import init_dit

cfg = dc.replace(get_arch("srds-dit-cifar"), num_layers=2, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 patch_size=2, dtype="float32")
params = init_dit(cfg, jax.random.PRNGKey(0))
mesh = make_srds_mesh(*{shape})
den = dit_denoiser(cfg, params, shard_axis="model", mesh=mesh,
                   use_kernel=False)
ref_fn = dit_denoiser(cfg, params, use_kernel=False)
sched = make_schedule("ddpm_linear", 8)
solver = SolverConfig("ddim")
x0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
cfg_s = SRDSConfig(num_blocks=4, per_sample=True)
ref = srds_sample(ref_fn, sched, solver, x0, cfg_s)
samp = make_sharded_sampler(mesh, "time", den, sched, solver, cfg_s,
                            data_axis="data")
res = samp(x0); jax.block_until_ready(res.sample)
diff = float(jnp.max(jnp.abs(ref.sample - res.sample)))
ts = []
for _ in range(3):
    t0 = time.perf_counter(); res = samp(x0)
    jax.block_until_ready(res.sample); ts.append(time.perf_counter() - t0)
print(json.dumps({{"t": sorted(ts)[1], "iters": int(jnp.max(res.iterations)),
                   "max_abs_diff": diff}}))
"""


def _run(code: str, devices: int):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env)
    if out.returncode != 0:
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])


def mesh_row():
    """The (time, data, model) DiT row for BENCH_core.json."""
    t, d, m = MESH_SHAPE
    r = _run(MESH_CODE.format(shape=MESH_SHAPE), devices=t * d * m)
    if r is None:
        raise RuntimeError("table6 mesh subprocess failed")
    name = f"table6/mesh_t{t}d{d}m{m}"
    emit(name, r["t"] * 1e6,
         f"iters={r['iters']};max_abs_diff={r['max_abs_diff']:.2e};"
         f"within_tol={r['max_abs_diff'] <= MESH_TOL}")
    return dict(name=name, devices=t * d * m, mesh_time=t, mesh_data=d,
                mesh_model=m, iterations=r["iters"],
                max_abs_diff=r["max_abs_diff"], tol=MESH_TOL,
                within_tol=bool(r["max_abs_diff"] <= MESH_TOL),
                t_mesh_s=r["t"])


def main(out: str = None):
    for dev in (1, 2, 4):
        r = _run(CODE.format(d=dev), devices=dev) or {"t": -1, "iters": -1}
        emit(f"table6/devices{dev}", r["t"] * 1e6,
             f"iters={r['iters']};wallclock_s={r['t']:.3f}")
    return merge_out(out, [mesh_row()], "pinned_table6",
                     {"mesh": dict(zip(("time", "data", "model"),
                                       MESH_SHAPE)),
                      "tol": MESH_TOL, "arch": "srds-dit-cifar/reduced",
                      "seed": 0, "num_steps": 8})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_core.json artifact to append the mesh "
                         "row into")
    main(out=ap.parse_args().out)
