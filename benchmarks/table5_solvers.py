"""Paper Table 5 (Appendix C): SRDS with other off-the-shelf solvers
(DDPM-frozen-noise, DPM-Solver-2, DDIM)."""
import jax, jax.numpy as jnp
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, toy_denoiser


def main():
    model_fn = toy_denoiser()
    x0 = jax.random.normal(jax.random.PRNGKey(3), (1, 16))
    key = jax.random.PRNGKey(9)
    cases = [("ddpm", 961), ("ddpm", 196), ("dpm2", 196), ("dpm2", 25),
             ("ddim", 196), ("ddim", 25)]
    for name, n in cases:
        sched = make_schedule("ddpm_linear", n)
        solver = SolverConfig(name, noise_key=key)
        r = run_pair(model_fn, sched, solver, x0, SRDSConfig(tol=1e-3))
        emit(f"table5/{name}{n}", r["t_srds"] * 1e6,
             f"seq_evals={r['seq_evals']};eff_serial={r['eff_serial']};"
             f"iters={r['iters']};err={r['err']:.1e};"
             f"proj_speedup={r['proj_speedup_pipelined']:.2f}x")


if __name__ == "__main__":
    main()
