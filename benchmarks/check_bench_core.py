"""CI regression gate for the core-hot-path benchmark (BENCH_core.json).

Compares a freshly emitted artifact (``benchmarks.table11_truncation``,
``benchmarks.table12_window``, ``benchmarks.table6_devices``,
``benchmarks.table13_accel`` and ``benchmarks.table14_kernels`` rows,
appended into one file) against the
committed baseline and fails on a >20% regression of any deterministic
count — physical model evals per sample (every ``evals_*`` field a row
carries), Parareal iterations-to-tolerance (``iters_*``, the table13
acceleration rows) and the truncation saving — never wall-clock, which
is runner noise.  A baseline row that disappears is a failure too
(silently dropping a measured config is how regressions hide), as is an
``ExactPrefix`` run that lost bit-identity with the untruncated engine
(``bit_identical`` / ``bit_identical_exact``) on a matching environment,
a table12 row whose residual window stopped doing strictly fewer evals
than the exact prefix, a table13 row whose accelerated run costs *more*
iterations than plain (checked on the current run alone — acceleration
that decelerates is a regression at any count), or any row carrying a
``within_tol`` accuracy verdict that is false (the table6 mesh row's
single-device-parity contract — also current-run-alone, so it gates on
every environment), a table14 kernel row whose fused path lost parity
with its reference (``parity_ok``) or whose tuning-seam provenance
(``config_source``/``config_params``) went missing.

Usage (what .github/workflows/ci.yml runs):

    PYTHONPATH=src python -m benchmarks.table11_truncation --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.table12_window --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.table6_devices --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.table13_accel --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.table14_kernels --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.check_bench_core \
        --current BENCH_core.json \
        --baseline benchmarks/baselines/BENCH_core_baseline.json

Refreshing the baseline after an intentional perf change: re-run all
emitters into one JSON and commit it to ``benchmarks/baselines/``.
"""
import argparse
import json
import sys

TOLERANCE = 0.20      # fail when evals/sample grows by more than this

# boolean bit-identity fields, by table: losing any of them on a matching
# environment fails the gate
_BIT_FIELDS = ("bit_identical", "bit_identical_exact")


def check(current: dict, baseline: dict, tolerance: float = TOLERANCE):
    """Returns a list of failure strings (empty = gate passes).

    Eval counts and the truncation saving ratio are pure arithmetic of
    the iteration count, so they are compared only when the run's
    iteration count matches the baseline's — a ±1-iteration shift near a
    tolerance knife-edge (e.g. a JAX version changing residual roundoff;
    the bench-smoke leg installs the unpinned latest) is an upstream
    numerical matter, not a hot-loop regression.  Bit-identity is a
    property of XLA's shape-dependent kernel selection, so it is gated
    only when the artifact's (jax_version, backend) match the baseline's
    — on a drifted environment it is informational.
    """
    failures = []
    cur_rows = {r["name"]: r for r in current.get("rows", [])}
    cm, bm = current.get("meta", {}), baseline.get("meta", {})
    same_env = (cm.get("jax_version"), cm.get("backend")) == \
        (bm.get("jax_version"), bm.get("backend"))
    for base in baseline.get("rows", []):
        name = base["name"]
        cur = cur_rows.get(name)
        if cur is None:
            failures.append(f"{name}: row missing from current artifact")
            continue
        # table13 rows carry no "iterations" field — their anchor count is
        # iters_plain, the unaccelerated run (same knife-edge reasoning)
        counts_match = (cur.get("iterations") == base.get("iterations")
                        and cur.get("iters_plain") == base.get("iters_plain"))
        if counts_match:
            # every deterministic count the row carries (table11:
            # evals_truncated/untruncated; table12: evals_window/
            # exact_prefix/flat; table13: iters_plain/accel +
            # evals_plain/accel) gates at the same tolerance
            for field in sorted(base):
                if not field.startswith(("evals_", "iters_")) \
                        or field.endswith("_pct"):
                    continue
                b, c = base[field], cur.get(field)
                if c is not None and c > b * (1.0 + tolerance):
                    failures.append(
                        f"{name}: {field} regressed {b} -> {c} "
                        f"(+{100.0 * (c / b - 1.0):.1f}% > "
                        f"{100 * tolerance:.0f}%)")
        for bf in _BIT_FIELDS:
            # a field the baseline measured True must stay True — absent
            # counts as lost too (an emitter that stopped writing it is
            # the silent-drop failure mode this gate exists for)
            if same_env and base.get(bf) and not cur.get(bf):
                failures.append(f"{name}: {bf} lost (exact path no longer "
                                f"bit-identical)")
        # table12 contract: the residual window must do strictly fewer
        # evals than the exact prefix (checked on the current run alone —
        # a window that stopped windowing is a regression at any count)
        if "evals_window" in cur and "evals_exact_prefix" in cur \
                and not cur["evals_window"] < cur["evals_exact_prefix"]:
            failures.append(
                f"{name}: residual window no longer beats the exact "
                f"prefix ({cur['evals_window']} >= "
                f"{cur['evals_exact_prefix']} evals)")
        # the table11 tentpole claim itself is part of the contract — but
        # the saving ratio is also pure arithmetic of the iteration count,
        # so it only gates when the counts match (same reason as evals_*)
        if "evals_truncated" in base \
                and cur.get("iterations") == base.get("iterations") \
                and base["evals_saving_pct"] >= 25.0 \
                > cur["evals_saving_pct"]:
            failures.append(
                f"{name}: truncation saving fell below 25% "
                f"({cur['evals_saving_pct']:.1f}%)")
        # the table13 tentpole claim: the pinned headline row's iteration
        # cut stays >= 25% (counts-matched, like the table11 saving)
        if "iters_accel" in base and counts_match \
                and base["iters_saving_pct"] >= 25.0 \
                > cur["iters_saving_pct"]:
            failures.append(
                f"{name}: acceleration iteration saving fell below 25% "
                f"({cur['iters_saving_pct']:.1f}%)")
    # accuracy contract (table6 mesh row): any current row that measures
    # a within-tolerance verdict must hold it — checked on the current
    # run alone (even rows not yet in the baseline), since parity with
    # the single-device engine is an invariant of the code, not of the
    # environment
    for name, cur in sorted(cur_rows.items()):
        if "within_tol" in cur and not cur["within_tol"]:
            failures.append(
                f"{name}: within_tol is false "
                f"(max_abs_diff={cur.get('max_abs_diff')} > "
                f"tol={cur.get('tol')})")
        # table13 contract: acceleration must never cost iterations —
        # current-run-alone (even rows not yet in the baseline), since
        # accelerated <= plain is an invariant of the code, not of the
        # environment
        if "iters_accel" in cur and "iters_plain" in cur \
                and not cur["iters_accel"] <= cur["iters_plain"]:
            failures.append(
                f"{name}: acceleration costs iterations "
                f"({cur['iters_accel']} > {cur['iters_plain']})")
        # table14 contract: every kernel row must hold fused-vs-reference
        # parity and record where its launch config came from — both
        # current-run-alone (a kernel that stopped matching its reference,
        # or an artifact that stopped recording tuned-vs-default
        # provenance, is a regression on any environment)
        if "parity_ok" in cur and not cur["parity_ok"]:
            failures.append(
                f"{name}: parity_ok is false (fused kernel diverged from "
                f"reference, max_abs_diff={cur.get('max_abs_diff')} > "
                f"tol={cur.get('tol')})")
        if name.startswith("table14/"):
            src = cur.get("config_source")
            params = cur.get("config_params")
            if src not in ("table", "heuristic", "override"):
                failures.append(
                    f"{name}: config_source {src!r} is not one of "
                    f"table/heuristic/override (tuning provenance lost)")
            if not isinstance(params, dict) or not params:
                failures.append(
                    f"{name}: config_params missing/empty (tuning "
                    f"provenance lost)")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, args.tolerance)
    if failures:
        print("BENCH_core regression gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"BENCH_core gate OK ({len(baseline.get('rows', []))} rows within "
          f"{100 * args.tolerance:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
