"""Table 13 (new): Anderson fixed-point acceleration — Parareal
iterations-to-tolerance, plain vs ``AndersonAccel``, at equal
convergence tolerance on a pinned slowly-converging N=100 config.

The headline metric is the paper's own hardware-independent unit: the
*iteration count* — every refinement pays one full fine sweep, so a
mixed run that converges in fewer iterations does proportionally fewer
physical model evals (``evals_plain`` vs ``evals_accel``, priced by
:func:`repro.core.engine.predicted_evals`; mixing itself adds zero model
evals).  The toy is deliberately *slow*: a time-varying linear model
whose per-dim oscillating contraction rates keep the refinement map in
its near-linear tail for many iterations — the regime Anderson mixing is
for (the repo's standard tanh toy converges in 2-3 refinements and
leaves mixing no headroom).  Both arms run untruncated
(``truncate=False``): joint Anderson mixing refuses truncating frontier
policies (see docs/acceleration.md), so the honest comparison is
flat-frontier vs flat-frontier at equal tolerance.

Asserted before anything is reported — a broken accelerator must crash
the benchmark, not emit pretty numbers:

* ``accel=NoAccel()`` is *bit-identical* to the default engine
  (``bit_identical``, gated by ``benchmarks.check_bench_core``);
* the accelerated run never costs more iterations than plain, and the
  headline row cuts them by >= 25%;
* the mixed sample's max-abs error vs the serial solve stays within
  ``err_bound``, a small multiple of the convergence tolerance (the
  mixed fixed point is the same fixed point).

Appends its rows to the ``BENCH_core.json`` artifact, alongside
table11/table12/table6's:

    PYTHONPATH=src python -m benchmarks.table13_accel --out BENCH_core.json

Row schema: ``{name, n, tol, accel, iters_plain, iters_accel,
iters_saving_pct, evals_plain, evals_accel, max_err_plain,
max_err_accel, err_bound, bit_identical, t_plain_s, t_accel_s}`` —
``iters_*`` / ``evals_*`` are deterministic (the regression gate keys on
them); ``t_*`` are informational wall-clock medians.

``--platform`` / ``--host-devices`` route through
:func:`repro.launch.env.configure_platform` (XLA flags must land before
backend init — see docs/benchmarks.md).
"""
import argparse
import dataclasses

from .table12_window import merge_out

# the pinned config: N=100 -> B=10 blocks of S=10 fine steps, cosine
# schedule, ddim, 16-dim slow toy, f32 (the numbers are knife-edge
# sensitive to precision, so the dtype is pinned explicitly)
N = 100
DIM = 16
AMP, FREQ = 2.0, 2.0
SEED = 1
DEPTH, WARMUP = 5, 2
# (tol, err-bound multiple): loose headline tolerance + a tight one
TOLS = [(3.0, 5.0), (0.1, 1.0)]


def slow_model(amp: float = AMP, freq: float = FREQ, dim: int = DIM):
    """Time-varying linear model with slow Parareal convergence: per-dim
    oscillating contraction rates (the same toy as tests/test_accel.py's
    iteration-cut assertions)."""
    import jax
    import jax.numpy as jnp
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    f32 = jnp.float32
    w = freq * (1 + jax.random.uniform(k1, (dim,), f32))
    ph = 2 * jnp.pi * jax.random.uniform(k2, (dim,), f32)
    a = amp * (0.5 + jax.random.uniform(k3, (dim,), f32))

    def model_fn(x, t):
        return (a * jnp.sin(w * t[..., None] * 0.06 + ph) * x).astype(f32)

    return model_fn


def run_rows(n: int = N, dim: int = DIM, tols=tuple(TOLS)):
    import jax
    import jax.numpy as jnp

    from repro.core import (AndersonAccel, NoAccel, SolverConfig, SRDSConfig,
                            iteration_cost, make_schedule, predicted_evals,
                            sample_sequential, srds_sample)

    from .common import emit, timeit

    model_fn = slow_model(dim=dim)
    sched = make_schedule("cosine", n)
    sched = dataclasses.replace(sched, ab=sched.ab.astype(jnp.float32),
                                t_model=sched.t_model.astype(jnp.float32))
    solver = SolverConfig("ddim")
    x0 = jax.random.normal(jax.random.PRNGKey(SEED), (dim,), jnp.float32)
    cost = iteration_cost(n, None, 1)
    ref = jax.jit(lambda x: sample_sequential(model_fn, sched, solver, x))(x0)
    acc = AndersonAccel(depth=DEPTH, warmup=WARMUP)

    def sample_with(cfg):
        return jax.jit(lambda x, c=cfg: srds_sample(
            model_fn, sched, solver, x, c))

    # --- NoAccel bit-identity: the seam's default must not perturb the
    # engine in any way before any acceleration number is trusted
    head_tol = tols[0][0]
    res_d = sample_with(SRDSConfig(tol=head_tol))(x0)
    res_0 = sample_with(SRDSConfig(tol=head_tol, accel=NoAccel()))(x0)
    bit_identical = (
        bool(jnp.all(res_d.sample == res_0.sample))
        and int(res_d.iterations) == int(res_0.iterations)
        and bool(jnp.all(res_d.delta_history == res_0.delta_history)))
    assert bit_identical, (
        f"NoAccel diverged from the default engine at n={n}: iters "
        f"{int(res_0.iterations)} vs {int(res_d.iterations)}")

    rows = []
    for tol, mult in tols:
        samp_p = sample_with(SRDSConfig(tol=tol))
        samp_a = sample_with(SRDSConfig(tol=tol, accel=acc))
        res_p = samp_p(x0)
        res_a = samp_a(x0)
        ip, ia = int(res_p.iterations), int(res_a.iterations)
        assert ia <= ip, (
            f"n={n} tol={tol}: acceleration cost iterations ({ia} > {ip})")
        err_p = float(jnp.max(jnp.abs(res_p.sample - ref)))
        err_a = float(jnp.max(jnp.abs(res_a.sample - ref)))
        # the approximation contract: the mixed fixed point is the same
        # fixed point, so the converged sample stays within a small
        # multiple of the tolerance every run already accepted
        bound = mult * tol
        assert err_a <= bound, (
            f"n={n} tol={tol}: accelerated error {err_a} exceeds "
            f"bound {bound}")
        ev_p = predicted_evals(cost, ip)
        ev_a = predicted_evals(cost, ia)
        t_p = timeit(samp_p, x0)
        t_a = timeit(samp_a, x0)
        name = f"table13/n{n}_tol{tol:g}"
        saving = 100.0 * (1.0 - ia / ip)
        emit(name, t_a * 1e6,
             f"iters={ia}vs{ip}plain;saving={saving:.1f}%;"
             f"evals={ev_a}vs{ev_p};err={err_a:.2e}vs{err_p:.2e}plain;"
             f"bit_identical={bit_identical}")
        rows.append(dict(
            name=name, n=n, tol=tol,
            accel=f"anderson(depth={DEPTH},warmup={WARMUP})",
            iters_plain=ip, iters_accel=ia, iters_saving_pct=saving,
            evals_plain=ev_p, evals_accel=ev_a,
            max_err_plain=err_p, max_err_accel=err_a, err_bound=bound,
            bit_identical=bit_identical, t_plain_s=t_p, t_accel_s=t_a))
    # the tentpole claim: >= 25% fewer iterations to the headline
    # tolerance at equal tolerance on the pinned N=100 config
    assert rows[0]["iters_saving_pct"] >= 25.0, rows[0]
    return rows


def main(out: str = None, n: int = N):
    rows = run_rows(n=n)
    return merge_out(out, rows, "pinned_accel",
                     {"n": n, "dim": DIM, "seed": SEED, "amp": AMP,
                      "freq": FREQ, "schedule": "cosine",
                      "depth": DEPTH, "warmup": WARMUP,
                      "tols": [t for t, _ in TOLS]})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_core.json artifact to append rows into")
    ap.add_argument("--n", type=int, default=N)
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin the JAX backend (gpu additionally installs "
                         "the XLA GPU performance preset) — "
                         "repro.launch.env.configure_platform")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices "
                         "(--xla_force_host_platform_device_count)")
    args = ap.parse_args()
    if args.platform is not None or args.host_devices is not None:
        from repro.launch.env import configure_platform
        configure_platform(args.platform, args.host_devices)
    main(out=args.out, n=args.n)
