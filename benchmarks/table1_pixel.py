"""Paper Table 1: pixel-diffusion benchmarks (LSUN/ImageNet/CIFAR scales),
N=1024 DDIM, tau=0.1-equivalent.  FID is infeasible offline; the
approximation-free property is verified directly (SRDS output vs the
sequential solve on the same model) alongside the paper's eval accounting.
"""
import jax, jax.numpy as jnp
from repro.core import SolverConfig, SRDSConfig, make_schedule
from .common import emit, run_pair, small_dit, toy_denoiser


def main():
    n = 1024
    sched = make_schedule("ddpm_linear", n)
    solver = SolverConfig("ddim")
    rows = [
        ("lsun_scale", small_dit(layers=2, d=64, img=32, seed=0)),
        ("imagenet_scale", small_dit(layers=2, d=64, img=16, seed=1)),
        ("cifar_scale", small_dit(layers=1, d=32, img=16, seed=2)),
    ]
    for name, (model_fn, cfg, img) in rows:
        x0 = jax.random.normal(jax.random.PRNGKey(7), (1, img, img, 3))
        cfgS = SRDSConfig(tol=1e-3, num_blocks=32)
        r = run_pair(model_fn, sched, solver, x0, cfgS)
        emit(f"table1/{name}", r["t_srds"] * 1e6,
             f"iters={r['iters']};eff_serial={r['eff_serial']};"
             f"total={r['total']};seq={r['seq_evals']};"
             f"err_vs_seq={r['err']:.2e};"
             f"eff_frac={r['eff_serial']/r['seq_evals']:.2f}")


if __name__ == "__main__":
    main()
