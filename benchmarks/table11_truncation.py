"""Table 11 (new): converged-prefix truncation — physical model evals per
sample and wall-clock per iteration, truncated vs the untruncated PR 3
baseline engine, on the pinned N=100 config.

The headline metric is hardware-independent and deterministic: *physical
model evals per sample*, from the engine's own accounting
(:func:`repro.core.engine.truncated_evals`, the exact frontier schedule
the unrolled loop executes, vs :func:`predicted_evals` for the while_loop
baseline).  Wall-clock per iteration is the corroborating physical
measurement on this box (same jitted program shape both sides).  The
truncated run is asserted equivalent (same iteration count, samples to
1e-5) before anything is reported — a truncation that drifts must crash
the benchmark, not emit pretty numbers.  ``bit_identical`` is *measured*
and recorded: the toy denoiser is a matmul model, so the shrinking
fine-solve batch may hit shape-dependent gemm kernels (exactly the
documented ``per_sample`` caveat); the bitwise guarantee itself is
enforced by tests/test_truncation.py on elementwise-deterministic models.

Emits the ``BENCH_core.json`` artifact (the seed of the core-hot-path perf
trajectory; CI uploads it and gates on regressions via
``benchmarks.check_bench_core``):

    PYTHONPATH=src python -m benchmarks.table11_truncation --out BENCH_core.json

Schema (``schema: 1``): ``{"meta": {jax_version, backend, python,
pinned: {n, dim, block, tols}}, "rows": [{name, n, tol, iterations,
evals_untruncated, evals_truncated, evals_saving_pct, serial_untruncated,
serial_truncated, t_untruncated_s, t_truncated_s, wallclock_saving_pct,
bit_identical}]}`` — ``evals_*`` fields are deterministic (the regression
gate keys on them); ``t_*`` are informational wall-clock medians.
"""
import argparse
import json
import platform

import jax
import jax.numpy as jnp

from repro.core import (SolverConfig, SRDSConfig, iteration_cost,
                        make_schedule, predicted_evals, srds_sample,
                        srds_stats, truncated_evals)

from .common import emit, timeit, toy_denoiser

# the pinned config: N=100 -> B=10 blocks of S=10 fine steps (Prop 4's
# sqrt(N) optimum), 16-dim toy denoiser, ddim
N = 100
DIM = 16
SEED = 0
TOLS = [0.0, 1e-5, 1e-3]     # exactness budget + two early-exit points


def run_rows(n: int = N, dim: int = DIM, tols=tuple(TOLS)):
    model_fn = toy_denoiser(dim=dim)
    x0 = jax.random.normal(jax.random.PRNGKey(SEED), (2, dim))
    sched = make_schedule("ddpm_linear", n)
    cost = iteration_cost(n, None, 1)
    rows = []
    for tol in tols:
        cfg_u = SRDSConfig(tol=tol)
        cfg_t = SRDSConfig(tol=tol, truncate=True)
        samp_u = jax.jit(lambda x, c=cfg_u: srds_sample(
            model_fn, sched, SolverConfig("ddim"), x, c))
        samp_t = jax.jit(lambda x, c=cfg_t: srds_sample(
            model_fn, sched, SolverConfig("ddim"), x, c))
        res_u = samp_u(x0)
        res_t = samp_t(x0)
        assert int(res_u.iterations) == int(res_t.iterations), (
            f"truncated run diverged at tol={tol}: iters "
            f"{int(res_t.iterations)} vs {int(res_u.iterations)}")
        max_diff = float(jnp.max(jnp.abs(res_u.sample - res_t.sample)))
        # f32 matmul-denoiser roundoff scale over ~100 steps (gemm kernels
        # are batch-shape-dependent); a real truncation bug is O(1)
        assert max_diff < 1e-4, f"tol={tol}: truncated drifted {max_diff}"
        bit_identical = bool(jnp.all(res_u.sample == res_t.sample))
        k = int(res_u.iterations)
        ev_u = predicted_evals(cost, k)
        ev_t = truncated_evals(cost, k)
        t_u = timeit(samp_u, x0)
        t_t = timeit(samp_t, x0)
        st_u = srds_stats(sched, SolverConfig("ddim"), cfg_u, k)
        st_t = srds_stats(sched, SolverConfig("ddim"), cfg_t, k)
        name = f"table11/n{n}_tol{tol:g}"
        saving = 100.0 * (1.0 - ev_t / ev_u)
        emit(name, t_t * 1e6,
             f"iters={k};evals={ev_t}vs{ev_u};saving={saving:.1f}%;"
             f"wallclock={t_t:.4f}s_vs_{t_u:.4f}s;bit_identical={bit_identical}")
        rows.append(dict(
            name=name, n=n, tol=tol, iterations=k,
            evals_untruncated=ev_u, evals_truncated=ev_t,
            evals_saving_pct=saving,
            serial_untruncated=st_u.serial_evals,
            serial_truncated=st_t.serial_evals,
            t_untruncated_s=t_u, t_truncated_s=t_t,
            wallclock_saving_pct=100.0 * (1.0 - t_t / t_u),
            bit_identical=bit_identical, max_abs_diff=max_diff))
    return rows


def main(out: str = None, n: int = N):
    rows = run_rows(n=n)
    # the acceptance bar: >= 25% fewer physical evals on the pinned
    # exactness-budget row (tol=0 runs to the cap)
    head = rows[0]
    assert head["evals_saving_pct"] >= 25.0, head
    payload = {
        "schema": 1,
        "meta": {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "pinned": {"n": n, "dim": DIM, "seed": SEED, "tols": list(TOLS)},
        },
        "rows": rows,
    }
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the BENCH_core.json artifact here")
    ap.add_argument("--n", type=int, default=N)
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin the JAX backend via "
                         "repro.launch.env.configure_platform")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices "
                         "(--xla_force_host_platform_device_count)")
    args = ap.parse_args()
    if args.platform is not None or args.host_devices is not None:
        from repro.launch.env import configure_platform
        configure_platform(args.platform, args.host_devices)
    main(out=args.out, n=args.n)
