"""Table 12 (new): residual-driven sliding window — evals per sample and
trajectory-vs-serial error for ``window_tol`` sweeps, vs the bit-exact
``ExactPrefix`` frontier, at N=100 and N=1000.

The residual window (``repro.core.window.ResidualWindow``) is the opt-in
*approximate* mode: the refinement frontier advances past every leading
block whose per-block residual passed ``window_tol``, not just the
provably-exact prefix — fewer model evals at a quality cost this
benchmark measures head-on.  Two deterministic quantities per row:

* ``evals_window`` — per-sample model evals of the *realized* window
  schedule (``SRDSResult.window_history`` priced by
  :func:`repro.core.engine.windowed_evals`), vs ``evals_exact_prefix``
  (:func:`truncated_evals`, the provable schedule) and ``evals_flat``
  (no truncation);
* ``max_err_window`` — max abs trajectory error vs the serial solve,
  reported next to the exact engine's own ``max_err_exact`` floor and
  asserted bounded (a window that drifts must crash the benchmark, not
  emit pretty numbers).

Before any window row is measured, the ``ExactPrefix`` *policy* run is
asserted identical to the PR 4 ``truncate=True`` engine (same sample,
iterations, delta_history) — the policy seam must not have changed the
exact path — and recorded as ``bit_identical_exact`` (gated by
``benchmarks.check_bench_core``).

Appends its rows to the ``BENCH_core.json`` artifact (creating it if
absent), alongside ``table11_truncation``'s:

    PYTHONPATH=src python -m benchmarks.table11_truncation --out BENCH_core.json
    PYTHONPATH=src python -m benchmarks.table12_window --out BENCH_core.json

Row schema: ``{name, n, tol, window_tol, iterations, evals_flat,
evals_exact_prefix, evals_window, evals_saving_pct, max_err_exact,
max_err_window, err_bound, bit_identical_exact, t_window_s}`` —
``evals_*`` and errors are deterministic (the regression gate keys on the
eval counts); ``t_window_s`` is an informational wall-clock median.
"""
import argparse
import json
import os
import platform

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ExactPrefix, ResidualWindow, SolverConfig, SRDSConfig,
                        iteration_cost, make_schedule, predicted_evals,
                        sample_sequential, srds_sample, truncated_evals,
                        windowed_evals)

from .common import emit, timeit, toy_denoiser

# pinned configs: N=100 -> B=10 x S=10 (Prop 4's sqrt-N optimum); N=1000 ->
# B=25 x S=40, capped at 8 refinements (CI-sized: convergence at TOL lands
# well inside the cap, and the unrolled loop compiles 8 suffixes, not 25)
CONFIGS = [dict(n=100, max_iters=None), dict(n=1000, max_iters=8)]
DIM = 16
SEED = 0
TOL = 1e-4                        # convergence tolerance of every run
WINDOW_TOLS = [1e-2, 1e-3, 1e-4]  # the approximation knob sweep


def run_rows(n: int, max_iters=None, dim: int = DIM,
             window_tols=tuple(WINDOW_TOLS)):
    model_fn = toy_denoiser(dim=dim)
    x0 = jax.random.normal(jax.random.PRNGKey(SEED), (2, dim))
    sched = make_schedule("ddpm_linear", n)
    solver = SolverConfig("ddim")
    cost = iteration_cost(n, None, 1)
    ref = jax.jit(lambda x: sample_sequential(model_fn, sched, solver, x))(x0)

    def sample_with(cfg):
        return jax.jit(lambda x, c=cfg: srds_sample(
            model_fn, sched, solver, x, c))

    # --- the exact side: PR 4 truncate engine vs the ExactPrefix policy —
    # the policy seam must reproduce it bit for bit
    samp_t = sample_with(SRDSConfig(tol=TOL, max_iters=max_iters,
                                    truncate=True))
    samp_e = sample_with(SRDSConfig(tol=TOL, max_iters=max_iters,
                                    window=ExactPrefix()))
    res_t = samp_t(x0)
    res_e = samp_e(x0)
    bit_identical_exact = (
        bool(jnp.all(res_t.sample == res_e.sample))
        and int(res_t.iterations) == int(res_e.iterations)
        and bool(jnp.all(res_t.delta_history == res_e.delta_history)))
    assert bit_identical_exact, (
        f"ExactPrefix policy diverged from the truncate=True engine at "
        f"n={n}: iters {int(res_e.iterations)} vs {int(res_t.iterations)}")
    k_exact = int(res_t.iterations)
    ev_flat = predicted_evals(cost, k_exact)
    ev_exact = truncated_evals(cost, k_exact)
    err_exact = float(jnp.max(jnp.abs(res_t.sample - ref)))

    rows = []
    for wt in window_tols:
        samp_w = sample_with(SRDSConfig(tol=TOL, max_iters=max_iters,
                                        window=ResidualWindow(wt)))
        res_w = samp_w(x0)
        k = int(res_w.iterations)
        ev_w = windowed_evals(cost, np.asarray(res_w.window_history))
        err_w = float(jnp.max(jnp.abs(res_w.sample - ref)))
        # the approximation contract: drift is bounded by the knob (plus
        # the convergence-tolerance floor every run already accepted);
        # a real window bug is O(1)
        bound = 20.0 * (wt + TOL) + 10.0 * err_exact
        assert err_w <= bound, (
            f"n={n} window_tol={wt}: trajectory error {err_w} exceeds "
            f"bound {bound}")
        t_w = timeit(samp_w, x0)
        name = f"table12/n{n}_wtol{wt:g}"
        saving = 100.0 * (1.0 - ev_w / ev_exact)
        emit(name, t_w * 1e6,
             f"iters={k};evals={ev_w}vs{ev_exact}exact/{ev_flat}flat;"
             f"saving_vs_exact={saving:.1f}%;err={err_w:.2e};"
             f"bit_identical_exact={bit_identical_exact}")
        rows.append(dict(
            name=name, n=n, tol=TOL, window_tol=wt, iterations=k,
            evals_flat=ev_flat, evals_exact_prefix=ev_exact,
            evals_window=ev_w, evals_saving_pct=saving,
            max_err_exact=err_exact, max_err_window=err_w, err_bound=bound,
            bit_identical_exact=bit_identical_exact, t_window_s=t_w))
    # the tentpole claim: the residual window at window_tol=1e-3 does
    # strictly fewer evals/sample than the provable exact prefix
    head = [r for r in rows if r["window_tol"] == 1e-3]
    for r in head:
        assert r["evals_window"] < r["evals_exact_prefix"], r
    return rows


def merge_out(out: str, rows, meta_key: str, meta_val):
    """Append rows into an existing BENCH_core.json (same schema), so
    table11 and table12 share one gated artifact; same-name rows are
    replaced, others preserved."""
    payload = {"schema": 1, "meta": {}, "rows": []}
    if out and os.path.exists(out):
        with open(out) as f:
            payload = json.load(f)
    payload.setdefault("meta", {}).update({
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        meta_key: meta_val,
    })
    kept = [r for r in payload.get("rows", [])
            if r["name"] not in {r2["name"] for r2 in rows}]
    payload["rows"] = kept + rows
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
    return payload


def main(out: str = None, configs=None):
    rows = []
    for cfg in (configs if configs is not None else CONFIGS):
        rows.extend(run_rows(**cfg))
    return merge_out(out, rows, "pinned_window",
                     {"configs": CONFIGS, "dim": DIM, "seed": SEED,
                      "tol": TOL, "window_tols": WINDOW_TOLS})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_core.json artifact to append rows into")
    ap.add_argument("--n", type=int, default=None,
                    help="run a single grid size instead of the pinned set")
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin the JAX backend via "
                         "repro.launch.env.configure_platform")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices "
                         "(--xla_force_host_platform_device_count)")
    args = ap.parse_args()
    if args.platform is not None or args.host_devices is not None:
        from repro.launch.env import configure_platform
        configure_platform(args.platform, args.host_devices)
    cfgs = None
    if args.n is not None:
        cfgs = [c for c in CONFIGS if c["n"] == args.n] \
            or [dict(n=args.n, max_iters=8)]
    main(out=args.out, configs=cfgs)
