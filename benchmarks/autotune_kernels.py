"""Measured kernel autotune sweep — refreshes the committed tuning tables.

Times every candidate launch configuration of each Pallas kernel family
(``elementwise``, ``flash``, ``rwkv6``) on the current backend, picks the
fastest per ``(kernel, dtype, shape-bucket)`` key, and assembles a
schema-valid tuning-table payload (``repro.kernels.tuning.validate_table``)
that ``--write-table`` commits to ``src/repro/kernels/tuning_tables/
<backend>.json`` — the table tier the :class:`~repro.kernels.tuning.
KernelTuner` resolves from.

Two modes:

* **full sweep** (default) — backend-gated: refuses to run unless
  ``kernels.ops.fused_default()`` is true (a compiled TPU/GPU lowering),
  because interpret-mode timings would tune the emulator, not the
  hardware.  Paper-scale shapes, the full candidate grid, bf16 included
  for flash.  Slow by construction — run it on the accelerator you are
  tuning for, then commit the refreshed table.
* **``--smoke``** — what CI runs in the CPU-only container: tiny shapes
  in interpret mode, a trimmed candidate grid, f32 only.  Wall-clock is
  informational; the assertions are structural — every winning config
  must (a) execute and match the reference path numerically, (b) land in
  a payload ``validate_table`` accepts, and (c) round-trip through a
  ``KernelTuner(tables=...)`` resolve with ``source == "table"``.

Each swept key also emits a roofline-harness-format cell (see
``benchmarks.roofline``) when ``--cells-dir`` is given: ``compute_s``
holds the tuned time, ``memory_s`` the heuristic-default time,
``collective_s`` is 0.0 and ``useful_fraction`` is the default/tuned
speedup — so ``python -m benchmarks.roofline --dir <cells-dir>`` renders
the tuning wins next to the sharding cells.

    PYTHONPATH=src python -m benchmarks.autotune_kernels --smoke
    PYTHONPATH=src python -m benchmarks.autotune_kernels \
        --platform gpu --write-table --cells-dir experiments/autotune

``--platform`` / ``--host-devices`` route through
:func:`repro.launch.env.configure_platform` (XLA flags must land before
backend init — see docs/benchmarks.md).
"""
import argparse
import json
import os
import sys

# (kernel, dtype) -> problem shape, per mode.  Shapes are the tuning
# shapes the seam buckets on: elementwise times a (rows, cols) operand,
# flash a (batch, heads, sq, sk, d) attention, rwkv6 a (b, h, t, dk, dv)
# recurrence.
_SHAPES = {
    False: {  # full sweep — paper-scale
        "elementwise": (4096, 256),
        "flash": (1, 4, 1024, 1024, 64),
        "rwkv6": (1, 4, 256, 64, 64),
    },
    True: {  # --smoke — interpret-mode friendly
        "elementwise": (64, 64),
        "flash": (1, 2, 64, 64, 16),
        "rwkv6": (1, 2, 32, 8, 8),
    },
}


def candidates(kernel: str, backend: str, smoke: bool):
    """Candidate param dicts for one kernel family on one backend."""
    if kernel == "elementwise":
        rows = (32, 64) if smoke else (32, 64, 128, 256, 512)
        return [{"tile_rows": r} for r in rows]
    if kernel == "flash":
        if smoke:
            pairs = ((16, 16), (32, 32))
        elif backend == "gpu":
            # Triton cares about warp/stage counts too
            return [{"block_q": bq, "block_k": bk,
                     "num_warps": w, "num_stages": s}
                    for bq, bk in ((64, 64), (128, 64), (128, 128))
                    for w in (4, 8) for s in (2, 3)]
        else:
            pairs = ((64, 64), (128, 128), (256, 128))
        return [{"block_q": bq, "block_k": bk} for bq, bk in pairs]
    if kernel == "rwkv6":
        caps = (8, 16) if smoke else (8, 16, 32, 64)
        return [{"chunk_target": c} for c in caps]
    raise ValueError(f"unknown kernel {kernel!r}")


def _runner(kernel: str, shape, dtype, interpret: bool):
    """Returns ``(run(params) -> array, ref_out, arg_bytes)`` for one key."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, tuning

    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    if kernel == "elementwise":
        x = jax.random.normal(keys[0], shape, dtype)
        eps = jax.random.normal(keys[1], shape, dtype)
        args = (x, eps)

        def run(params):
            return ops.ddim_fused(x, eps, 0.98, 0.19,
                                  block_rows=params["tile_rows"],
                                  use_kernel=True)

        ref = ops.ddim_fused(x, eps, 0.98, 0.19, use_kernel=False)
    elif kernel == "flash":
        b, h, sq, sk, d = shape
        q = jax.random.normal(keys[0], (b, h, sq, d), dtype)
        k = jax.random.normal(keys[1], (b, h, sk, d), dtype)
        v = jax.random.normal(keys[2], (b, h, sk, d), dtype)
        args = (q, k, v)

        def run(params):
            return ops.attention(q, k, v, causal=True,
                                 block_q=params["block_q"],
                                 block_k=params["block_k"],
                                 num_warps=params.get("num_warps"),
                                 num_stages=params.get("num_stages"),
                                 use_kernel=True)

        ref = ops.attention(q, k, v, causal=True, use_kernel=False)
    elif kernel == "rwkv6":
        b, h, t, dk, dv = shape
        r = jax.random.normal(keys[0], (b, h, t, dk), dtype)
        k = jax.random.normal(keys[1], (b, h, t, dk), dtype)
        v = jax.random.normal(keys[2], (b, h, t, dv), dtype)
        w = jax.random.normal(keys[3], (b, h, t, dk), dtype) * 0.1
        u = jax.random.normal(keys[4], (h, dk), dtype)
        args = (r, k, v, w, u)

        def run(params):
            chunk = tuning.pick_chunk(t, params["chunk_target"])
            out, _ = ops.rwkv6_wkv(r, k, v, w, u, chunk=chunk,
                                   use_kernel=True)
            return out

        ref, _ = ops.rwkv6_wkv(r, k, v, w, u, use_kernel=False)
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    arg_bytes = sum(int(a.size) * a.dtype.itemsize for a in args)
    return run, ref, arg_bytes


def tuning_shape(kernel: str, shape):
    """The shape the seam buckets on (not the operand layout)."""
    if kernel == "flash":
        _, _, sq, sk, d = shape
        return (sq, sk, d)
    if kernel == "rwkv6":
        _, _, t, dk, _ = shape
        return (t, dk)
    return shape


def sweep_key(kernel: str, dtype_name: str, smoke: bool, backend: str,
              tol: float):
    """Time default + candidates for one key; returns (entry, cell)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import tuning

    from .common import timeit

    shape = _SHAPES[smoke][kernel]
    run, ref, arg_bytes = _runner(kernel, shape, jnp.dtype(dtype_name),
                                  interpret=smoke)
    # heuristic-tier baseline: an empty (valid) in-memory table blocks the
    # committed-table tier, so memory_s prices the pre-tuning default
    empty = {"version": tuning.TABLE_SCHEMA_VERSION, "backend": backend,
             "entries": []}
    default = tuning.KernelTuner(tables={backend: empty}).resolve(
        kernel, backend=backend, dtype=dtype_name,
        shape=tuning_shape(kernel, shape))
    t_default = timeit(run, dict(default.params), repeats=1 if smoke else 3)
    best_params, t_best = dict(default.params), t_default
    for params in candidates(kernel, backend, smoke):
        out = run(params)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err <= tol, (
            f"{kernel}/{dtype_name}{shape}: candidate {params} diverged "
            f"from the reference path (max abs diff {err} > {tol})")
        t = timeit(run, params, repeats=1 if smoke else 3)
        if t < t_best:
            best_params, t_best = dict(params), t
    bucket = tuning.bucket_for(kernel, tuning_shape(kernel, shape))
    entry = {"kernel": kernel, "dtype": dtype_name,
             "bucket": list(bucket), "params": best_params}
    cell = {
        "arch": backend, "shape": f"{kernel}/{dtype_name}{tuple(shape)}",
        "mesh": "-",
        "roofline": {"compute_s": t_best, "memory_s": t_default,
                     "collective_s": 0.0, "dominant": "compute_s",
                     "useful_fraction": (t_default / t_best)
                     if t_best > 0 else None},
        "memory_analysis": {"argument_bytes": arg_bytes,
                            "temp_bytes": int(ref.size) * ref.dtype.itemsize},
    }
    print(f"autotune {backend}/{kernel}/{dtype_name} bucket={list(bucket)}: "
          f"best={best_params} ({t_best * 1e6:.0f}us vs "
          f"{t_default * 1e6:.0f}us default)", flush=True)
    return entry, cell


def sweep(smoke: bool, cells_dir: str = None):
    """Runs the sweep; returns the schema-valid table payload."""
    import jax

    from repro.kernels import tuning

    backend = jax.default_backend()
    dtypes = {"elementwise": ["float32"], "rwkv6": ["float32"],
              "flash": ["float32"] if smoke else ["float32", "bfloat16"]}
    tols = {"float32": 5e-5, "bfloat16": 5e-2}
    entries, cells = [], []
    for kernel in tuning.KERNELS:
        for dt in dtypes[kernel]:
            entry, cell = sweep_key(kernel, dt, smoke, backend, tols[dt])
            entries.append(entry)
            cells.append(cell)
    payload = {
        "version": tuning.TABLE_SCHEMA_VERSION,
        "backend": backend,
        "comment": ("measured by benchmarks.autotune_kernels "
                    + ("--smoke (structural check only — interpret-mode "
                       "timings tune the emulator, do not commit)"
                       if smoke else "(full sweep)")),
        "entries": entries,
    }
    tuning.validate_table(payload, "<autotune sweep>")
    # round-trip self-check: a tuner built on this payload must resolve
    # every swept key from the table tier with exactly the winning params
    tuner = tuning.KernelTuner(tables={backend: payload})
    for e in entries:
        cfg = tuner.resolve(e["kernel"], backend=backend, dtype=e["dtype"],
                            shape=tuple(e["bucket"])
                            if e["kernel"] != "elementwise"
                            else (e["bucket"][0],))
        assert cfg.source == "table", cfg
        assert all(cfg.params.get(p) == val
                   for p, val in e["params"].items()), cfg
    if cells_dir:
        os.makedirs(cells_dir, exist_ok=True)
        for cell in cells:
            slug = cell["shape"].replace("/", "_").replace(" ", "")
            with open(os.path.join(cells_dir, f"{slug}.json"), "w") as f:
                json.dump(cell, f, indent=2, sort_keys=True)
        print(f"wrote {len(cells)} roofline cells to {cells_dir}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode structural check (CI mode); "
                         "timings informational, table not committed")
    ap.add_argument("--write-table", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write the swept table (default: the committed "
                         "tuning_tables/<backend>.json)")
    ap.add_argument("--cells-dir", default=None,
                    help="emit roofline-format cells here "
                         "(benchmarks.roofline --dir renders them)")
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin the JAX backend (gpu additionally installs "
                         "the XLA GPU performance preset) — "
                         "repro.launch.env.configure_platform")
    ap.add_argument("--host-devices", type=int, default=None)
    args = ap.parse_args(argv)
    if args.platform is not None or args.host_devices is not None:
        from repro.launch.env import configure_platform
        configure_platform(args.platform, args.host_devices)

    from repro.kernels import ops, tuning

    if not args.smoke and not ops.fused_default():
        print("autotune_kernels: full sweep needs a compiled Pallas "
              "backend (fused_default() is false here) — interpret-mode "
              "timings would tune the emulator.  Run with --smoke for the "
              "structural check, or on TPU/GPU for a real sweep.",
              file=sys.stderr)
        return 2
    payload = sweep(args.smoke, cells_dir=args.cells_dir)
    print(json.dumps(payload, indent=2, sort_keys=True))
    if args.write_table is not None:
        path = args.write_table or os.path.join(
            tuning.TABLE_DIR, f"{payload['backend']}.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
