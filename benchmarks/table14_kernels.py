"""Table 14 (new): fused Pallas kernel families — per-backend parity and
tuning-seam provenance rows in the gated ``BENCH_core.json`` artifact.

One row per kernel family (``elementwise``, ``flash``, ``rwkv6``) on the
current backend.  Each row carries:

* ``parity_ok`` — the fused path (compiled on TPU/GPU, interpret-mode in
  the CPU container) matches the pure-``jnp`` reference numerically at
  the pinned shape/tolerance.  Gated current-run-alone by
  ``benchmarks.check_bench_core`` — a kernel that stopped matching its
  reference is a correctness regression on any environment, at any
  count.
* ``config_source`` / ``config_params`` — where the launch configuration
  came from (``table`` entry, backend ``heuristic``, or an ``override``)
  and what it resolved to, so the artifact records whether the run used
  tuned or default tiles.  The gate requires the provenance fields to be
  present and well-formed on every table14 row.
* ``max_abs_diff`` / ``tol`` and an informational wall-clock median.

Appends into the shared artifact, alongside table11/12/6/13's rows:

    PYTHONPATH=src python -m benchmarks.table14_kernels --out BENCH_core.json

``--platform`` / ``--host-devices`` route through
:func:`repro.launch.env.configure_platform` (XLA flags must land before
backend init — see docs/benchmarks.md).
"""
import argparse

from .table12_window import merge_out

# pinned probe shapes — big enough to cross tile boundaries (and to be
# non-multiples of every default tile), small enough for interpret mode
ELEM_SHAPE = (3, 129)                # ddim_fused: flattened total 387
FLASH_SHAPE = (1, 2, 48, 80, 16)    # (b, h, sq, sk, d), cross-attention
RWKV_SHAPE = (1, 2, 36, 8, 12)      # (b, h, t, dk, dv), t % 32 != 0
TOL = {"float32": 5e-5}


def run_rows():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, tuning

    from .common import emit, timeit

    backend = jax.default_backend()
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    f32 = jnp.float32
    tol = TOL["float32"]

    probes = {}

    x = jax.random.normal(keys[0], ELEM_SHAPE, f32)
    eps = jax.random.normal(keys[1], ELEM_SHAPE, f32)
    probes["elementwise"] = (
        lambda: ops.ddim_fused(x, eps, 0.98, 0.19, use_kernel=True),
        lambda: ops.ddim_fused(x, eps, 0.98, 0.19, use_kernel=False),
        (x.size,))

    b, h, sq, sk, d = FLASH_SHAPE
    q = jax.random.normal(keys[2], (b, h, sq, d), f32)
    k = jax.random.normal(keys[3], (b, h, sk, d), f32)
    v = jax.random.normal(keys[4], (b, h, sk, d), f32)
    probes["flash"] = (
        lambda: ops.attention(q, k, v, causal=True, use_kernel=True),
        lambda: ops.attention(q, k, v, causal=True, use_kernel=False),
        (sq, sk, d))

    bb, hh, t, dk, dv = RWKV_SHAPE
    r_ = jax.random.normal(keys[5], (bb, hh, t, dk), f32)
    k_ = jax.random.normal(keys[6], (bb, hh, t, dk), f32)
    v_ = jax.random.normal(keys[7], (bb, hh, t, dv), f32)
    w_ = jax.random.normal(keys[0], (bb, hh, t, dk), f32) * 0.1
    u_ = jax.random.normal(keys[1], (hh, dk), f32)
    probes["rwkv6"] = (
        lambda: ops.rwkv6_wkv(r_, k_, v_, w_, u_, use_kernel=True)[0],
        lambda: ops.rwkv6_wkv(r_, k_, v_, w_, u_, use_kernel=False)[0],
        (t, dk))

    rows = []
    for kernel, (fused, reference, shape) in probes.items():
        cfg = tuning.resolve(kernel, backend=backend, dtype=f32, shape=shape)
        out = fused()
        ref_out = reference()
        diff = float(jnp.max(jnp.abs(out.astype(f32) - ref_out.astype(f32))))
        parity_ok = diff <= tol
        assert parity_ok, (
            f"{backend}/{kernel}: fused path diverged from reference "
            f"(max abs diff {diff} > {tol})")
        t_fused = timeit(fused)
        name = f"table14/{backend}/{kernel}"
        emit(name, t_fused * 1e6,
             f"parity_ok={parity_ok};diff={diff:.2e};"
             f"config={cfg.source}:{dict(cfg.params)}")
        rows.append(dict(
            name=name, kernel=kernel, backend=backend, dtype="float32",
            compiled=ops.fused_default(), parity_ok=parity_ok,
            max_abs_diff=diff, tol=tol,
            config_source=cfg.source, config_params=dict(cfg.params),
            t_fused_s=t_fused))
    return rows


def main(out: str = None):
    rows = run_rows()
    return merge_out(out, rows, "pinned_kernels",
                     {"elementwise_shape": list(ELEM_SHAPE),
                      "flash_shape": list(FLASH_SHAPE),
                      "rwkv6_shape": list(RWKV_SHAPE),
                      "tol": TOL})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_core.json artifact to append rows into")
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="pin the JAX backend (gpu additionally installs "
                         "the XLA GPU performance preset) — "
                         "repro.launch.env.configure_platform")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices "
                         "(--xla_force_host_platform_device_count)")
    args = ap.parse_args()
    if args.platform is not None or args.host_devices is not None:
        from repro.launch.env import configure_platform
        configure_platform(args.platform, args.host_devices)
    main(out=args.out)
