"""CI bench smoke: a CI-sized run of the two serving benchmarks
(table9 batched slot-recycling, table10 SLO scheduling) written to a JSON
artifact — the seed of the serving-perf trajectory.

Usage (what .github/workflows/ci.yml runs):

    PYTHONPATH=src python -m benchmarks.serve_smoke --out BENCH_serve.json

Sizes are deliberately small (a couple of minutes on a cold CPU runner);
the numbers that matter are the hardware-independent ones — evals/sample
savings and virtual-clock latency/SLO metrics — which are identical to the
full-size runs' shape and bit-deterministic, so regressions diff cleanly
across workflow artifacts.
"""
import argparse
import json
import platform

import jax

from . import table9_batched, table10_slo


def main(out: str = "BENCH_serve.json"):
    payload = {
        "meta": {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
        },
        # CI-sized: 12 requests over 2 batch sizes / 40 requests per trace
        "table9_batched": table9_batched.main(requests=12,
                                              batch_sizes=(1, 4)),
        "table10_slo": table10_slo.main(n_requests=40, rate=380.0),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    main(ap.parse_args().out)
